//! API-compatible stub of the `xla` PJRT bindings used by `rpel::runtime`.
//!
//! The offline crate set does not carry the real `xla` crate (it links the
//! `xla_extension` C++ library). This stub reproduces exactly the API
//! surface the runtime touches so the crate builds and tests everywhere:
//! the client constructs (artifact directories still open and list their
//! manifests), while HLO parsing/compilation/execution fail with an
//! actionable "stubbed" message, so every HLO-engine path degrades to a
//! clear runtime error instead of failing to link. The Literal plumbing is
//! real enough that shape bookkeeping and marshalling stay exercised.
//!
//! To enable the production HLO path, point the `xla` dependency in the
//! workspace `Cargo.toml` at the real bindings; no `rpel` source changes
//! are needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' debug-formatted errors.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the xla/PJRT bindings are stubbed in this build \
         (offline crate set); use the native engine or link the real \
         `xla` crate"
    )))
}

/// Element types the literal marshalling supports.
pub trait NativeType: Copy {
    fn into_elements(data: &[Self]) -> Elements;
    fn from_elements(e: &Elements) -> Option<Vec<Self>>;
}

/// Typed storage behind a [`Literal`].
#[derive(Clone, Debug)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    fn into_elements(data: &[Self]) -> Elements {
        Elements::F32(data.to_vec())
    }

    fn from_elements(e: &Elements) -> Option<Vec<Self>> {
        match e {
            Elements::F32(v) => Some(v.clone()),
            Elements::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn into_elements(data: &[Self]) -> Elements {
        Elements::I32(data.to_vec())
    }

    fn from_elements(e: &Elements) -> Option<Vec<Self>> {
        match e {
            Elements::I32(v) => Some(v.clone()),
            Elements::F32(_) => None,
        }
    }
}

/// Host-side tensor value (flat storage + dims, or a tuple of literals).
#[derive(Clone, Debug)]
pub enum Literal {
    Array { data: Elements, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal over a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            data: T::into_elements(data),
            dims: vec![data.len() as i64],
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal::Array {
            data: T::into_elements(&[value]),
            dims: Vec::new(),
        }
    }

    /// Reshape; the element count must match the new dims.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, dims: old } => {
                let count: i64 = dims.iter().product();
                let old_count: i64 = old.iter().product();
                if count != old_count {
                    return Err(Error(format!(
                        "reshape {old:?} -> {dims:?}: element count mismatch"
                    )));
                }
                Ok(Literal::Array {
                    data: data.clone(),
                    dims: dims.to_vec(),
                })
            }
            Literal::Tuple(_) => Err(Error("cannot reshape a tuple literal".into())),
        }
    }

    /// Flat element vector, typed.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::from_elements(data)
                .ok_or_else(|| Error("literal element type mismatch".into())),
            Literal::Tuple(_) => Err(Error("cannot read elements of a tuple".into())),
        }
    }

    fn tuple_n(&self, n: usize) -> Result<&[Literal]> {
        match self {
            Literal::Tuple(items) if items.len() == n => Ok(items),
            Literal::Tuple(items) => Err(Error(format!(
                "expected {n}-tuple, got {}-tuple",
                items.len()
            ))),
            Literal::Array { .. } => Err(Error(format!(
                "expected {n}-tuple, got array literal"
            ))),
        }
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        let items = self.tuple_n(1)?;
        Ok(items[0].clone())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        let items = self.tuple_n(2)?;
        Ok((items[0].clone(), items[1].clone()))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        let items = self.tuple_n(3)?;
        Ok((items[0].clone(), items[1].clone(), items[2].clone()))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable(&format!("cannot parse {}", path.as_ref().display()))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
///
/// Construction succeeds (so artifact directories can be opened and their
/// manifests inspected); compiling or parsing HLO fails with the stub
/// message — the first point where the real bindings would be needed.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_accessors_check_arity() {
        let t = Literal::Tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        assert!(t.to_tuple2().is_ok());
        assert!(t.to_tuple1().is_err());
        assert!(t.to_tuple3().is_err());
        assert!(Literal::scalar(0i32).to_tuple1().is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().unwrap();
        let err = match client.compile(&XlaComputation) {
            Ok(_) => panic!("stub client must not compile"),
            Err(e) => format!("{e:?}"),
        };
        assert!(err.contains("stubbed"), "{err}");
        let err = format!("{:?}", HloModuleProto::from_text_file("x.hlo.txt").err());
        assert!(err.contains("stubbed"), "{err}");
    }
}
