//! End-to-end round benchmarks: full coordinator rounds per second across
//! engines and component breakdown (train step / attack craft / aggregate /
//! eval) — the L3 profile that drives the §Perf optimization loop.
//!
//! Run: cargo bench --bench bench_round

use rpel::attacks::AttackKind;
use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::presets;
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::model::native::{MlpSpec, TrainHyper};
use rpel::runtime::artifacts_available;
use rpel::util::rng::Rng;

fn fig1_tiny() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
    cfg.n = 30;
    cfg.b = 3;
    cfg.topology = Topology::Epidemic { s: 15 };
    cfg.bhat = Some(5);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 16;
    cfg.samples_per_node = 96;
    cfg.test_samples = 256;
    cfg.engine = EngineKind::Native;
    cfg
}

fn main() {
    let b = Bencher {
        warmup_iters: 2,
        samples: 8,
        iters_per_sample: 1,
    };

    section("full coordinator round (fig1 geometry: n=30 b=3 s=15)");
    {
        let cfg = fig1_tiny();
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round native engine", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
        println!(
            "  => {:.1} rounds/s, {:.0} model-pulls/s",
            1e9 / r.mean_ns(),
            cfg.messages_per_round() as f64 * 1e9 / r.mean_ns()
        );
        let r = b.run("evaluate all honest nodes (256-sample test set)", || {
            black_box(trainer.evaluate(0).unwrap().avg_acc)
        });
        println!("{}", r.report());
    }

    section("parallel round engine: threads sweep (n=64 b=6 s=12, mnistlike)");
    {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
        cfg.n = 64;
        cfg.b = 6;
        cfg.topology = Topology::Epidemic { s: 12 };
        cfg.bhat = Some(4);
        cfg.attack = AttackKind::Alie;
        cfg.batch = 16;
        cfg.samples_per_node = 64;
        cfg.test_samples = 128;
        cfg.engine = EngineKind::Native;
        let mut sweep: Vec<usize> = [1usize, 2, 4, 8]
            .into_iter()
            .filter(|&t| t <= avail)
            .collect();
        if !sweep.contains(&avail) {
            sweep.push(avail);
        }
        let mut baseline_ns = 0.0f64;
        for &threads in &sweep {
            cfg.threads = threads;
            let mut trainer = Trainer::from_config(&cfg).unwrap();
            let mut round = 0usize;
            let r = b.run(&format!("round n=64 threads={threads}"), || {
                round += 1;
                black_box(trainer.round(round).unwrap())
            });
            if threads == 1 {
                baseline_ns = r.mean_ns();
            }
            println!(
                "{}  [speedup vs serial: {:.2}x]",
                r.report(),
                baseline_ns / r.mean_ns()
            );
        }
        if avail == 1 {
            println!("(single-core host — speedup column is trivially 1.0x)");
        }
    }

    if artifacts_available("artifacts") {
        let mut cfg = presets::quickstart_config();
        cfg.engine = EngineKind::Hlo;
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round HLO engine (quickstart: n=8 s=7)", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
        let mut cfg = presets::quickstart_config();
        cfg.engine = EngineKind::Native;
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round native engine (quickstart: n=8 s=7)", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
    } else {
        println!("(artifacts not built — HLO round skipped)");
    }

    section("component breakdown (mnistlike arch, batch 16)");
    {
        let spec = MlpSpec::by_name("mlp_mnistlike").unwrap();
        let mut params = spec.init_native(0);
        let mut momentum = vec![0.0f32; params.len()];
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..16 * 64).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..16).map(|_| rng.index(10) as i32).collect();
        let hp = TrainHyper {
            lr: 0.1,
            beta: 0.9,
            weight_decay: 1e-4,
        };
        let mut scratch = Vec::new();
        let r = b.run_throughput("train_step (one node)", (16 * 4874) as f64, || {
            black_box(spec.train_step(&mut params, &mut momentum, &x, &y, hp, &mut scratch))
        });
        println!("{}", r.report());

        let ex: Vec<f32> = (0..256 * 64).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..256).map(|_| rng.index(10) as i32).collect();
        let r = b.run_throughput("eval forward (256 samples)", 256.0, || {
            black_box(spec.evaluate(&params, &ex, &ey))
        });
        println!("{}", r.report());
    }

    section("communication accounting: O(n log n) vs O(n^2)");
    for &n in &[100usize, 1_000, 10_000, 100_000] {
        // Lemma 4.1 log-scaling fan-in at 10% Byzantine, T=200, p=0.99
        let s = rpel::sampling::selector::lemma41_min_s(n as u64, n as u64 / 10, 200, 0.99);
        let rpel_msgs = n as u64 * s;
        let all2all = n as u64 * (n as u64 - 1);
        println!(
            "n={n:<7} s={s:<4} RPEL msgs/round={rpel_msgs:<12} all-to-all={all2all:<14} saving {:.0}x",
            all2all as f64 / rpel_msgs as f64
        );
    }
}
