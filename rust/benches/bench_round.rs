//! End-to-end round benchmarks: full coordinator rounds per second across
//! engines, the persistent-pool vs scoped-spawn dispatch comparison, a
//! (serial | pool | sharded) round sweep over n, and component breakdown
//! (train step / eval) — the L3 profile that drives the §Perf loop.
//!
//! Emits `BENCH_round.json` (ns/round for serial vs pool vs sharded at
//! n ∈ {64, 256, 1024}, plus the sparse n-sweep: dense vs virtual-node
//! backend at 1% participation for n ∈ {10³, 10⁴, 10⁵, 10⁶}, with
//! resident-bytes per point) so the perf trajectory is machine-readable
//! across PRs. Set `BENCH_SMOKE=1` for a short CI iteration (fewer
//! samples, n = 64 only; sparse sweep capped at n = 10⁴).
//!
//! Run: cargo bench --bench bench_round

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::attacks::AttackKind;
use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::presets;
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::model::native::{MlpSpec, TrainHyper};
use rpel::runtime::artifacts_available;
use rpel::util::json::Json;
use rpel::util::pool::{scoped_try_for_each, WorkerPool};
use rpel::util::rng::Rng;
use std::collections::BTreeMap;

fn fig1_tiny() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
    cfg.n = 30;
    cfg.b = 3;
    cfg.topology = Topology::Epidemic { s: 15 };
    cfg.bhat = Some(5);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 16;
    cfg.samples_per_node = 96;
    cfg.test_samples = 256;
    cfg.engine = EngineKind::Native;
    cfg
}

/// Tiny-task round geometry for the n sweep (small d: the spawn-bound
/// regime where dispatch overhead matters most).
fn sweep_cfg(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("bench_n{n}");
    cfg.n = n;
    cfg.b = n / 10;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = Some(3);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.engine = EngineKind::Native;
    cfg
}

/// Sparse-activation sweep geometry: no adversary (this sweep referees
/// throughput and residency, not robustness), participation pinned at
/// 1% so the active set scales as n/100 while dense state scales as n.
fn sparse_sweep_cfg(n: usize, virtual_nodes: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("bench_sparse_n{n}");
    cfg.n = n;
    cfg.b = 0;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.attack = AttackKind::None;
    cfg.batch = 8;
    cfg.samples_per_node = 16;
    cfg.test_samples = 32;
    cfg.engine = EngineKind::Native;
    cfg.threads = 0; // all cores
    cfg.participation = 0.01;
    cfg.virtual_nodes = virtual_nodes;
    cfg
}

/// Small per-item workload for the dispatch-overhead comparison: enough
/// work to be a realistic "one node's phase slice", little enough that
/// spawn overhead dominates a scoped dispatch.
fn phase_slice(i: usize) -> f32 {
    let mut acc = i as f32;
    for k in 0..256 {
        acc = acc * 1.0001 + k as f32 * 1e-3;
    }
    acc
}

fn round_mean_ns(b: &Bencher, label: &str, cfg: &ExperimentConfig) -> f64 {
    let mut trainer = Trainer::from_config(cfg).unwrap();
    let mut round = 0usize;
    let r = b.run(label, || {
        round += 1;
        black_box(trainer.round(round).unwrap())
    });
    println!("{}", r.report());
    r.mean_ns()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke {
        Bencher {
            warmup_iters: 1,
            samples: 2,
            iters_per_sample: 1,
        }
    } else {
        Bencher {
            warmup_iters: 2,
            samples: 8,
            iters_per_sample: 1,
        }
    };
    let avail = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = avail.min(8);

    let mut json_root: BTreeMap<String, Json> = BTreeMap::new();
    json_root.insert("bench".into(), Json::Str("bench_round".into()));
    json_root.insert("units".into(), Json::Str("ns_per_round".into()));
    json_root.insert("smoke".into(), Json::Bool(smoke));
    json_root.insert("threads".into(), Json::Num(threads as f64));

    section(&format!(
        "dispatch overhead: persistent pool vs scoped spawns (64 jobs, 3 dispatches/iter, threads={threads})"
    ));
    {
        // the spawn-bound regime the persistent pool exists for: per-item
        // work is small, so a scoped dispatch pays thread spawn + join on
        // every phase while the pool pays two channel ops per worker
        let pool = WorkerPool::new(threads);
        let mut items = vec![0.0f32; 64];
        let r_pool = b.run("persistent pool dispatch", || {
            for _ in 0..3 {
                pool.try_for_each(&mut items, |i, slot| {
                    *slot = phase_slice(i);
                    Ok(())
                })
                .unwrap();
            }
            black_box(items[0])
        });
        println!("{}", r_pool.report());
        let mut items2 = vec![0.0f32; 64];
        let r_scoped = b.run("scoped spawn dispatch (legacy)", || {
            for _ in 0..3 {
                scoped_try_for_each(&mut items2, threads, |i, slot| {
                    *slot = phase_slice(i);
                    Ok(())
                })
                .unwrap();
            }
            black_box(items2[0])
        });
        println!("{}", r_scoped.report());
        println!(
            "  => persistent pool speedup vs scoped spawns: {:.2}x",
            r_scoped.mean_ns() / r_pool.mean_ns()
        );
        let mut obj = BTreeMap::new();
        obj.insert("jobs".into(), Json::Num(64.0));
        obj.insert("dispatches_per_iter".into(), Json::Num(3.0));
        obj.insert("pool_ns".into(), Json::Num(r_pool.mean_ns()));
        obj.insert("scoped_ns".into(), Json::Num(r_scoped.mean_ns()));
        obj.insert(
            "pool_speedup".into(),
            Json::Num(r_scoped.mean_ns() / r_pool.mean_ns()),
        );
        json_root.insert("dispatch_overhead".into(), Json::Obj(obj));
    }

    section("round sweep: serial vs pool vs sharded (tiny task, s=8, alie)");
    let sweep_ns: &[usize] = if smoke { &[64] } else { &[64, 256, 1024] };
    {
        let mut rows = Vec::new();
        for &n in sweep_ns {
            let mut cfg = sweep_cfg(n);
            cfg.threads = 1;
            cfg.shards = 1;
            let serial = round_mean_ns(&b, &format!("round n={n} serial"), &cfg);
            cfg.threads = threads;
            cfg.shards = 1;
            let pool =
                round_mean_ns(&b, &format!("round n={n} pool threads={threads}"), &cfg);
            cfg.threads = threads;
            cfg.shards = 4;
            let sharded = round_mean_ns(
                &b,
                &format!("round n={n} sharded shards=4 threads={threads}"),
                &cfg,
            );
            println!(
                "  => n={n}: pool {:.2}x, sharded {:.2}x vs serial",
                serial / pool,
                serial / sharded
            );
            let mut obj = BTreeMap::new();
            obj.insert("n".into(), Json::Num(n as f64));
            obj.insert("serial_ns".into(), Json::Num(serial));
            obj.insert("pool_ns".into(), Json::Num(pool));
            obj.insert("sharded_ns".into(), Json::Num(sharded));
            obj.insert("shards".into(), Json::Num(4.0));
            rows.push(Json::Obj(obj));
        }
        json_root.insert("rounds".into(), Json::Arr(rows));
    }

    section("sparse n sweep: dense vs virtual-node backend (p=0.01, b=0)");
    let sparse_sweep: &[usize] = if smoke {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    {
        // measures a round at 1% participation both ways: the dense
        // engine still owns full n·2·d·4 resident state and O(n) table
        // scans; the virtual backend's committed state is (seed, delta
        // log) and only the touched rows materialize
        let mut rows = Vec::new();
        for &n in sparse_sweep {
            let mut obj = BTreeMap::new();
            obj.insert("n".into(), Json::Num(n as f64));
            obj.insert("participation".into(), Json::Num(0.01));
            if n <= 100_000 {
                let cfg = sparse_sweep_cfg(n, false);
                let mut t = Trainer::from_config(&cfg).unwrap();
                let mut round = 0usize;
                let r = b.run(&format!("round n={n} dense p=0.01"), || {
                    round += 1;
                    black_box(t.round(round).unwrap())
                });
                println!("{}", r.report());
                let (_, _, resident) = t.sparse_round_stats(round);
                obj.insert("dense_ns".into(), Json::Num(r.mean_ns()));
                obj.insert("dense_resident_bytes".into(), Json::Num(resident as f64));
            } else {
                // the dense table alone is gigabytes at n = 10^6 —
                // exactly the regime the virtual backend exists for
                println!("round n={n} dense p=0.01: skipped (dense state too large)");
                obj.insert("dense_ns".into(), Json::Null);
                obj.insert("dense_resident_bytes".into(), Json::Null);
            }
            {
                let cfg = sparse_sweep_cfg(n, true);
                let mut t = Trainer::from_config(&cfg).unwrap();
                let mut round = 0usize;
                let r = b.run(&format!("round n={n} virtual p=0.01"), || {
                    round += 1;
                    black_box(t.round(round).unwrap())
                });
                println!("{}", r.report());
                let (active, materialized, resident) = t.sparse_round_stats(round);
                println!(
                    "  => n={n}: active={active} materialized={materialized} resident={resident} B"
                );
                obj.insert("virtual_ns".into(), Json::Num(r.mean_ns()));
                obj.insert("virtual_resident_bytes".into(), Json::Num(resident as f64));
                obj.insert("virtual_materialized".into(), Json::Num(materialized as f64));
            }
            rows.push(Json::Obj(obj));
        }
        json_root.insert("n_sweep".into(), Json::Arr(rows));
    }

    match std::fs::write(
        "BENCH_round.json",
        Json::Obj(json_root).to_string_compact(),
    ) {
        Ok(()) => println!("\nwrote BENCH_round.json"),
        Err(e) => println!("\ncould not write BENCH_round.json: {e}"),
    }

    if smoke {
        println!("(BENCH_SMOKE set — skipping the deep-dive sections)");
        return;
    }

    section("full coordinator round (fig1 geometry: n=30 b=3 s=15)");
    {
        let cfg = fig1_tiny();
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round native engine", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
        println!(
            "  => {:.1} rounds/s, {:.0} model-pulls/s",
            1e9 / r.mean_ns(),
            cfg.messages_per_round() as f64 * 1e9 / r.mean_ns()
        );
        let r = b.run("evaluate all honest nodes (256-sample test set)", || {
            black_box(trainer.evaluate(0).unwrap().avg_acc)
        });
        println!("{}", r.report());
    }

    if artifacts_available("artifacts") {
        let mut cfg = presets::quickstart_config();
        cfg.engine = EngineKind::Hlo;
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round HLO engine (quickstart: n=8 s=7)", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
        let mut cfg = presets::quickstart_config();
        cfg.engine = EngineKind::Native;
        let mut trainer = Trainer::from_config(&cfg).unwrap();
        let mut round = 0usize;
        let r = b.run("round native engine (quickstart: n=8 s=7)", || {
            round += 1;
            black_box(trainer.round(round).unwrap())
        });
        println!("{}", r.report());
    } else {
        println!("(artifacts not built — HLO round skipped)");
    }

    section("component breakdown (mnistlike arch, batch 16)");
    {
        let spec = MlpSpec::by_name("mlp_mnistlike").unwrap();
        let mut params = spec.init_native(0);
        let mut momentum = vec![0.0f32; params.len()];
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..16 * 64).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..16).map(|_| rng.index(10) as i32).collect();
        let hp = TrainHyper {
            lr: 0.1,
            beta: 0.9,
            weight_decay: 1e-4,
        };
        let mut scratch = Vec::new();
        let r = b.run_throughput("train_step (one node)", (16 * 4874) as f64, || {
            black_box(spec.train_step(&mut params, &mut momentum, &x, &y, hp, &mut scratch))
        });
        println!("{}", r.report());

        let ex: Vec<f32> = (0..256 * 64).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..256).map(|_| rng.index(10) as i32).collect();
        let r = b.run_throughput("eval forward (256 samples)", 256.0, || {
            black_box(spec.evaluate(&params, &ex, &ey))
        });
        println!("{}", r.report());
    }

    section("communication accounting: O(n log n) vs O(n^2)");
    for &n in &[100usize, 1_000, 10_000, 100_000] {
        // Lemma 4.1 log-scaling fan-in at 10% Byzantine, T=200, p=0.99
        let s = rpel::sampling::selector::lemma41_min_s(n as u64, n as u64 / 10, 200, 0.99);
        let rpel_msgs = n as u64 * s;
        let all2all = n as u64 * (n as u64 - 1);
        println!(
            "n={n:<7} s={s:<4} RPEL msgs/round={rpel_msgs:<12} all-to-all={all2all:<14} saving {:.0}x",
            all2all as f64 / rpel_msgs as f64
        );
    }
}
