//! Aggregation fast-path benchmarks: the three layers of the hot path
//! measured against their baselines, plus the rule panel and the
//! native-vs-Pallas/HLO comparison.
//!
//! * **pairwise kernel** — naive serial subtract-square loop vs the
//!   Gram-blocked kernel (precomputed sq-norms + tile-swept dot
//!   products) at m ∈ {8, 16, 32} × d ∈ {10³, 10⁵};
//! * **round-level distance memoization** — h victims co-pulling from a
//!   shared row table, NNM∘CWTM per victim, with and without the
//!   [`DistCache`], plus the row-pair evaluation ledger
//!   (`aggregation::perf`) proving the cached path computes strictly
//!   fewer distances than the naive victims × (s+1)² bound;
//! * **trimmed-stats crossover** — insertion-sort vs selection path for
//!   the per-coordinate trimmed sum across m (the data behind
//!   `cwtm::SELECT_MIN_M`);
//! * **end-to-end** — full n=256 coordinator rounds, cache on vs off.
//!
//! Emits `BENCH_aggregation.json` (naive/blocked/cached comparison
//! points) next to `BENCH_round.json`; the CI `bench-smoke` job runs
//! `BENCH_SMOKE=1` and uploads the measured file.
//!
//! Run: cargo bench --bench bench_aggregation

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::aggregation::cwtm::{trimmed_sum_select_path, trimmed_sum_sort_path};
use rpel::aggregation::{pairwise_sqdist, perf, Aggregator, DistCache, RowCtx, RuleKind};
use rpel::attacks::AttackKind;
use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::runtime::{artifacts_available, Runtime};
use rpel::util::json::Json;
use rpel::util::rng::Rng;
use std::collections::BTreeMap;

fn random_rows(rng: &mut Rng, m: usize, d: usize) -> Vec<Vec<f32>> {
    (0..m)
        .map(|_| (0..d).map(|_| rng.gaussian32(0.0, 1.0)).collect())
        .collect()
}

/// The pre-fast-path kernel: serial subtract-and-square per pair.
fn naive_pairwise(inputs: &[&[f32]]) -> Vec<f64> {
    let m = inputs.len();
    let mut out = vec![0.0f64; m * m];
    for i in 0..m {
        for j in (i + 1)..m {
            let mut acc = 0.0f64;
            for (x, y) in inputs[i].iter().zip(inputs[j]) {
                let d = (*x as f64) - (*y as f64);
                acc += d * d;
            }
            out[i * m + j] = acc;
            out[j * m + i] = acc;
        }
    }
    out
}

/// One simulated round of the shard engine's access pattern: every
/// victim aggregates its own published row plus its pulled ones, all
/// identified for the (optional) round cache.
fn aggregate_all_victims(
    rule: &dyn Aggregator,
    rows: &[Vec<f32>],
    pulls: &[Vec<usize>],
    cache: Option<&DistCache>,
    out: &mut [f32],
) {
    for (v, pulled) in pulls.iter().enumerate() {
        let mut refs: Vec<&[f32]> = Vec::with_capacity(pulled.len() + 1);
        let mut ids: Vec<Option<u32>> = Vec::with_capacity(pulled.len() + 1);
        refs.push(rows[v].as_slice());
        ids.push(Some(v as u32));
        for &p in pulled {
            refs.push(rows[p].as_slice());
            ids.push(Some(p as u32));
        }
        let ctx = RowCtx { ids: &ids, cache };
        rule.aggregate_with_ctx(&refs, &ctx, out);
    }
}

/// Aggregation-bound round geometry: tiny model math, fat fan-in, so
/// phase 4 dominates and the cache effect is visible end-to-end.
fn round_cfg(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("bench_agg_n{n}");
    cfg.n = n;
    cfg.b = n / 10;
    cfg.topology = Topology::Epidemic { s: 16 };
    cfg.bhat = Some(5);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.engine = EngineKind::Native;
    cfg
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke {
        Bencher {
            warmup_iters: 1,
            samples: 2,
            iters_per_sample: 1,
        }
    } else {
        Bencher::default()
    };
    let mut rng = Rng::new(42);
    let mut json_root: BTreeMap<String, Json> = BTreeMap::new();
    json_root.insert("bench".into(), Json::Str("bench_aggregation".into()));
    json_root.insert("units".into(), Json::Str("ns_per_iter".into()));
    json_root.insert("smoke".into(), Json::Bool(smoke));

    section("pairwise kernel: naive serial loop vs Gram-blocked");
    {
        let mut rows_json = Vec::new();
        for &(m, d) in &[
            (8usize, 1_000usize),
            (16, 1_000),
            (32, 1_000),
            (8, 100_000),
            (16, 100_000),
            (32, 100_000),
        ] {
            let rows = random_rows(&mut rng, m, d);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let r_naive = b.run_throughput(
                &format!("naive pairwise m={m} d={d}"),
                (m * m * d) as f64,
                || black_box(naive_pairwise(&refs)),
            );
            println!("{}", r_naive.report());
            let r_blocked = b.run_throughput(
                &format!("blocked pairwise m={m} d={d}"),
                (m * m * d) as f64,
                || black_box(pairwise_sqdist(&refs)),
            );
            println!("{}", r_blocked.report());
            println!(
                "  => blocked speedup: {:.2}x",
                r_naive.mean_ns() / r_blocked.mean_ns()
            );
            let mut obj = BTreeMap::new();
            obj.insert("m".into(), Json::Num(m as f64));
            obj.insert("d".into(), Json::Num(d as f64));
            obj.insert("naive_ns".into(), Json::Num(r_naive.mean_ns()));
            obj.insert("blocked_ns".into(), Json::Num(r_blocked.mean_ns()));
            obj.insert(
                "blocked_speedup".into(),
                Json::Num(r_naive.mean_ns() / r_blocked.mean_ns()),
            );
            rows_json.push(Json::Obj(obj));
        }
        json_root.insert("pairwise".into(), Json::Arr(rows_json));
    }

    section("round-level memoization: h victims co-pulling shared rows");
    {
        // h published rows; each victim aggregates its own row plus s
        // pulled ones — the shard-engine access pattern, distilled
        let (h, s) = (64usize, 15usize);
        let mut rows_json = Vec::new();
        for &d in &[1_000usize, 100_000] {
            let rows = random_rows(&mut rng, h, if smoke && d > 1_000 { 10_000 } else { d });
            let d_eff = rows[0].len();
            let rule = RuleKind::NnmCwtm.build(5);
            let mut out = vec![0.0f32; d_eff];
            // per-victim pull sets, fixed across iterations
            let mut pull_rng = Rng::new(7);
            let pulls: Vec<Vec<usize>> = (0..h)
                .map(|v| pull_rng.sample_distinct_excluding(h, s, v))
                .collect();
            let r_uncached = b.run(&format!("{h} victims uncached d={d_eff}"), || {
                aggregate_all_victims(rule.as_ref(), &rows, &pulls, None, &mut out);
                black_box(out[0])
            });
            println!("{}", r_uncached.report());
            let r_cached = b.run(&format!("{h} victims cached d={d_eff}"), || {
                let cache = DistCache::new(); // fresh per "round"
                aggregate_all_victims(rule.as_ref(), &rows, &pulls, Some(&cache), &mut out);
                black_box(out[0])
            });
            println!("{}", r_cached.report());
            // the evaluation ledger for one cached round
            perf::reset_dist_pair_evals();
            let cache = DistCache::new();
            aggregate_all_victims(rule.as_ref(), &rows, &pulls, Some(&cache), &mut out);
            let cached_evals = perf::dist_pair_evals();
            perf::reset_dist_pair_evals();
            aggregate_all_victims(rule.as_ref(), &rows, &pulls, None, &mut out);
            let uncached_evals = perf::dist_pair_evals();
            perf::reset_dist_pair_evals();
            println!(
                "  => cached speedup {:.2}x; pair evals {cached_evals} vs {uncached_evals} \
                 (naive bound {})",
                r_uncached.mean_ns() / r_cached.mean_ns(),
                h * (s + 1) * (s + 1)
            );
            assert!(
                cached_evals < uncached_evals,
                "cache must strictly reduce pair evaluations"
            );
            let mut obj = BTreeMap::new();
            obj.insert("h".into(), Json::Num(h as f64));
            obj.insert("s".into(), Json::Num(s as f64));
            obj.insert("d".into(), Json::Num(d_eff as f64));
            obj.insert("uncached_ns".into(), Json::Num(r_uncached.mean_ns()));
            obj.insert("cached_ns".into(), Json::Num(r_cached.mean_ns()));
            obj.insert(
                "cached_speedup".into(),
                Json::Num(r_uncached.mean_ns() / r_cached.mean_ns()),
            );
            obj.insert("cached_pair_evals".into(), Json::Num(cached_evals as f64));
            obj.insert(
                "uncached_pair_evals".into(),
                Json::Num(uncached_evals as f64),
            );
            rows_json.push(Json::Obj(obj));
        }
        json_root.insert("cached".into(), Json::Arr(rows_json));
    }

    section("trimmed-stats crossover: insertion sort vs selection (b = m/4)");
    {
        let mut rows_json = Vec::new();
        let d = 4096usize;
        for &m in &[8usize, 16, 24, 32, 48, 64] {
            let cols: Vec<Vec<f32>> = (0..d)
                .map(|_| (0..m).map(|_| rng.gaussian32(0.0, 1.0)).collect())
                .collect();
            let trim = m / 4;
            let r_sort = b.run(&format!("trimmed sum sort m={m}"), || {
                let mut acc = 0.0f64;
                for col in &cols {
                    acc += trimmed_sum_sort_path(col, trim);
                }
                black_box(acc)
            });
            println!("{}", r_sort.report());
            let r_select = b.run(&format!("trimmed sum select m={m}"), || {
                let mut acc = 0.0f64;
                for col in &cols {
                    acc += trimmed_sum_select_path(col, trim);
                }
                black_box(acc)
            });
            println!("{}", r_select.report());
            let mut obj = BTreeMap::new();
            obj.insert("m".into(), Json::Num(m as f64));
            obj.insert("b".into(), Json::Num(trim as f64));
            obj.insert("coords".into(), Json::Num(d as f64));
            obj.insert("sort_ns".into(), Json::Num(r_sort.mean_ns()));
            obj.insert("select_ns".into(), Json::Num(r_select.mean_ns()));
            rows_json.push(Json::Obj(obj));
        }
        json_root.insert("trimmed".into(), Json::Arr(rows_json));
    }

    section("end-to-end: n=256 rounds, distance cache on vs off");
    {
        let n = 256usize;
        let cfg = round_cfg(n);
        let mut on = Trainer::from_config(&cfg).unwrap();
        let mut off = Trainer::from_config(&cfg).unwrap();
        off.set_dist_cache(false);
        let mut round = 0usize;
        let r_on = b.run("round n=256 cache on", || {
            round += 1;
            black_box(on.round(round).unwrap())
        });
        println!("{}", r_on.report());
        let mut round_off = 0usize;
        let r_off = b.run("round n=256 cache off", || {
            round_off += 1;
            black_box(off.round(round_off).unwrap())
        });
        println!("{}", r_off.report());
        // the acceptance ledger: one cached round computes strictly fewer
        // row-pair distances than victims × (s+1)²
        let victims = n - cfg.b;
        let s = 16usize;
        let bound = (victims * (s + 1) * (s + 1)) as u64;
        perf::reset_dist_pair_evals();
        round += 1;
        black_box(on.round(round).unwrap());
        let evals = perf::dist_pair_evals();
        perf::reset_dist_pair_evals();
        println!(
            "  => cache speedup {:.2}x; cached round pair evals {evals} < naive bound {bound}",
            r_off.mean_ns() / r_on.mean_ns()
        );
        assert!(
            evals < bound,
            "cached round computed {evals} pair distances, naive bound is {bound}"
        );
        let mut obj = BTreeMap::new();
        obj.insert("n".into(), Json::Num(n as f64));
        obj.insert("s".into(), Json::Num(s as f64));
        obj.insert("cache_on_ns".into(), Json::Num(r_on.mean_ns()));
        obj.insert("cache_off_ns".into(), Json::Num(r_off.mean_ns()));
        obj.insert(
            "cache_speedup".into(),
            Json::Num(r_off.mean_ns() / r_on.mean_ns()),
        );
        obj.insert("cached_round_pair_evals".into(), Json::Num(evals as f64));
        obj.insert("naive_pair_bound".into(), Json::Num(bound as f64));
        json_root.insert("round".into(), Json::Obj(obj));
    }

    match std::fs::write(
        "BENCH_aggregation.json",
        Json::Obj(json_root).to_string_compact(),
    ) {
        Ok(()) => println!("\nwrote BENCH_aggregation.json"),
        Err(e) => println!("\ncould not write BENCH_aggregation.json: {e}"),
    }

    if smoke {
        println!("(BENCH_SMOKE set — skipping the deep-dive sections)");
        return;
    }

    section("Definition-5.1 rules (m=16, d=4874: fig1 geometry)");
    let rows = random_rows(&mut rng, 16, 4874);
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let mut out = vec![0.0f32; 4874];
    for kind in [
        RuleKind::Mean,
        RuleKind::CwTm,
        RuleKind::CwMed,
        RuleKind::Krum,
        RuleKind::GeoMedian,
        RuleKind::NnmCwtm,
    ] {
        let rule = kind.build(7);
        let r = b.run_throughput(&format!("rule {}", kind.name()), (16 * 4874) as f64, || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
    }

    section("NNM∘CWTM across model sizes (m=16, b̂=7)");
    for &d in &[340usize, 4874, 16318, 21066, 176_050] {
        let rows = random_rows(&mut rng, 16, d);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        let rule = RuleKind::NnmCwtm.build(7);
        let r = b.run_throughput(&format!("nnm_cwtm d={d}"), (16 * d) as f64, || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
    }

    section("native vs Pallas/HLO executable (m=8, b̂=2, d=340)");
    if artifacts_available("artifacts") {
        let mut rt = Runtime::open("artifacts").unwrap();
        let exec = rt.aggregate_exec("mlp_tiny", 8, 2).unwrap();
        let rows = random_rows(&mut rng, 8, 340);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0f32; 340];
        let rule = RuleKind::NnmCwtm.build(2);
        let r = b.run("native nnm_cwtm (m=8 d=340)", || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
        let r = b.run("pallas/hlo nnm_cwtm (m=8 d=340)", || {
            black_box(exec.run(&refs).unwrap()[0])
        });
        println!("{}", r.report());
        if let Ok(exec) = rt.aggregate_exec("mlp_mnistlike", 16, 7) {
            let d = exec.entry.d;
            let rows = random_rows(&mut rng, 16, d);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let r = b.run(&format!("pallas/hlo nnm_cwtm (m=16 d={d})"), || {
                black_box(exec.run(&refs).unwrap()[0])
            });
            println!("{}", r.report());
            let rule = RuleKind::NnmCwtm.build(7);
            let mut out = vec![0.0f32; d];
            let r = b.run(&format!("native nnm_cwtm (m=16 d={d})"), || {
                rule.aggregate(&refs, &mut out);
                black_box(out[0])
            });
            println!("{}", r.report());
        }
    } else {
        println!("(artifacts not built — HLO comparison skipped; run `make artifacts`)");
    }

    section("ablation: NNM pre-aggregation cost share");
    let rows = random_rows(&mut rng, 16, 21066);
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let mut out = vec![0.0f32; 21066];
    let cwtm_only = RuleKind::CwTm.build(7);
    let nnm_cwtm = RuleKind::NnmCwtm.build(7);
    let r1 = b.run("cwtm alone (d=21066)", || {
        cwtm_only.aggregate(&refs, &mut out);
        black_box(out[0])
    });
    let r2 = b.run("nnm+cwtm (d=21066)", || {
        nnm_cwtm.aggregate(&refs, &mut out);
        black_box(out[0])
    });
    println!("{}", r1.report());
    println!("{}", r2.report());
    println!(
        "NNM overhead: {:.1}x over CWTM alone",
        r2.mean_ns() / r1.mean_ns()
    );
}
