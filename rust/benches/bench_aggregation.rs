//! Aggregation-rule benchmarks: the L3 hot path (one aggregation per
//! honest node per round) across rules, fan-ins and model sizes — plus the
//! native-vs-Pallas/HLO comparison that the §Perf log in EXPERIMENTS.md
//! tracks.
//!
//! Run: cargo bench --bench bench_aggregation

use rpel::aggregation::{pairwise_sqdist, RuleKind};
use rpel::benchkit::{black_box, section, Bencher};
use rpel::runtime::{artifacts_available, Runtime};
use rpel::util::rng::Rng;

fn random_rows(rng: &mut Rng, m: usize, d: usize) -> Vec<Vec<f32>> {
    (0..m)
        .map(|_| (0..d).map(|_| rng.gaussian32(0.0, 1.0)).collect())
        .collect()
}

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(42);

    section("pairwise squared distances (m x m over d)");
    for &(m, d) in &[(8usize, 4874usize), (16, 4874), (16, 21066), (32, 21066)] {
        let rows = random_rows(&mut rng, m, d);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let r = b.run_throughput(
            &format!("pairwise_sqdist m={m} d={d}"),
            (m * m * d) as f64,
            || black_box(pairwise_sqdist(&refs)),
        );
        println!("{}", r.report());
    }

    section("Definition-5.1 rules (m=16, d=4874: fig1 geometry)");
    let rows = random_rows(&mut rng, 16, 4874);
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let mut out = vec![0.0f32; 4874];
    for kind in [
        RuleKind::Mean,
        RuleKind::CwTm,
        RuleKind::CwMed,
        RuleKind::Krum,
        RuleKind::GeoMedian,
        RuleKind::NnmCwtm,
    ] {
        let rule = kind.build(7);
        let r = b.run_throughput(&format!("rule {}", kind.name()), (16 * 4874) as f64, || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
    }

    section("NNM∘CWTM across model sizes (m=16, b̂=7)");
    for &d in &[340usize, 4874, 16318, 21066, 176_050] {
        let rows = random_rows(&mut rng, 16, d);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        let rule = RuleKind::NnmCwtm.build(7);
        let r = b.run_throughput(&format!("nnm_cwtm d={d}"), (16 * d) as f64, || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
    }

    section("native vs Pallas/HLO executable (m=8, b̂=2, d=340)");
    if artifacts_available("artifacts") {
        let mut rt = Runtime::open("artifacts").unwrap();
        let exec = rt.aggregate_exec("mlp_tiny", 8, 2).unwrap();
        let rows = random_rows(&mut rng, 8, 340);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0f32; 340];
        let rule = RuleKind::NnmCwtm.build(2);
        let r = b.run("native nnm_cwtm (m=8 d=340)", || {
            rule.aggregate(&refs, &mut out);
            black_box(out[0])
        });
        println!("{}", r.report());
        let r = b.run("pallas/hlo nnm_cwtm (m=8 d=340)", || {
            black_box(exec.run(&refs).unwrap()[0])
        });
        println!("{}", r.report());
        if let Ok(exec) = rt.aggregate_exec("mlp_mnistlike", 16, 7) {
            let d = exec.entry.d;
            let rows = random_rows(&mut rng, 16, d);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let r = b.run(&format!("pallas/hlo nnm_cwtm (m=16 d={d})"), || {
                black_box(exec.run(&refs).unwrap()[0])
            });
            println!("{}", r.report());
            let rule = RuleKind::NnmCwtm.build(7);
            let mut out = vec![0.0f32; d];
            let r = b.run(&format!("native nnm_cwtm (m=16 d={d})"), || {
                rule.aggregate(&refs, &mut out);
                black_box(out[0])
            });
            println!("{}", r.report());
        }
    } else {
        println!("(artifacts not built — HLO comparison skipped; run `make artifacts`)");
    }

    section("ablation: NNM pre-aggregation cost share");
    let rows = random_rows(&mut rng, 16, 21066);
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let mut out = vec![0.0f32; 21066];
    let cwtm_only = RuleKind::CwTm.build(7);
    let nnm_cwtm = RuleKind::NnmCwtm.build(7);
    let r1 = b.run("cwtm alone (d=21066)", || {
        cwtm_only.aggregate(&refs, &mut out);
        black_box(out[0])
    });
    let r2 = b.run("nnm+cwtm (d=21066)", || {
        nnm_cwtm.aggregate(&refs, &mut out);
        black_box(out[0])
    });
    println!("{}", r1.report());
    println!("{}", r2.report());
    println!(
        "NNM overhead: {:.1}x over CWTM alone",
        r2.mean_ns() / r1.mean_ns()
    );
}
