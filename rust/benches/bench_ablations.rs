//! Ablation benchmarks for the design choices DESIGN.md calls out:
//!
//!  A1  local momentum on/off (paper §5.3 credits momentum for removing
//!      σ² from the non-vanishing term)
//!  A2  NNM pre-aggregation on/off (Corollary 5.7's κ = O(b̂/(s+1)) needs
//!      NNM; bare CWTM has a worse κ)
//!  A3  pull vs push epidemic communication (§3.3 / Appendix D)
//!  A4  Algorithm-2 simulated b̂ vs exact max-quantile b̂ (Appendix B
//!      Remark 2)
//!
//! These are accuracy ablations (quality, not wall-clock). Run:
//! cargo bench --bench bench_ablations

use rpel::aggregation::RuleKind;
use rpel::attacks::AttackKind;
use rpel::benchkit::section;
use rpel::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::sampling::selector::select_bhat_exact;
use rpel::sampling::EafSimulator;
use rpel::util::rng::Rng;

fn base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
    cfg.n = 20;
    cfg.b = 3;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = Some(3);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 50;
    cfg.batch = 16;
    cfg.samples_per_node = 96;
    cfg.test_samples = 256;
    cfg.eval_every = 10;
    cfg.engine = EngineKind::Native;
    cfg
}

fn final_acc(cfg: &ExperimentConfig) -> f64 {
    Trainer::from_config(cfg).unwrap().run().unwrap().final_avg_accuracy()
}

fn main() {
    section("A1: local momentum (β) under ALIE");
    for beta in [0.0f32, 0.9] {
        let mut cfg = base();
        cfg.momentum = beta;
        cfg.name = format!("momentum/beta{beta}");
        println!("beta={beta:<4} final_acc={:.3}", final_acc(&cfg));
    }

    section("A2: NNM pre-aggregation under ALIE (κ quality)");
    for (label, rule) in [
        ("cwtm alone", RuleKind::CwTm),
        ("nnm + cwtm", RuleKind::NnmCwtm),
        ("cwmed alone", RuleKind::CwMed),
        ("nnm + cwmed", RuleKind::NnmCwMed),
    ] {
        let mut cfg = base();
        cfg.rule = RuleChoice::Epidemic(rule);
        cfg.name = format!("nnm-ablation/{}", rule.name());
        println!("{label:<12} final_acc={:.3}", final_acc(&cfg));
    }

    section("A3: pull vs push epidemic (SF attack, flooding adversary)");
    for (label, topo) in [
        ("pull s=8", Topology::Epidemic { s: 8 }),
        ("push s=8", Topology::EpidemicPush { s: 8 }),
    ] {
        let mut cfg = base();
        cfg.attack = AttackKind::SignFlip;
        cfg.topology = topo;
        cfg.bhat = None;
        cfg.name = format!("pullpush/{label}");
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        println!(
            "{label:<10} final_acc={:.3} observed_b̂={} msgs/round={}",
            hist.final_avg_accuracy(),
            hist.observed_bhat(),
            hist.messages_per_round
        );
    }

    section("A4: Algorithm-2 simulated b̂ vs exact max-quantile (n=100, b=10, T=200)");
    let mut rng = Rng::new(3);
    let sim = EafSimulator::new(100, 10, 200, 5);
    println!("{:<6} {:>8} {:>8}", "s", "sim b̂", "exact b̂");
    for s in [10u64, 15, 20, 30] {
        let p = sim.point(s, &mut rng);
        let exact = select_bhat_exact(100, 10, 200, s, 0.99);
        println!("{s:<6} {:>8} {exact:>8}", p.bhat);
    }
}
