//! Async-engine overhead benchmark: what does the virtual clock cost?
//!
//! Three configurations over the same n=64 round geometry:
//!
//! * **sync** — the synchronous engine (async disabled, no schedule);
//! * **neutral async** — `quorum = h`: the async engine runs (schedule,
//!   freshness bookkeeping, ledgers) but every node is fresh every
//!   round, so this prices the pure engine overhead against sync;
//! * **straggler async** — two-point stragglers + churn + bounded
//!   staleness: the working regime, including carry/decay serves.
//!
//! Emits the `timing` section of `BENCH_async.json` (the `sweep`
//! section belongs to `examples/async_jungle.rs`); the CI `bench-smoke`
//! job runs `BENCH_SMOKE=1` and uploads the measured file.
//!
//! Run: cargo bench --bench bench_async

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::attacks::AttackKind;
use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::{AsyncCfg, EngineKind, ExperimentConfig, StragglerKind, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::util::json::Json;
use std::collections::BTreeMap;

const N: usize = 64;

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = name.into();
    cfg.n = N;
    cfg.b = N / 10;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = Some(3);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 1_000_000; // never: rounds only
    cfg.engine = EngineKind::Native;
    cfg
}

fn round_mean_ns(b: &Bencher, label: &str, cfg: &ExperimentConfig) -> f64 {
    let mut trainer = Trainer::from_config(cfg).unwrap();
    let mut round = 0usize;
    let r = b.run(label, || {
        round += 1;
        black_box(trainer.round(round).unwrap())
    });
    println!("{}", r.report());
    r.mean_ns()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke {
        Bencher {
            warmup_iters: 1,
            samples: 2,
            iters_per_sample: 1,
        }
    } else {
        Bencher {
            warmup_iters: 2,
            samples: 8,
            iters_per_sample: 1,
        }
    };
    let h = N - N / 10;

    let mut json_root: BTreeMap<String, Json> = BTreeMap::new();
    json_root.insert("bench".into(), Json::Str("bench_async".into()));
    json_root.insert("produced_by".into(), Json::Str("rust/benches/bench_async".into()));
    json_root.insert("units".into(), Json::Str("ns_per_round".into()));
    json_root.insert("smoke".into(), Json::Bool(smoke));
    json_root.insert("sweep".into(), Json::Null); // async_jungle fills this

    section(&format!(
        "async engine overhead (n={N}, s=8, alie, native engine)"
    ));

    let sync_ns = round_mean_ns(&b, "sync round", &base_cfg("bench_async_sync"));

    let mut neutral = base_cfg("bench_async_neutral");
    neutral.asyn.quorum = h;
    let neutral_ns = round_mean_ns(&b, "neutral async round (quorum = h)", &neutral);

    let mut straggler = base_cfg("bench_async_straggler");
    straggler.asyn = AsyncCfg {
        quorum: h * 3 / 4,
        max_staleness: 2,
        straggler: StragglerKind::TwoPoint,
        slow_prob: 0.2,
        slow_latency: 4.0,
        crash_prob: 0.05,
        down_rounds: 2,
        ..AsyncCfg::default()
    };
    let straggler_ns = round_mean_ns(&b, "straggler async round (q = 3h/4)", &straggler);

    println!(
        "  => neutral overhead {:.2}x, straggler {:.2}x vs sync",
        neutral_ns / sync_ns,
        straggler_ns / sync_ns
    );

    let mut timing = BTreeMap::new();
    timing.insert("n".into(), Json::Num(N as f64));
    timing.insert("s".into(), Json::Num(8.0));
    timing.insert("sync_ns".into(), Json::Num(sync_ns));
    timing.insert("neutral_async_ns".into(), Json::Num(neutral_ns));
    timing.insert("straggler_async_ns".into(), Json::Num(straggler_ns));
    timing.insert("neutral_overhead".into(), Json::Num(neutral_ns / sync_ns));
    timing.insert(
        "straggler_overhead".into(),
        Json::Num(straggler_ns / sync_ns),
    );
    json_root.insert("timing".into(), Json::Obj(timing));

    match std::fs::write("BENCH_async.json", Json::Obj(json_root).to_string_compact()) {
        Ok(()) => println!("\nwrote BENCH_async.json"),
        Err(e) => println!("\ncould not write BENCH_async.json: {e}"),
    }
}
