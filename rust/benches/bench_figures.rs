//! Figure-level benchmarks: time every paper figure's regeneration at tiny
//! scale and assert the communication accounting each figure's caption
//! relies on. The `make bench` roll-up that EXPERIMENTS.md references.
//!
//! Run: cargo bench --bench bench_figures
//! (full training figures at bench scale — a few minutes on one core)

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::benchkit::section;
use rpel::config::presets::{self, FigureSeries, Scale};
use rpel::config::EngineKind;
use rpel::coordinator::Trainer;
use rpel::experiments;
use std::time::Instant;

fn main() {
    // bench scale: headline figures in full, appendix figures truncated
    let headline = ["fig1L", "fig1R", "fig2L", "fig2R", "fig3"];

    section("headline figures (full tiny-scale regeneration)");
    for id in headline {
        let fig = presets::figure(id).unwrap();
        let t0 = Instant::now();
        match fig.series(Scale::Tiny) {
            FigureSeries::Training(cfgs) => {
                let mut final_accs = Vec::new();
                let mut msgs = 0usize;
                for cfg in &cfgs {
                    let hist = Trainer::from_config(cfg).unwrap().run().unwrap();
                    msgs = hist.messages_per_round;
                    final_accs.push(format!(
                        "{}={:.2}",
                        cfg.attack.name(),
                        hist.final_avg_accuracy()
                    ));
                }
                println!(
                    "{:<7} {:>8.2}s  msgs/round={:<6} [{}]",
                    id,
                    t0.elapsed().as_secs_f64(),
                    msgs,
                    final_accs.join(" ")
                );
            }
            FigureSeries::Eaf(scens) => {
                let rows = experiments::run_eaf(&scens, 1);
                println!(
                    "{:<7} {:>8.2}s  ({} grid points, max n=100k)",
                    id,
                    t0.elapsed().as_secs_f64(),
                    rows.len()
                );
            }
        }
    }

    section("appendix figures (first series, truncated rounds)");
    for fig in presets::all_figures() {
        if headline.contains(&fig.id) {
            continue;
        }
        if let FigureSeries::Training(mut cfgs) = fig.series(Scale::Tiny) {
            let cfg = &mut cfgs[0];
            cfg.rounds = cfg.rounds.min(20);
            cfg.engine = EngineKind::Native;
            let t0 = Instant::now();
            let hist = Trainer::from_config(cfg).unwrap().run().unwrap();
            println!(
                "{:<7} {:>8.2}s/20r  first-series acc={:.2}  msgs/round={}",
                fig.id,
                t0.elapsed().as_secs_f64(),
                hist.final_avg_accuracy(),
                hist.messages_per_round
            );
        }
    }

    section("budget table: every figure's messages/round (paper scale)");
    for fig in presets::all_figures() {
        if let FigureSeries::Training(cfgs) = fig.series(Scale::Paper) {
            let budgets: std::collections::BTreeSet<usize> =
                cfgs.iter().map(|c| c.messages_per_round()).collect();
            println!(
                "{:<7} series={:<3} msgs/round={:?}",
                fig.id,
                cfgs.len(),
                budgets
            );
        }
    }
}
