//! Sampling-substrate benchmarks: hypergeometric draws (the Figure-3
//! engine pulls tens of millions), the EAF/Algorithm-2 selection, and the
//! per-round epidemic pull sampler.
//!
//! Run: cargo bench --bench bench_sampling

use rpel::benchkit::{black_box, section, Bencher};
use rpel::coordinator::PullSampler;
use rpel::sampling::{simulate_bhat_max, EafSimulator, Hypergeometric};
use rpel::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(7);

    section("hypergeometric sampling");
    let hg_small = Hypergeometric::new(99, 10, 15);
    let r = b.run_throughput("HG(99,10,15) table-inversion x10k", 10_000.0, || {
        let mut acc = 0u64;
        for _ in 0..10_000 {
            acc += hg_small.sample(&mut rng);
        }
        black_box(acc)
    });
    println!("{}", r.report());
    let r = b.run_throughput("HG(99,10,15) sequential-urn x10k", 10_000.0, || {
        let mut acc = 0u64;
        for _ in 0..10_000 {
            acc += rng.hypergeometric(99, 10, 15);
        }
        black_box(acc)
    });
    println!("{}", r.report());
    let hg_big = Hypergeometric::new(99_999, 10_000, 30);
    let r = b.run_throughput("HG(99999,10000,30) table-inversion x10k", 10_000.0, || {
        let mut acc = 0u64;
        for _ in 0..10_000 {
            acc += hg_big.sample(&mut rng);
        }
        black_box(acc)
    });
    println!("{}", r.report());

    section("distribution construction (log-gamma CDF table)");
    let r = b.run("Hypergeometric::new(99999,10000,30)", || {
        black_box(Hypergeometric::new(99_999, 10_000, 30))
    });
    println!("{}", r.report());

    section("Algorithm 2 / Figure 3 grid points");
    let r = b.run("b̂-max draw: |H|·T = 18k (n=100 setting)", || {
        black_box(simulate_bhat_max(&hg_small, 90 * 200, &mut rng))
    });
    println!("{}", r.report());
    let quick = Bencher::quick();
    let sim = EafSimulator::new(100_000, 10_000, 200, 5);
    let r = quick.run("fig3 point: n=100k b=10k s=30 (5 sims)", || {
        black_box(sim.point(30, &mut rng).bhat)
    });
    println!("{}", r.report());

    section("epidemic pull sampler (per-round cost is n samples)");
    for &(n, s) in &[(100usize, 15usize), (1_000, 30), (100_000, 30)] {
        let sampler = PullSampler::new(n, s);
        let r = b.run_throughput(&format!("pull n={n} s={s} x1k victims"), 1_000.0, || {
            let mut acc = 0usize;
            for v in 0..1_000 {
                acc += sampler.sample(v % n, &mut rng).len();
            }
            black_box(acc)
        });
        println!("{}", r.report());
    }
}
