//! Wire-codec benchmark: what does the bandwidth diet cost in CPU, and
//! what does it save in bytes?
//!
//! Two layers over the same synthetic model geometry:
//!
//! * **codec** — `encode_rows` (publish-point transform) and the full
//!   frame decode (`decode_peer_c` on a `PullReply` block) per
//!   compression level, at a model-sized row block;
//! * **round** — in-process training rounds with the publish-point
//!   transform on (`none` vs `f16` vs `q8`), pricing the codec against
//!   the whole round path.
//!
//! Emits `BENCH_wire.json`; the CI `bench-smoke` job runs
//! `BENCH_SMOKE=1` and uploads the measured file.
//!
//! Run: cargo bench --bench bench_wire

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::attacks::AttackKind;
use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::util::json::Json;
use rpel::wire::codec::{self, Compression, RowCodec};
use rpel::wire::proto;
use std::collections::BTreeMap;

const LEVELS: [Compression; 3] = [Compression::None, Compression::F16, Compression::Q8];

/// Deterministic synthetic block: a reference vector plus rows a small
/// delta away from it — the regime the delta codec is built for.
fn synth_rows(rows: usize, d: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    let reference: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
    let table: Vec<Vec<f32>> = (0..rows)
        .map(|r| {
            (0..d)
                .map(|i| reference[i] + ((r * d + i) as f32 * 0.11).cos() * 0.05)
                .collect()
        })
        .collect();
    (reference, table)
}

fn base_cfg(name: &str, comp: Compression) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = name.into();
    cfg.n = 24;
    cfg.b = 3;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 1_000_000; // never: rounds only
    cfg.engine = EngineKind::Native;
    cfg.compression = comp;
    cfg
}

fn round_mean_ns(b: &Bencher, label: &str, cfg: &ExperimentConfig) -> f64 {
    let mut trainer = Trainer::from_config(cfg).unwrap();
    let mut round = 0usize;
    let r = b.run(label, || {
        round += 1;
        black_box(trainer.round(round).unwrap())
    });
    println!("{}", r.report());
    r.mean_ns()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke {
        Bencher {
            warmup_iters: 1,
            samples: 2,
            iters_per_sample: 1,
        }
    } else {
        Bencher {
            warmup_iters: 2,
            samples: 8,
            iters_per_sample: 1,
        }
    };
    let (rows, d) = if smoke { (8usize, 256usize) } else { (64, 4096) };

    let mut json_root: BTreeMap<String, Json> = BTreeMap::new();
    json_root.insert("bench".into(), Json::Str("bench_wire".into()));
    json_root.insert("produced_by".into(), Json::Str("rust/benches/bench_wire".into()));
    json_root.insert("units".into(), Json::Str("ns_per_op".into()));
    json_root.insert("smoke".into(), Json::Bool(smoke));

    section(&format!("row codec ({rows} rows x d={d})"));
    let (reference, table) = synth_rows(rows, d);

    let mut timing = BTreeMap::new();
    timing.insert("rows".into(), Json::Num(rows as f64));
    timing.insert("d".into(), Json::Num(d as f64));
    for comp in LEVELS {
        let rc = RowCodec::new(comp, &reference);
        let enc = b.run(&format!("{} encode_rows", comp.name()), || {
            black_box(codec::encode_rows(&rc, &table))
        });
        println!("{}", enc.report());
        let block = codec::encode_rows(&rc, &table);
        let frame = proto::encode_pull_reply_block(1, &block);
        let dec = b.run(&format!("{} frame decode", comp.name()), || {
            black_box(proto::decode_peer_c(&frame, &rc).unwrap())
        });
        println!("{}", dec.report());
        println!(
            "  => {}: {} bytes/row ({}x vs raw)",
            comp.name(),
            comp.stride(d),
            (4 * d) as f64 / comp.stride(d) as f64
        );
        timing.insert(format!("{}_encode_ns", comp.name()), Json::Num(enc.mean_ns()));
        timing.insert(format!("{}_decode_ns", comp.name()), Json::Num(dec.mean_ns()));
        timing.insert(
            format!("{}_bytes_per_row", comp.name()),
            Json::Num(comp.stride(d) as f64),
        );
    }
    json_root.insert("timing".into(), Json::Obj(timing));

    section("in-process round with publish-point transform (n=24, s=6, alie)");
    let mut rounds = BTreeMap::new();
    let mut none_ns = 0f64;
    for comp in LEVELS {
        let ns = round_mean_ns(
            &b,
            &format!("{} round", comp.name()),
            &base_cfg(&format!("bench_wire_{}", comp.name()), comp),
        );
        if comp.is_none() {
            none_ns = ns;
        } else {
            println!("  => {} overhead {:.2}x vs none", comp.name(), ns / none_ns);
        }
        rounds.insert(format!("{}_round_ns", comp.name()), Json::Num(ns));
    }
    json_root.insert("round".into(), Json::Obj(rounds));

    match std::fs::write("BENCH_wire.json", Json::Obj(json_root).to_string_compact()) {
        Ok(()) => println!("\nwrote BENCH_wire.json"),
        Err(e) => println!("\ncould not write BENCH_wire.json: {e}"),
    }
}
