//! Crash-recovery benchmark: what does durability cost per round
//! boundary?
//!
//! Times the durable-checkpoint path — `write_checkpoint` (serialize +
//! checksum + atomic rename) and `read_checkpoint` (validate + decode)
//! — on synthetic boundary states at n ∈ {64, 1024} honest nodes, and
//! reports the file size alongside, since the checkpoint's byte
//! footprint is the other half of the durability price.
//!
//! Emits `BENCH_recovery.json`; the CI `bench-smoke` job runs
//! `BENCH_SMOKE=1` and uploads the measured file.
//!
//! Run: cargo bench --bench bench_recovery

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::benchkit::{black_box, section, Bencher};
use rpel::config::file::to_toml_str;
use rpel::config::ExperimentConfig;
use rpel::coordinator::checkpoint::{read_checkpoint, write_checkpoint, BoundaryState};
use rpel::data::TaskKind;
use rpel::metrics::History;
use rpel::util::json::Json;
use std::collections::BTreeMap;

/// Deterministic synthetic boundary state: h honest rows of width d,
/// carried rows on the odd indices (the mixed dense/absent shape the
/// sparse serializer sees in practice).
fn synth_state(h: usize, d: usize) -> BoundaryState {
    let wire_ref: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
    let params: Vec<Vec<f32>> = (0..h)
        .map(|r| (0..d).map(|i| ((r * d + i) as f32 * 0.11).cos()).collect())
        .collect();
    let momentum: Vec<Vec<f32>> = (0..h)
        .map(|r| (0..d).map(|i| ((r * d + i) as f32 * 0.07).sin() * 0.1).collect())
        .collect();
    let carried: Vec<Option<Vec<f32>>> = (0..h)
        .map(|r| (r % 2 == 1).then(|| vec![0.5f32; d]))
        .collect();
    BoundaryState {
        round: 5,
        wire_ref,
        params,
        momentum,
        carried,
        vclock: None,
    }
}

/// A few rounds of plausible ledger history, so the embedded `History`
/// block is exercised too.
fn synth_hist(rounds: usize) -> History {
    let mut h = History::new("bench_recovery", 100);
    for r in 0..rounds {
        h.train_loss.push(1.0 / (r + 1) as f64);
        h.observed_byz_max.push(0);
        h.delivered_per_round.push(100);
        h.worker_restarts_per_round.push(0);
        h.peer_retries_per_round.push(0);
        h.checkpoint_bytes_per_round.push(0);
    }
    h
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke {
        Bencher {
            warmup_iters: 1,
            samples: 2,
            iters_per_sample: 1,
        }
    } else {
        Bencher {
            warmup_iters: 2,
            samples: 8,
            iters_per_sample: 3,
        }
    };
    let d = if smoke { 64usize } else { 256 };

    let mut json_root: BTreeMap<String, Json> = BTreeMap::new();
    json_root.insert("bench".into(), Json::Str("bench_recovery".into()));
    json_root.insert(
        "produced_by".into(),
        Json::Str("rust/benches/bench_recovery".into()),
    );
    json_root.insert("units".into(), Json::Str("ns_per_op".into()));
    json_root.insert("smoke".into(), Json::Bool(smoke));

    let dir = std::env::temp_dir().join(format!("rpel-bench-recovery-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut timing = BTreeMap::new();
    timing.insert("d".into(), Json::Num(d as f64));
    for h in [64usize, 1024] {
        section(&format!("checkpoint at n={h} honest nodes (d={d})"));
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.name = format!("bench_recovery_{h}");
        cfg.n = h;
        cfg.b = 0;
        let toml = to_toml_str(&cfg);
        let state = synth_state(h, d);
        let hist = synth_hist(8);

        let bytes = write_checkpoint(&dir, &toml, &state, &hist).unwrap();
        let write = b.run(&format!("n={h} write_checkpoint"), || {
            black_box(write_checkpoint(&dir, &toml, &state, &hist).unwrap())
        });
        println!("{}", write.report());
        let read = b.run(&format!("n={h} read_checkpoint"), || {
            black_box(read_checkpoint(&dir).unwrap())
        });
        println!("{}", read.report());
        println!(
            "  => n={h}: {bytes} bytes on disk ({:.1} bytes per model row)",
            bytes as f64 / h as f64
        );

        timing.insert(format!("n{h}_write_ns"), Json::Num(write.mean_ns()));
        timing.insert(format!("n{h}_read_ns"), Json::Num(read.mean_ns()));
        timing.insert(format!("n{h}_bytes"), Json::Num(bytes as f64));
    }
    json_root.insert("timing".into(), Json::Obj(timing));

    std::fs::remove_dir_all(&dir).ok();
    match std::fs::write("BENCH_recovery.json", Json::Obj(json_root).to_string_compact()) {
        Ok(()) => println!("\nwrote BENCH_recovery.json"),
        Err(e) => println!("\ncould not write BENCH_recovery.json: {e}"),
    }
}
