//! `rpel` — the RPEL coordinator CLI (leader entrypoint).
//!
//! Commands:
//!   train        — run one training config (TOML file or built-in preset)
//!   figure       — regenerate a paper figure (fig1L..fig21, fig3 = EAF sim)
//!   eaf          — Effective-adversarial-fraction simulation (Algorithm 2 core)
//!   select       — Algorithm 2 hyper-parameter selection for (s, b̂)
//!   list         — figures, presets (Tables 1–2), and artifact inventory
//!   check        — verify the AOT artifact directory loads and executes
//!   shard-worker — host one honest shard for a `--procs N` coordinator
//!                  (spawned internally; speaks the wire protocol on
//!                  stdin/stdout)

use rpel::cli::Args;
use rpel::config::presets::{self, Scale};
use rpel::config::{file as config_file, EngineKind, StalePolicyKind, StragglerKind, TransportKind};
use rpel::testkit::scenario::Scenario;
use rpel::experiments;
use rpel::metrics::write_histories;
use rpel::sampling::select_params;
use rpel::util::rng::Rng;

const USAGE: &str = "\
rpel — Robust Pull-based Epidemic Learning (paper reproduction CLI)

USAGE:
  rpel train  (--config <file.toml> | --preset <figure-id[:idx]>)
              [--engine hlo|native] [--out results] [--seed N] [--rounds N]
              [--threads N]   (0 = all cores, 1 = serial; same results)
              [--shards N]    (node-shard partitions, default 1; same results)
              [--procs N]     (shard worker processes, default 1; same results)
              [--transport pipe|socket|tcp]  (worker wire; same results.
                socket/tcp = worker-served pulls, no O(h·d) table broadcast)
              [--compression none|f16|q8]  (row-block wire codec; a modeled
                knob — any fixed level is bit-identical across the
                transport/procs/shards/threads grid)
              [--socket-dir DIR]  (unix-socket directory; default temp)
              [--scenario NAME]   (named [async] scenario: straggler_twopoint|
                straggler_lognormal|crash_recover|partition_heal)
              [--quorum N] [--deadline T] [--max-staleness K]
              [--stale-policy carry|decay] [--stale-decay L]
              [--straggler constant|two_point|lognormal]
              [--crash-prob P] [--down-rounds N]
                (async round engine on a deterministic virtual clock;
                 quorum = honest count reproduces synchronous runs)
              [--participation P]  (per-round active fraction in (0,1],
                sampled on the PARTICIPATE stream; 1.0 = everyone)
              [--virtual-nodes]    (sparse backend: committed state as
                seed + delta log, lazy per-round materialization;
                procs = 1, epidemic pull only)
              [--checkpoint-dir DIR]  (durable round checkpoints: atomic
                checksummed boundary snapshots; resume is bit-identical
                to the straight-through run)
              [--checkpoint-every K]  (checkpoint cadence in rounds;
                default 1)
              [--max-worker-restarts N]  (supervised worker respawn
                budget per worker, procs > 1; 0 = crashes are fatal)
  rpel train  --resume <checkpoint-dir>   [--out results]
              (continue a checkpointed run; the config is embedded in
               the checkpoint, so no --config/--preset is needed)
  rpel figure --id <fig1L|fig1R|...|fig21|all> [--scale tiny|paper]
              [--engine hlo|native] [--out results] [--threads N] [--shards N]
              [--procs N] [--transport pipe|socket|tcp]
  rpel eaf    --n <N> --b <B> [--t 200] [--sims 5] --grid 5,10,15,...
  rpel select --n <N> --b <B> [--t 200] [--q 0.49] [--sims 5]
              [--grid 2,...,n-1] [--exact] [--p 0.99]
  rpel list   [--presets] [--artifacts <dir>]
  rpel check  [--artifacts <dir>]
  rpel lint   [--json] [path]   (determinism & panic-safety static analysis
              over rust/src; nonzero exit on findings. See rpel::analysis.)

Run `make artifacts` before using --engine hlo (the default for check).
";

fn main() {
    env_logger_lite();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("figure") => cmd_figure(&args),
        Some("eaf") => cmd_eaf(&args),
        Some("select") => cmd_select(&args),
        Some("list") => cmd_list(&args),
        Some("check") => cmd_check(&args),
        Some("lint") => cmd_lint(&args),
        Some("shard-worker") => cmd_shard_worker(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command '{other}'").into()),
    }
    .map(|_| 0)
    .unwrap_or_else(|e: Box<dyn std::error::Error>| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

type CmdResult = Result<(), Box<dyn std::error::Error>>;

fn engine_override(args: &Args) -> Result<Option<EngineKind>, String> {
    match args.get("engine") {
        None => Ok(None),
        Some(e) => EngineKind::parse(e)
            .map(Some)
            .ok_or_else(|| format!("unknown engine '{e}'")),
    }
}

fn transport_override(args: &Args) -> Result<Option<TransportKind>, String> {
    match args.get("transport") {
        None => Ok(None),
        Some(t) => TransportKind::parse(t)
            .map(Some)
            .ok_or_else(|| format!("unknown transport '{t}' (pipe|socket|tcp)")),
    }
}

fn cmd_train(args: &Args) -> CmdResult {
    args.check_known(&[
        "config",
        "preset",
        "engine",
        "out",
        "seed",
        "rounds",
        "threads",
        "shards",
        "procs",
        "transport",
        "socket-dir",
        "compression",
        "scenario",
        "quorum",
        "deadline",
        "max-staleness",
        "stale-policy",
        "stale-decay",
        "straggler",
        "crash-prob",
        "down-rounds",
        "participation",
        "virtual-nodes",
        "checkpoint-dir",
        "checkpoint-every",
        "max-worker-restarts",
        "resume",
    ])?;
    if let Some(dir) = args.get("resume") {
        let hist = experiments::resume_training(dir)?;
        let out = args.get_or("out", "results");
        let paths = write_histories(&format!("{out}/train"), &[hist])?;
        println!("wrote {}", paths.join(", "));
        return Ok(());
    }
    let mut cfg = if let Some(path) = args.get("config") {
        config_file::load(path)?
    } else if let Some(preset) = args.get("preset") {
        let (id, idx) = match preset.split_once(':') {
            Some((id, idx)) => (id, idx.parse::<usize>().map_err(|_| "bad preset index")?),
            None => (preset, 0),
        };
        if id == "quickstart" {
            presets::quickstart_config()
        } else {
            let fig = presets::figure(id).ok_or(format!("unknown preset '{id}'"))?;
            match fig.series(Scale::Tiny) {
                presets::FigureSeries::Training(cfgs) => cfgs
                    .into_iter()
                    .nth(idx)
                    .ok_or(format!("preset index {idx} out of range"))?,
                presets::FigureSeries::Eaf(_) => {
                    return Err("fig3 is a simulation; use `rpel figure --id fig3`".into())
                }
            }
        }
    } else {
        return Err("train needs --config or --preset".into());
    };
    if let Some(engine) = engine_override(args)? {
        cfg.engine = engine;
    }
    if let Some(seed) = args.get_usize("seed")? {
        cfg.seed = seed as u64;
    }
    if let Some(rounds) = args.get_usize("rounds")? {
        cfg.rounds = rounds;
    }
    if let Some(threads) = args.get_usize("threads")? {
        cfg.threads = threads;
    }
    if let Some(shards) = args.get_usize("shards")? {
        cfg.shards = shards;
    }
    if let Some(procs) = args.get_usize("procs")? {
        cfg.procs = procs;
    }
    if let Some(transport) = transport_override(args)? {
        cfg.transport = transport;
    }
    if let Some(dir) = args.get("socket-dir") {
        cfg.socket_dir = dir.to_string();
    }
    if let Some(c) = args.get("compression") {
        cfg.compression = rpel::config::Compression::parse(c)
            .ok_or_else(|| format!("unknown compression '{c}' (none|f16|q8)"))?;
    }
    apply_async_flags(args, &mut cfg)?;
    let mut recovery_touched = false;
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.recovery.checkpoint_dir = dir.to_string();
        recovery_touched = true;
    }
    if let Some(k) = args.get_usize("checkpoint-every")? {
        cfg.recovery.checkpoint_every = k;
        recovery_touched = true;
    }
    if let Some(n) = args.get_usize("max-worker-restarts")? {
        cfg.recovery.max_worker_restarts = n;
        recovery_touched = true;
    }
    if recovery_touched {
        cfg.validate()?;
    }
    let mut sparse_touched = false;
    if let Some(p) = args.get_f64("participation")? {
        cfg.participation = p;
        sparse_touched = true;
    }
    if args.has("virtual-nodes") {
        cfg.virtual_nodes = true;
        sparse_touched = true;
    }
    if sparse_touched {
        cfg.validate()?;
    }
    let hist = experiments::run_training(&cfg)?;
    let out = args.get_or("out", "results");
    let paths = write_histories(&format!("{out}/train"), &[hist])?;
    println!("wrote {}", paths.join(", "));
    Ok(())
}

/// Apply the async round-engine flags: a named `--scenario` first (a
/// whole `[async]` section at once), then per-knob overrides on top.
/// Re-validates the combined config whenever anything async changed.
fn apply_async_flags(args: &Args, cfg: &mut rpel::config::ExperimentConfig) -> CmdResult {
    let mut touched = false;
    if let Some(name) = args.get("scenario") {
        let scenario = Scenario::named(name).ok_or_else(|| {
            format!(
                "unknown scenario '{name}' (try straggler_twopoint|\
                 straggler_lognormal|crash_recover|partition_heal)"
            )
        })?;
        cfg.asyn = scenario.asyn;
        touched = true;
    }
    if let Some(q) = args.get_usize("quorum")? {
        cfg.asyn.quorum = q;
        touched = true;
    }
    if let Some(t) = args.get_f64("deadline")? {
        cfg.asyn.deadline = t;
        touched = true;
    }
    if let Some(k) = args.get_usize("max-staleness")? {
        cfg.asyn.max_staleness = k;
        touched = true;
    }
    if let Some(p) = args.get("stale-policy") {
        cfg.asyn.stale_policy = StalePolicyKind::parse(p)
            .ok_or_else(|| format!("unknown stale policy '{p}' (carry|decay)"))?;
        touched = true;
    }
    if let Some(l) = args.get_f64("stale-decay")? {
        cfg.asyn.stale_decay = l;
        touched = true;
    }
    if let Some(s) = args.get("straggler") {
        cfg.asyn.straggler = StragglerKind::parse(s).ok_or_else(|| {
            format!("unknown straggler kind '{s}' (constant|two_point|lognormal)")
        })?;
        touched = true;
    }
    if let Some(p) = args.get_f64("crash-prob")? {
        cfg.asyn.crash_prob = p;
        touched = true;
    }
    if let Some(r) = args.get_usize("down-rounds")? {
        cfg.asyn.down_rounds = r;
        touched = true;
    }
    if touched {
        cfg.validate()?;
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> CmdResult {
    args.check_known(&[
        "id", "scale", "engine", "out", "threads", "shards", "procs", "transport",
    ])?;
    let id = args.get("id").ok_or("figure needs --id")?;
    let scale =
        Scale::parse(args.get_or("scale", "tiny")).ok_or("scale must be tiny|paper")?;
    let engine = engine_override(args)?;
    let threads = args.get_usize("threads")?;
    let shards = args.get_usize("shards")?;
    let procs = args.get_usize("procs")?;
    let transport = transport_override(args)?;
    let out = args.get_or("out", "results");
    let figs: Vec<_> = if id == "all" {
        presets::all_figures().to_vec()
    } else {
        vec![presets::figure(id)
            .ok_or_else(|| format!("unknown figure '{id}' (try `rpel list`)"))?]
    };
    for fig in figs {
        let outcome = experiments::run_figure(
            &fig, scale, engine, threads, shards, procs, transport, out,
        )?;
        println!("\n{}", experiments::summary_table(&outcome));
        println!("csv: {}\n", outcome.csv_paths.join(", "));
    }
    Ok(())
}

fn cmd_eaf(args: &Args) -> CmdResult {
    args.check_known(&["n", "b", "t", "sims", "grid", "seed"])?;
    let n = args.get_usize("n")?.ok_or("--n required")? as u64;
    let b = args.get_usize("b")?.ok_or("--b required")? as u64;
    let t = args.get_usize("t")?.unwrap_or(200) as u64;
    let sims = args.get_usize("sims")?.unwrap_or(5);
    let grid = args
        .get_u64_list("grid")?
        .ok_or("--grid required (e.g. 5,10,15)")?;
    experiments::run_eaf(
        &[presets::EafScenario {
            label: format!("n={n}, b={b}"),
            n,
            b,
            t,
            grid,
            sims,
        }],
        args.get_usize("seed")?.unwrap_or(2025) as u64,
    );
    Ok(())
}

fn cmd_select(args: &Args) -> CmdResult {
    args.check_known(&["n", "b", "t", "q", "sims", "grid", "exact", "p"])?;
    let n = args.get_usize("n")?.ok_or("--n required")? as u64;
    let b = args.get_usize("b")?.ok_or("--b required")? as u64;
    let t = args.get_usize("t")?.unwrap_or(200) as u64;
    let q = args.get_f64("q")?.unwrap_or(0.49);
    let sims = args.get_usize("sims")?.unwrap_or(5);
    let grid = args
        .get_u64_list("grid")?
        .unwrap_or_else(|| (1..n).collect());
    if args.has("exact") {
        let p = args.get_f64("p")?.unwrap_or(0.99);
        for &s in &grid {
            if s == 0 || s >= n {
                continue;
            }
            let bhat = rpel::sampling::selector::select_bhat_exact(n, b, t, s, p);
            let eaf = bhat as f64 / (s + 1) as f64;
            let mark = if eaf <= q { "  <= q ✓" } else { "" };
            println!("s={s:<5} b̂={bhat:<4} EAF={eaf:.3}{mark}");
            if eaf <= q {
                return Ok(());
            }
        }
        return Err(format!("no s in grid reaches EAF <= {q}").into());
    }
    let mut rng = Rng::new(2025);
    match select_params(n, b, t, &grid, sims, q, &mut rng) {
        Some(sel) => {
            println!(
                "Algorithm 2 selection: s={} b̂={} EAF={:.3} (target q={q})",
                sel.s, sel.bhat, sel.eaf
            );
            if b > 0 && b < n / 2 {
                let s41 = rpel::sampling::selector::lemma41_min_s(n, b, t, 0.99);
                println!("Lemma 4.1 sufficient bound (p=0.99): s >= {s41}");
            }
            Ok(())
        }
        None => Err(format!("no s in grid reaches EAF <= {q}").into()),
    }
}

fn cmd_list(args: &Args) -> CmdResult {
    args.check_known(&["presets", "artifacts"])?;
    println!("figures:");
    for f in presets::all_figures() {
        println!("  {:<7} {}", f.id, f.title);
    }
    if args.has("presets") {
        println!("\npreset hyper-parameters (paper Tables 1–2, paper scale):");
        for id in ["fig1L", "fig2L", "fig20"] {
            let fig = presets::figure(id).unwrap();
            if let presets::FigureSeries::Training(cfgs) = fig.series(Scale::Paper) {
                let c = &cfgs[0];
                println!(
                    "  {:<7} task={:<12} n={:<4} b={:<3} {:?} rounds={} batch={} lr={:?} β={} wd={} α={}",
                    id,
                    c.task.name(),
                    c.n,
                    c.b,
                    c.topology,
                    c.rounds,
                    c.batch,
                    c.lr_schedule,
                    c.momentum,
                    c.weight_decay,
                    c.alpha
                );
            }
        }
    }
    if let Some(dir) = args.get("artifacts") {
        let manifest = rpel::runtime::Manifest::load(format!("{dir}/manifest.json"))?;
        println!(
            "\nartifacts ({} entries, scale={}):",
            manifest.len(),
            manifest.scale
        );
        for e in manifest.iter() {
            println!(
                "  {:<40} kind={:<10} arch={} d={}",
                e.name, e.kind, e.arch, e.d
            );
        }
    }
    Ok(())
}

fn cmd_check(args: &Args) -> CmdResult {
    args.check_known(&["artifacts"])?;
    let dir = args.get_or("artifacts", "artifacts");
    let mut rt = rpel::runtime::Runtime::open(dir)?;
    println!(
        "manifest: {} artifacts (scale={})",
        rt.manifest().len(),
        rt.manifest().scale
    );
    // smoke-execute the mlp_tiny path end to end
    let init = rt.init_exec("mlp_tiny")?;
    let params = init.run(0)?;
    println!("init_mlp_tiny: d={} ✓", params.len());
    let agg = rt.aggregate_exec("mlp_tiny", 8, 2)?;
    let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; params.len()]).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let out = agg.run(&refs)?;
    println!("aggregate_mlp_tiny_m8_b2: out[0]={} ✓", out[0]);
    println!("artifact check OK");
    Ok(())
}

/// Determinism & panic-safety static analysis (see `rpel::analysis` for
/// the rule catalogue and exemption-marker syntax). Exits nonzero when
/// any rule fires so CI and pre-commit hooks can gate on it.
fn cmd_lint(args: &Args) -> CmdResult {
    args.check_known(&["json"])?;
    // Accept both `rpel lint path --json` and `rpel lint --json path`: in
    // the latter the bare grammar parses the path as --json's value.
    let root = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("json").filter(|v| !v.is_empty()))
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let report = rpel::analysis::run_lint(&root).map_err(|e| format!("{e:#}"))?;
    if args.has("json") {
        println!("{}", rpel::analysis::report::render_json(&report));
    } else {
        print!("{}", rpel::analysis::report::render_text(&report));
    }
    if report.clean() {
        Ok(())
    } else {
        Err(format!("rpel lint: {} finding(s)", report.findings.len()).into())
    }
}

/// Host one honest shard for a multi-process coordinator: strict
/// request/reply wire protocol on stdin/stdout (pipe transport) or on a
/// stream socket with worker-side pull serving (`--transport socket
/// --connect <addr> --worker <idx>`). See `rpel::wire::proto` for the
/// sequence diagrams. Spawned by `Trainer` when `--procs N > 1`; not
/// intended for manual use.
fn cmd_shard_worker(args: &Args) -> CmdResult {
    args.check_known(&["transport", "connect", "worker", "incarnation"])?;
    let result = match args.get_or("transport", "pipe") {
        "pipe" => rpel::coordinator::proc::run_worker(std::io::stdin(), std::io::stdout()),
        "socket" | "tcp" => {
            let connect = args
                .get("connect")
                .ok_or("shard-worker --transport socket needs --connect")?;
            let worker = args
                .get_usize("worker")?
                .ok_or("shard-worker --transport socket needs --worker")?;
            // respawned workers carry their restart generation so the
            // coordinator can tell a fresh hello from a stale one
            let incarnation = args.get_usize("incarnation")?.unwrap_or(0) as u32;
            rpel::coordinator::proc::run_worker_socket(connect, worker, incarnation)
        }
        other => return Err(format!("unknown shard-worker transport '{other}'").into()),
    };
    result.map_err(|e| format!("{e:#}").into())
}

/// Minimal env_logger replacement: RUST_LOG=debug|info|warn enables stderr
/// logging through the `log` facade.
#[allow(clippy::disallowed_methods)] // log verbosity may read the environment
fn env_logger_lite() {
    struct L(log::LevelFilter);
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= self.0
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        Ok("warn") => log::LevelFilter::Warn,
        _ => log::LevelFilter::Error,
    };
    let _ = log::set_boxed_logger(Box::new(L(level)));
    log::set_max_level(level);
}
