//! `RowCodec` — the wire row-block compression layer (protocol v4).
//!
//! `Snapshot` and `PullReply` row blocks can travel at a configured
//! `compression ∈ {none, f16, q8}` (`[wire] compression` in the TOML,
//! `--compression` on the CLI). Each row is encoded as a **delta against
//! the round's reference vector** — the previous round's
//! [`crate::attacks::HonestDigest`] mean narrowed per-coordinate to f32
//! (`mean[i] as f32`), all-zeros before the first fold — and the decode
//! is part of the wire spec: every consumer aggregates the *decoded*
//! bits, so compression is a **modeled accuracy knob, not FP noise**.
//!
//! Encoded layouts, per row of width `d` (stride in bytes):
//!
//! ```text
//! none  [d × f32 LE]            stride 4d   (bit-identical to v3 blocks)
//! f16   [d × u16 LE]            stride 2d   (IEEE binary16 bit patterns)
//! q8    [f32 LE scale][d × i8]  stride 4+d  (symmetric, saturating)
//! ```
//!
//! **f16**: `delta_i = row_i − ref_i` (one f32 subtract), converted to
//! binary16 by deterministic round-to-nearest-even bit manipulation
//! ([`f32_to_f16`]): overflow rounds to ±Inf (`0x7C00`/`0xFC00`), every
//! NaN canonicalizes to the quiet pattern `0x7E00`, magnitudes below the
//! binary16 subnormal floor round to ±0. Decode is
//! `ref_i + f16_to_f32(bits)` — one f32 add.
//!
//! **q8**: per-row scale derivation `m = max |delta_i|` over the row's
//! *finite* deltas (0 when none are finite), `scale = m / 127.0` (f32
//! divide; a subnormal `m` may underflow `scale` to 0, which encodes the
//! row as exactly the reference). Each delta quantizes to
//! `k_i = round(delta_i / scale)` — round-half-away-from-zero, then
//! saturated to `[−127, +127]` — with the non-finite saturation bits
//! `NaN → 0`, `+Inf → +127`, `−Inf → −127`. Decode is
//! `ref_i + (k_i as f32) · scale`.
//!
//! Neither encode nor decode ever re-encodes already-decoded bits:
//! quantization is **not** FP-idempotent (`fl(fl(ref+x)−ref) ≠ x` in
//! general), so producers encode **once** at the publish point via
//! [`transform_rows`] — which returns the encoded block *and* overwrites
//! the rows with the decoded bits everyone must aggregate — and serve
//! cached per-row segments ([`EncodedRows::gather`]) verbatim thereafter.
//! That single-encode discipline is what keeps a fixed compression level
//! bit-identical across the whole (transport × procs × shards × threads
//! × participation) grid, pinned in `rust/tests/determinism.rs`.
//!
//! The read side is as paranoid as the rest of the codec: block sizes go
//! through `checked_mul` against the remaining buffer *before* any
//! allocation, a zero-width or reference-width-mismatched header is an
//! error, and decode never panics on any byte pattern (the `panic-path`
//! and `unchecked-alloc` lint rules cover this module).

use super::{Reader, Writer};
use anyhow::{bail, Context, Result};

/// Row-block compression level. `None` is the v3-compatible raw f32
/// layout; `F16`/`Q8` are the delta codecs specified in the module docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Compression {
    #[default]
    None,
    F16,
    Q8,
}

impl Compression {
    /// Parse the config/CLI spelling (`none` / `f16` / `q8`).
    pub fn parse(s: &str) -> Option<Compression> {
        match s {
            "none" => Some(Compression::None),
            "f16" => Some(Compression::F16),
            "q8" => Some(Compression::Q8),
            _ => None,
        }
    }

    /// The config/CLI spelling; inverse of [`Compression::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::F16 => "f16",
            Compression::Q8 => "q8",
        }
    }

    pub fn is_none(self) -> bool {
        self == Compression::None
    }

    /// Encoded bytes per row of width `d` (see the layout table in the
    /// module docs). `d` comes off a u32 header, so this cannot overflow
    /// 64-bit usize; the *block* size `rows · stride` is the quantity
    /// that must be (and is) checked against the buffer.
    pub fn stride(self, d: usize) -> usize {
        match self {
            Compression::None => d.saturating_mul(4),
            Compression::F16 => d.saturating_mul(2),
            Compression::Q8 => d.saturating_add(4),
        }
    }
}

/// One round's codec context: the compression level plus the reference
/// vector deltas are taken against (the previous round's digest mean as
/// f32, zeros before the first fold). For `Compression::None` the
/// reference is ignored and may be empty.
#[derive(Clone, Copy, Debug)]
pub struct RowCodec<'a> {
    pub comp: Compression,
    pub reference: &'a [f32],
}

impl<'a> RowCodec<'a> {
    pub fn new(comp: Compression, reference: &'a [f32]) -> RowCodec<'a> {
        RowCodec { comp, reference }
    }

    /// The v3-compatible no-compression codec.
    pub fn none() -> RowCodec<'static> {
        RowCodec {
            comp: Compression::None,
            reference: &[],
        }
    }

    /// Reference coordinate `i`; 0.0 past the end (encode-side
    /// robustness — the decode path validates the width instead).
    fn ref_at(&self, i: usize) -> f32 {
        self.reference.get(i).copied().unwrap_or(0.0)
    }
}

/// Narrow a digest mean (f64) to the f32 reference vector of
/// [`RowCodec`]. Both sides of every link derive the reference through
/// this exact conversion, so the bits agree everywhere.
pub fn reference_from_mean(mean: &[f64]) -> Vec<f32> {
    mean.iter().map(|&x| x as f32).collect()
}

/// An encoded row block: `rows` rows of width `d`, stored as contiguous
/// fixed-stride per-row segments. Producers cache this at the publish
/// point and serve [`EncodedRows::gather`]ed segments verbatim — rows
/// are never re-encoded (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedRows {
    pub comp: Compression,
    pub rows: usize,
    pub d: usize,
    pub payload: Vec<u8>,
}

impl EncodedRows {
    pub fn stride(&self) -> usize {
        self.comp.stride(self.d)
    }

    /// The encoded segment of row `i`, or `None` out of range.
    pub fn row_payload(&self, i: usize) -> Option<&[u8]> {
        let s = self.stride();
        let lo = i.checked_mul(s)?;
        self.payload.get(lo..lo.checked_add(s)?)
    }

    /// Assemble a new block from the given row indices (a `PullReply`
    /// serving rows it cached at publish time), in request order.
    pub fn gather(&self, idx: &[usize]) -> Result<EncodedRows> {
        let s = self.stride();
        let mut payload = Vec::with_capacity(idx.len().saturating_mul(s));
        for &i in idx {
            let seg = self
                .row_payload(i)
                .with_context(|| format!("wire: gather of row {i} beyond {} cached", self.rows))?;
            payload.extend_from_slice(seg);
        }
        Ok(EncodedRows {
            comp: self.comp,
            rows: idx.len(),
            d: self.d,
            payload,
        })
    }

    /// Raw (decoded) size of the block in bytes: `rows · d · 4`.
    pub fn raw_bytes(&self) -> u64 {
        (self.rows as u64) * (self.d as u64) * 4
    }

    /// Encoded size of the block in bytes: `rows · stride`.
    pub fn encoded_bytes(&self) -> u64 {
        self.payload.len() as u64
    }
}

/// Ledger helper: encoded bytes of a `rows × d` block at `comp`
/// (`rows · stride`), without materializing it.
pub fn block_bytes(comp: Compression, rows: usize, d: usize) -> u64 {
    (rows as u64) * (comp.stride(d) as u64)
}

// ---------------------------------------------------------------------------
// binary16 bit conversion (std has no stable f16): deterministic
// round-to-nearest-even, canonical NaN, saturating overflow.
// ---------------------------------------------------------------------------

/// f16 bit patterns for the documented saturation cases.
pub const F16_POS_INF: u16 = 0x7C00;
pub const F16_NEG_INF: u16 = 0xFC00;
pub const F16_NAN: u16 = 0x7E00;

/// f32 → binary16 bits, round-to-nearest-even. Overflow saturates to
/// ±Inf, every NaN canonicalizes to [`F16_NAN`], and magnitudes below
/// the binary16 subnormal floor round to ±0.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN: Inf keeps its sign, NaN canonicalizes
        return if man != 0 { F16_NAN } else { sign | F16_POS_INF };
    }
    // re-bias 127 → 15
    let e = exp - 112;
    if e >= 0x1F {
        return sign | F16_POS_INF; // overflow → ±Inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below half the smallest subnormal → ±0
        }
        // subnormal: shift the 24-bit significand (implicit bit set)
        // into place, rounding the dropped bits to nearest-even
        let man24 = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = man24 >> shift;
        let rem = man24 & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half & 1) == 1) {
            half + 1
        } else {
            half
        };
        // a carry out of the mantissa lands in exponent 1 — still the
        // correct encoding
        return sign | rounded as u16;
    }
    // normal: drop 13 mantissa bits with round-to-nearest-even; a carry
    // propagates into the exponent, and rounding max-finite up yields
    // the Inf pattern 0x7C00 naturally
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        half + 1
    } else {
        half
    };
    sign | rounded as u16
}

/// binary16 bits → f32 (exact: every f16 value is representable).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1F) as u32;
    let man = (bits & 0x3FF) as u32;
    let out = if exp == 0x1F {
        // Inf / NaN (payload shifts up; 0x7E00 → canonical quiet f32 NaN)
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: normalize. value = man · 2^-24, top bit at p ≤ 9
            let p = 31 - man.leading_zeros();
            let exp32 = 103 + p; // (p − 24) + 127
            sign | (exp32 << 23) | ((man << (23 - p)) & 0x007F_FFFF)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(out)
}

// ---------------------------------------------------------------------------
// Row encode / decode
// ---------------------------------------------------------------------------

fn encode_row_into(codec: &RowCodec<'_>, row: &[f32], out: &mut Vec<u8>) {
    match codec.comp {
        Compression::None => {
            for &x in row {
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        Compression::F16 => {
            for (i, &x) in row.iter().enumerate() {
                let bits = f32_to_f16(x - codec.ref_at(i));
                out.extend_from_slice(&bits.to_le_bytes());
            }
        }
        Compression::Q8 => {
            // per-row scale: max |delta| over the row's finite deltas
            let mut m = 0f32;
            for (i, &x) in row.iter().enumerate() {
                let delta = x - codec.ref_at(i);
                if delta.is_finite() {
                    m = m.max(delta.abs());
                }
            }
            let scale = if m == 0.0 { 0.0 } else { m / 127.0 };
            out.extend_from_slice(&scale.to_bits().to_le_bytes());
            for (i, &x) in row.iter().enumerate() {
                let delta = x - codec.ref_at(i);
                let k: i8 = if delta.is_nan() {
                    0
                } else if delta == f32::INFINITY {
                    127
                } else if delta == f32::NEG_INFINITY {
                    -127
                } else if scale == 0.0 {
                    0
                } else {
                    // round half away from zero, then saturate (the max
                    // element can land a hair above 127.0 in f32)
                    (delta / scale).round().clamp(-127.0, 127.0) as i8
                };
                out.push(k as u8);
            }
        }
    }
}

/// Decode one `stride`-sized segment into `out` (length `d`). `seg` is
/// pre-validated by the callers ([`read_rows`] / [`transform_rows`]).
fn decode_row_into(codec: &RowCodec<'_>, seg: &[u8], out: &mut [f32]) -> Result<()> {
    match codec.comp {
        Compression::None => {
            for (x, b) in out.iter_mut().zip(seg.chunks_exact(4)) {
                *x = f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
        }
        Compression::F16 => {
            for (i, (x, b)) in out.iter_mut().zip(seg.chunks_exact(2)).enumerate() {
                *x = codec.ref_at(i) + f16_to_f32(u16::from_le_bytes([b[0], b[1]]));
            }
        }
        Compression::Q8 => {
            let (s, ks) = match seg.split_at_checked(4) {
                Some(parts) => parts,
                None => bail!("wire: q8 row segment shorter than its scale"),
            };
            let scale = f32::from_bits(u32::from_le_bytes([s[0], s[1], s[2], s[3]]));
            for (i, (x, &k)) in out.iter_mut().zip(ks.iter()).enumerate() {
                *x = codec.ref_at(i) + (k as i8 as f32) * scale;
            }
        }
    }
    Ok(())
}

/// Encode a rectangular row block. Every row must be width `d` =
/// `rows[0].len()` (mirrors [`Writer::put_f32_rows`]'s contract).
pub fn encode_rows<R: AsRef<[f32]>>(codec: &RowCodec<'_>, rows: &[R]) -> EncodedRows {
    let d = rows.first().map(|r| r.as_ref().len()).unwrap_or(0);
    let stride = codec.comp.stride(d);
    let mut payload = Vec::with_capacity(rows.len().saturating_mul(stride));
    for row in rows {
        let row = row.as_ref();
        debug_assert_eq!(row.len(), d, "ragged row block");
        encode_row_into(codec, row, &mut payload);
    }
    EncodedRows {
        comp: codec.comp,
        rows: rows.len(),
        d,
        payload,
    }
}

/// The publish-point transform: encode `rows` **once**, overwrite them
/// in place with the decoded bits (the bits every consumer aggregates),
/// and return the encoded block for caching/serving. Identity (and no
/// block is materialized lazily — callers skip it) at `none`.
pub fn transform_rows(codec: &RowCodec<'_>, rows: &mut [Vec<f32>]) -> Result<EncodedRows> {
    let enc = encode_rows(codec, rows);
    if codec.comp.is_none() {
        return Ok(enc);
    }
    for (i, row) in rows.iter_mut().enumerate() {
        let seg = enc
            .row_payload(i)
            .context("wire: transform lost a row segment")?;
        decode_row_into(codec, seg, row)?;
    }
    Ok(enc)
}

/// In-process twin of [`transform_rows`] for a single row: encode once
/// against `codec`, decode back in place. The trainer uses this on the
/// non-empty rows of a (possibly sparse) published table so in-process
/// and virtual runs aggregate the exact bits a remote consumer would
/// decode off the wire. `scratch` is caller-owned to amortize the
/// encode buffer across rows; no-op at `none`.
pub fn transform_row_in_place(
    codec: &RowCodec<'_>,
    row: &mut [f32],
    scratch: &mut Vec<u8>,
) -> Result<()> {
    if codec.comp.is_none() {
        return Ok(());
    }
    scratch.clear();
    encode_row_into(codec, row, scratch);
    decode_row_into(codec, scratch, row)
}

/// Write an encoded block with the standard row-block header:
/// `[u32 rows][u32 d][rows · stride bytes]`. At `none` this is
/// byte-identical to [`Writer::put_f32_rows`].
pub fn put_block(w: &mut Writer, block: &EncodedRows) {
    w.put_u32(block.rows as u32);
    w.put_u32(block.d as u32);
    w.put_raw(&block.payload);
}

/// Read and decode a row block at `codec`. For `none` this is exactly
/// [`Reader::f32_rows`]; otherwise the block's width must match the
/// reference vector, the byte size is `checked_mul`-bounded against the
/// remaining buffer before any allocation, and truncated or oversized
/// blocks error without allocating.
pub fn read_rows(r: &mut Reader<'_>, codec: &RowCodec<'_>) -> Result<Vec<Vec<f32>>> {
    if codec.comp.is_none() {
        return r.f32_rows();
    }
    let rows = r.u32()? as usize;
    let d = r.u32()? as usize;
    if rows > 0 && d == 0 {
        // see Reader::f32_rows: a zero-width header would sidestep the
        // byte-level bound and allocate ~4G rows
        bail!("wire: zero-width row block with {rows} rows");
    }
    if rows > 0 && d != codec.reference.len() {
        bail!(
            "wire: encoded row block width {d} != reference width {}",
            codec.reference.len()
        );
    }
    let stride = codec.comp.stride(d);
    let total = rows
        .checked_mul(stride)
        .context("wire: row block size overflow")?;
    let raw = r.take(total)?;
    let mut out = Vec::with_capacity(rows);
    for seg in raw.chunks_exact(stride) {
        let mut row = vec![0f32; d];
        decode_row_into(codec, seg, &mut row)?;
        out.push(row);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt16(x: f32) -> f32 {
        f16_to_f32(f32_to_f16(x))
    }

    #[test]
    fn f16_bits_of_known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.5), 0xC100);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // max finite
        assert_eq!(f32_to_f16(65520.0), F16_POS_INF); // RNE boundary → Inf
        assert_eq!(f32_to_f16(f32::INFINITY), F16_POS_INF);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), F16_NEG_INF);
        assert_eq!(f32_to_f16(f32::NAN), F16_NAN);
        assert_eq!(f32_to_f16(5.960_464_5e-8), 0x0001); // min subnormal
        assert_eq!(f32_to_f16(2.980_232_2e-8), 0x0000); // half of it → even
        assert_eq!(f32_to_f16(6.103_515_6e-5), 0x0400); // min normal
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1 + 2^-10:
        // RNE picks the even mantissa (1.0)
        assert_eq!(f32_to_f16(1.0 + f32::powi(2.0, -11)), 0x3C00);
        // 1 + 3·2^-11 is halfway between odd 1+2^-10 and even 1+2^-9
        assert_eq!(f32_to_f16(1.0 + 3.0 * f32::powi(2.0, -11)), 0x3C02);
    }

    #[test]
    fn every_f16_value_round_trips_through_f32() {
        for bits in 0..=u16::MAX {
            let x = f16_to_f32(bits);
            if x.is_nan() {
                assert_eq!(f32_to_f16(x), F16_NAN, "bits={bits:#06x}");
            } else {
                assert_eq!(f32_to_f16(x), bits, "bits={bits:#06x}");
            }
        }
    }

    #[test]
    fn f16_exact_values_survive_codec() {
        let reference = [0.5f32, -3.0, 0.0, 1e4];
        let codec = RowCodec::new(Compression::F16, &reference);
        // deltas exactly representable in f16 → lossless round trip
        let mut rows = vec![vec![0.5f32 + 0.25, -3.0 - 2.0, 6.0, 1e4]];
        let want = rows.clone();
        let enc = transform_rows(&codec, &mut rows).unwrap();
        assert_eq!(rows, want);
        assert_eq!(enc.encoded_bytes(), 8);
        assert_eq!(enc.raw_bytes(), 16);
    }

    #[test]
    fn q8_scale_and_saturation_bits() {
        let reference = [0f32; 4];
        let codec = RowCodec::new(Compression::Q8, &reference);
        let enc = encode_rows(&codec, &[vec![0.0f32, 63.5, -127.0, 127.0]]);
        // scale = 127/127 = 1.0; 63.5 rounds half away from zero → 64
        assert_eq!(
            enc.payload,
            vec![0x00, 0x00, 0x80, 0x3F, 0, 64, 0x81, 0x7F]
        );
        let nf = encode_rows(&codec, &[vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0]]);
        // finite deltas = {2.0} → scale = 2/127; NaN→0, ±Inf→±127, 2.0→127
        assert_eq!(&nf.payload[4..], &[0, 0x7F, 0x81, 0x7F]);
        assert_eq!(
            f32::from_bits(u32::from_le_bytes([
                nf.payload[0],
                nf.payload[1],
                nf.payload[2],
                nf.payload[3]
            ])),
            2.0f32 / 127.0
        );
    }

    #[test]
    fn q8_all_zero_or_nonfinite_rows_use_zero_scale() {
        let reference = [1.0f32, 2.0];
        let codec = RowCodec::new(Compression::Q8, &reference);
        let mut rows = vec![vec![1.0f32, 2.0], vec![f32::NAN, f32::INFINITY]];
        let enc = transform_rows(&codec, &mut rows).unwrap();
        // zero-delta row decodes to exactly the reference
        assert_eq!(rows[0], vec![1.0, 2.0]);
        // non-finite row: scale 0 ⇒ NaN→ref, +Inf→ref (±127·0 = 0)
        assert_eq!(rows[1], vec![1.0, 2.0]);
        assert_eq!(enc.row_payload(1).unwrap(), &[0, 0, 0, 0, 0, 0x7F]);
    }

    #[test]
    fn block_round_trips_through_wire_header() {
        let reference = [0.25f32, -0.5, 3.0];
        for comp in [Compression::None, Compression::F16, Compression::Q8] {
            let codec = RowCodec::new(comp, &reference);
            let mut rows = vec![
                vec![1.0f32, -2.0, 3.5],
                vec![0.25, -0.5, 3.0],
                vec![-1e3, 0.0, 42.0],
            ];
            let enc = transform_rows(&codec, &mut rows).unwrap();
            let mut w = Writer::new();
            put_block(&mut w, &enc);
            let buf = w.into_bytes();
            let mut r = Reader::new(&buf);
            let got = read_rows(&mut r, &codec).unwrap();
            r.finish().unwrap();
            // the wire decode reproduces the transform's decoded bits
            assert_eq!(got, rows, "{comp:?}");
        }
    }

    #[test]
    fn per_row_transform_matches_block_transform() {
        let reference = [0.1f32, -2.0, 7.5];
        for comp in [Compression::None, Compression::F16, Compression::Q8] {
            let codec = RowCodec::new(comp, &reference);
            let mut block = vec![vec![1.0f32, -2.5, 9.0], vec![0.1, 1e3, -0.25]];
            let mut single = block.clone();
            transform_rows(&codec, &mut block).unwrap();
            let mut scratch = Vec::new();
            for row in &mut single {
                transform_row_in_place(&codec, row, &mut scratch).unwrap();
            }
            assert_eq!(single, block, "{comp:?}");
        }
    }

    #[test]
    fn gather_serves_cached_segments_verbatim() {
        let reference = [0f32; 2];
        let codec = RowCodec::new(Compression::Q8, &reference);
        let enc = encode_rows(
            &codec,
            &[vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]],
        );
        let sub = enc.gather(&[2, 0]).unwrap();
        assert_eq!(sub.rows, 2);
        assert_eq!(sub.row_payload(0).unwrap(), enc.row_payload(2).unwrap());
        assert_eq!(sub.row_payload(1).unwrap(), enc.row_payload(0).unwrap());
        assert!(enc.gather(&[3]).is_err());
    }

    #[test]
    fn truncated_and_corrupt_blocks_error_not_panic() {
        let reference = [0f32; 3];
        for comp in [Compression::F16, Compression::Q8] {
            let codec = RowCodec::new(comp, &reference);
            let enc = encode_rows(&codec, &[vec![1.0f32, 2.0, 3.0]]);
            let mut w = Writer::new();
            put_block(&mut w, &enc);
            let buf = w.into_bytes();
            for cut in 0..buf.len() {
                let mut r = Reader::new(&buf[..cut]);
                assert!(read_rows(&mut r, &codec).is_err(), "{comp:?} cut={cut}");
            }
            // oversized claimed row count must error before allocating
            let mut big = buf.clone();
            big[..4].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(read_rows(&mut Reader::new(&big), &codec).is_err());
            // zero-width and reference-width-mismatch headers rejected
            let mut zw = buf.clone();
            zw[4..8].copy_from_slice(&0u32.to_le_bytes());
            assert!(read_rows(&mut Reader::new(&zw), &codec).is_err());
            let mut wide = buf.clone();
            wide[4..8].copy_from_slice(&7u32.to_le_bytes());
            assert!(read_rows(&mut Reader::new(&wide), &codec).is_err());
        }
    }

    #[test]
    fn compression_parse_and_name_inverse() {
        for comp in [Compression::None, Compression::F16, Compression::Q8] {
            assert_eq!(Compression::parse(comp.name()), Some(comp));
        }
        assert_eq!(Compression::parse("gzip"), None);
        assert_eq!(block_bytes(Compression::Q8, 5, 10), 70);
        assert_eq!(block_bytes(Compression::F16, 5, 10), 100);
        assert_eq!(block_bytes(Compression::None, 5, 10), 200);
    }
}
