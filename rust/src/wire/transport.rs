//! Framed transports: the byte streams the wire codec rides on.
//!
//! [`Transport`] abstracts "send one frame / receive one frame" over any
//! duplex byte channel, with cumulative byte counters so the coordinator
//! can keep a per-round bytes-on-the-wire ledger. Two implementations:
//!
//! * [`PipeTransport`] — the original stdin/stdout path: any
//!   `Read` + `Write` pair (child pipes, in-memory cursors in tests);
//! * [`SocketTransport`] — a connected stream socket. Unix-domain
//!   sockets are the default on unix; TCP sits behind the **same**
//!   listener/stream code ([`Listener`], [`SockAddr::Tcp`]) so shard
//!   workers can later live on other hosts.
//!
//! Both speak the identical `[u32 LE length][payload]` framing of
//! [`super`], so a message is byte-for-byte the same on either transport
//! — which is what lets the determinism suite pin bit-identical results
//! across the whole (transport × procs × shards × threads) grid.
//!
//! Teardown is part of the contract: [`Transport::shutdown`] closes the
//! write direction and then drains the read side, so a peer blocked
//! mid-write (a reply larger than the kernel buffer, aimed at a
//! coordinator that already gave up on the round) is unblocked and
//! observes EOF instead of deadlocking the reap.
//!
//! Transient faults are absorbed by [`RetryPolicy`]: a bounded,
//! configuration-driven retry schedule whose decisions never read a
//! clock, so what a run computes is identical whether or not a dial or
//! fetch had to be retried along the way.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;

/// Deterministic bounded-retry schedule for transient transport faults
/// (refused dials, resets, timed-out reads on a supervised socket).
///
/// The decision path reads no clocks: the attempt budget and the
/// backoff schedule come from `[recovery]` configuration, so whether a
/// retry happens — and which error finally surfaces — depends only on
/// how many times the operation failed, never on elapsed wall time.
/// Sleeping between attempts is allowed (it changes *when* things
/// happen, not *what* happens); reading time to decide is not.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total tries, including the first. Values below 1 behave as 1, so
    /// a zeroed policy still runs the operation exactly once.
    pub attempts: usize,
    /// Base backoff: try `k+1` follows failed try `k` (0-based) after
    /// `backoff_ms · 2^k` milliseconds (exponent capped, saturating).
    pub backoff_ms: u64,
}

impl RetryPolicy {
    /// A single try with no waiting — the pre-recovery behavior, used
    /// where a higher layer (supervised restart) owns fault handling.
    pub fn once() -> RetryPolicy {
        RetryPolicy {
            attempts: 1,
            backoff_ms: 0,
        }
    }

    /// The pause after failed attempt `attempt` (0-based).
    pub fn backoff(&self, attempt: usize) -> std::time::Duration {
        let factor = 1u64 << attempt.min(16);
        std::time::Duration::from_millis(self.backoff_ms.saturating_mul(factor))
    }

    /// Run `op` (which receives the 0-based attempt index) until it
    /// succeeds or the budget is spent, sleeping the backoff between
    /// tries. On exhaustion the *last* error surfaces, wrapped with
    /// `what` and the attempt count so the failure names what was being
    /// retried and how hard.
    pub fn run<T>(&self, what: &str, mut op: impl FnMut(usize) -> Result<T>) -> Result<T> {
        let budget = self.attempts.max(1);
        let mut attempt = 0;
        loop {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    attempt += 1;
                    if attempt >= budget {
                        return Err(
                            e.context(format!("{what}: giving up after {budget} attempt(s)"))
                        );
                    }
                    std::thread::sleep(self.backoff(attempt - 1));
                }
            }
        }
    }
}

/// One frame in, one frame out, with byte accounting.
pub trait Transport: Send {
    /// Write one framed payload and flush it.
    fn send(&mut self, payload: &[u8]) -> Result<()>;
    /// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
    fn recv_opt(&mut self) -> Result<Option<Vec<u8>>>;
    /// Read one frame; EOF anywhere is an error (peer died mid-protocol).
    fn recv(&mut self) -> Result<Vec<u8>> {
        self.recv_opt()?
            .context("wire: unexpected end of stream")
    }
    /// Cumulative bytes written (payloads + 4-byte frame headers).
    fn bytes_out(&self) -> u64;
    /// Cumulative bytes read (payloads + 4-byte frame headers).
    fn bytes_in(&self) -> u64;
    /// Close the write direction, then drain the read side to EOF so a
    /// peer blocked mid-write can finish and observe the close.
    fn shutdown(&mut self);
}

/// Framed transport over any `Read` + `Write` pair — the stdin/stdout
/// pipe path, and the generic substrate the chaos harness wraps.
pub struct PipeTransport<R: Read, W: Write> {
    r: R,
    /// `None` after [`Transport::shutdown`] (dropping the writer closes
    /// the pipe's write end, which is EOF for the peer).
    w: Option<W>,
    bytes_in: u64,
    bytes_out: u64,
}

impl<R: Read, W: Write> PipeTransport<R, W> {
    pub fn new(r: R, w: W) -> PipeTransport<R, W> {
        PipeTransport {
            r,
            w: Some(w),
            bytes_in: 0,
            bytes_out: 0,
        }
    }
}

impl<R: Read + Send, W: Write + Send> Transport for PipeTransport<R, W> {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        let w = self
            .w
            .as_mut()
            .context("wire: transport already shut down")?;
        super::write_frame(w, payload)?;
        w.flush()?;
        self.bytes_out += payload.len() as u64 + 4;
        Ok(())
    }

    fn recv_opt(&mut self) -> Result<Option<Vec<u8>>> {
        let frame = super::read_frame_opt(&mut self.r)?;
        if let Some(f) = &frame {
            self.bytes_in += f.len() as u64 + 4;
        }
        Ok(frame)
    }

    fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    fn shutdown(&mut self) {
        if let Some(mut w) = self.w.take() {
            let _ = w.flush();
            drop(w); // closes the write end: the peer reads EOF
        }
        // unblock a peer stuck writing a bigger-than-buffer reply
        let _ = std::io::copy(&mut self.r, &mut std::io::sink());
    }
}

/// Socket address for [`Listener`]/[`SocketTransport`]: a filesystem
/// path (unix-domain) or `host:port` (TCP). The textual form
/// (`unix:<path>` / `tcp:<host:port>`) is what travels in `PeerHello`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SockAddr {
    Unix(PathBuf),
    Tcp(String),
}

impl SockAddr {
    pub fn parse(s: &str) -> Result<SockAddr> {
        if let Some(path) = s.strip_prefix("unix:") {
            Ok(SockAddr::Unix(PathBuf::from(path)))
        } else if let Some(addr) = s.strip_prefix("tcp:") {
            Ok(SockAddr::Tcp(addr.to_string()))
        } else {
            bail!("bad socket address '{s}' (expected unix:<path> or tcp:<host:port>)")
        }
    }
}

impl std::fmt::Display for SockAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SockAddr::Unix(p) => write!(f, "unix:{}", p.display()),
            SockAddr::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

/// A connected stream socket (unix-domain or TCP) behind one type.
pub enum SocketStream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl SocketStream {
    pub fn connect(addr: &SockAddr) -> Result<SocketStream> {
        match addr {
            #[cfg(unix)]
            SockAddr::Unix(path) => {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connecting to unix socket {}", path.display()))?;
                Ok(SocketStream::Unix(s))
            }
            #[cfg(not(unix))]
            SockAddr::Unix(path) => bail!(
                "unix-domain sockets are unsupported on this platform \
                 (addr {}); use transport \"tcp\"",
                path.display()
            ),
            SockAddr::Tcp(a) => {
                let s = TcpStream::connect(a.as_str())
                    .with_context(|| format!("connecting to tcp socket {a}"))?;
                s.set_nodelay(true).ok();
                Ok(SocketStream::Tcp(s))
            }
        }
    }

    fn try_clone(&self) -> Result<SocketStream> {
        Ok(match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => SocketStream::Unix(s.try_clone()?),
            SocketStream::Tcp(s) => SocketStream::Tcp(s.try_clone()?),
        })
    }

    fn shutdown_write(&self) {
        let how = std::net::Shutdown::Write;
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => {
                let _ = s.shutdown(how);
            }
            SocketStream::Tcp(s) => {
                let _ = s.shutdown(how);
            }
        }
    }

    pub fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => s.set_nonblocking(nb)?,
            SocketStream::Tcp(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Bound blocking reads (`None` = block forever). A timed-out read
    /// surfaces as an io error, so a mute peer becomes an actionable
    /// failure instead of a hang.
    pub fn set_read_timeout(&self, timeout: Option<std::time::Duration>) -> Result<()> {
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => s.set_read_timeout(timeout)?,
            SocketStream::Tcp(s) => s.set_read_timeout(timeout)?,
        }
        Ok(())
    }
}

impl Read for SocketStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => s.read(buf),
            SocketStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for SocketStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => s.write(buf),
            SocketStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            SocketStream::Unix(s) => s.flush(),
            SocketStream::Tcp(s) => s.flush(),
        }
    }
}

/// Framed transport over a connected socket. Reader and writer are
/// independent handles onto the same socket (`try_clone`), so a serving
/// thread can hold one while the protocol loop holds the other.
pub struct SocketTransport {
    r: std::io::BufReader<SocketStream>,
    w: Option<std::io::BufWriter<SocketStream>>,
    bytes_in: u64,
    bytes_out: u64,
}

impl SocketTransport {
    pub fn connect(addr: &SockAddr) -> Result<SocketTransport> {
        SocketTransport::from_stream(SocketStream::connect(addr)?)
    }

    pub fn from_stream(stream: SocketStream) -> Result<SocketTransport> {
        let w = stream.try_clone()?;
        Ok(SocketTransport {
            r: std::io::BufReader::new(stream),
            w: Some(std::io::BufWriter::new(w)),
            bytes_in: 0,
            bytes_out: 0,
        })
    }

    /// See [`SocketStream::set_read_timeout`].
    pub fn set_read_timeout(&self, timeout: Option<std::time::Duration>) -> Result<()> {
        self.r.get_ref().set_read_timeout(timeout)
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        let w = self
            .w
            .as_mut()
            .context("wire: socket transport already shut down")?;
        super::write_frame(w, payload)?;
        w.flush()?;
        self.bytes_out += payload.len() as u64 + 4;
        Ok(())
    }

    fn recv_opt(&mut self) -> Result<Option<Vec<u8>>> {
        let frame = super::read_frame_opt(&mut self.r)?;
        if let Some(f) = &frame {
            self.bytes_in += f.len() as u64 + 4;
        }
        Ok(frame)
    }

    fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    fn shutdown(&mut self) {
        if let Some(mut w) = self.w.take() {
            let _ = w.flush();
            // half-close: the socket stays readable, the peer sees EOF
            w.get_ref().shutdown_write();
        }
        let _ = std::io::copy(&mut self.r, &mut std::io::sink());
    }
}

/// Bound listener: unix-domain and TCP behind the same accept loop, so
/// the worker-spawning code is transport-family agnostic.
pub enum Listener {
    #[cfg(unix)]
    Unix { inner: UnixListener, path: PathBuf },
    Tcp(TcpListener),
}

impl Listener {
    /// Bind at `addr`. `tcp:host:0` binds an ephemeral port — query the
    /// real address with [`Listener::local_addr`]. A stale unix socket
    /// file at the path is removed first.
    pub fn bind(addr: &SockAddr) -> Result<Listener> {
        match addr {
            #[cfg(unix)]
            SockAddr::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let inner = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {}", path.display()))?;
                Ok(Listener::Unix {
                    inner,
                    path: path.clone(),
                })
            }
            #[cfg(not(unix))]
            SockAddr::Unix(path) => bail!(
                "unix-domain sockets are unsupported on this platform \
                 (addr {}); use transport \"tcp\"",
                path.display()
            ),
            SockAddr::Tcp(a) => {
                let inner = TcpListener::bind(a.as_str())
                    .with_context(|| format!("binding tcp socket {a}"))?;
                Ok(Listener::Tcp(inner))
            }
        }
    }

    /// The bound address (with the real port for ephemeral TCP binds).
    pub fn local_addr(&self) -> Result<SockAddr> {
        Ok(match self {
            #[cfg(unix)]
            Listener::Unix { path, .. } => SockAddr::Unix(path.clone()),
            Listener::Tcp(l) => SockAddr::Tcp(l.local_addr()?.to_string()),
        })
    }

    pub fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            Listener::Unix { inner, .. } => inner.set_nonblocking(nb)?,
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Accept one connection (honors the listener's blocking mode; a
    /// `WouldBlock` is returned as the raw io error for poll loops).
    pub fn accept(&self) -> std::io::Result<SocketStream> {
        match self {
            #[cfg(unix)]
            Listener::Unix { inner, .. } => {
                let (s, _) = inner.accept()?;
                Ok(SocketStream::Unix(s))
            }
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true).ok();
                Ok(SocketStream::Tcp(s))
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipe_transport_frames_and_counts() {
        let mut out = Vec::new();
        {
            let mut t = PipeTransport::new(std::io::empty(), &mut out);
            t.send(b"abc").unwrap();
            t.send(b"").unwrap();
            assert_eq!(t.bytes_out(), 3 + 4 + 4);
        }
        let mut t = PipeTransport::new(std::io::Cursor::new(out), std::io::sink());
        assert_eq!(t.recv().unwrap(), b"abc");
        assert_eq!(t.recv().unwrap(), b"");
        assert!(t.recv_opt().unwrap().is_none());
        assert_eq!(t.bytes_in(), 3 + 4 + 4);
        assert!(t.recv().is_err(), "EOF mid-protocol is an error");
    }

    #[test]
    fn sockaddr_round_trips_textually() {
        for addr in [
            SockAddr::Unix(PathBuf::from("/tmp/x.sock")),
            SockAddr::Tcp("127.0.0.1:7007".into()),
        ] {
            assert_eq!(SockAddr::parse(&addr.to_string()).unwrap(), addr);
        }
        assert!(SockAddr::parse("carrier-pigeon:coop").is_err());
    }

    #[test]
    fn tcp_listener_and_socket_transport_round_trip() {
        let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = SocketTransport::from_stream(listener.accept().unwrap()).unwrap();
            let got = t.recv().unwrap();
            t.send(&got).unwrap();
            t.shutdown();
        });
        let mut c = SocketTransport::connect(&addr).unwrap();
        c.send(b"ping").unwrap();
        assert_eq!(c.recv().unwrap(), b"ping");
        assert!(c.recv_opt().unwrap().is_none(), "server half-closed");
        assert_eq!(c.bytes_out(), 8);
        assert_eq!(c.bytes_in(), 8);
        server.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn unix_listener_and_socket_transport_round_trip() {
        let dir = std::env::temp_dir().join(format!("rpel-transport-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sock");
        let listener = Listener::bind(&SockAddr::Unix(path.clone())).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = SocketTransport::from_stream(listener.accept().unwrap()).unwrap();
            assert_eq!(t.recv().unwrap(), b"hello");
            t.send(b"world").unwrap();
        });
        let mut c = SocketTransport::connect(&addr).unwrap();
        c.send(b"hello").unwrap();
        assert_eq!(c.recv().unwrap(), b"world");
        server.join().unwrap();
        assert!(!path.exists(), "listener drop removes the socket file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retry_policy_schedule_is_deterministic() {
        let p = RetryPolicy {
            attempts: 3,
            backoff_ms: 1,
        };
        assert_eq!(p.backoff(0), std::time::Duration::from_millis(1));
        assert_eq!(p.backoff(1), std::time::Duration::from_millis(2));
        assert_eq!(p.backoff(5), std::time::Duration::from_millis(32));
        // exponent cap + saturation: absurd attempt counts never overflow
        let big = RetryPolicy {
            attempts: 3,
            backoff_ms: u64::MAX,
        };
        assert_eq!(big.backoff(400), big.backoff(16));
        assert_eq!(RetryPolicy::once().backoff(9), std::time::Duration::ZERO);
    }

    #[test]
    fn retry_policy_budget_and_final_error() {
        let p = RetryPolicy {
            attempts: 3,
            backoff_ms: 0,
        };
        // succeeds on the final allowed attempt
        let mut calls = 0;
        let out = p
            .run("op", |attempt| {
                calls += 1;
                if attempt < 2 {
                    bail!("transient")
                }
                Ok(attempt)
            })
            .unwrap();
        assert_eq!((out, calls), (2, 3));

        // exhaustion surfaces the last error, naming the op + budget
        let err: anyhow::Error = p
            .run("pull from peer 3 (round 7)", |_| -> Result<()> {
                bail!("connection refused")
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("pull from peer 3 (round 7): giving up after 3 attempt(s)"),
            "{msg}"
        );
        assert!(msg.contains("connection refused"), "{msg}");

        // a zeroed budget still tries exactly once
        let mut tries = 0;
        let r: Result<()> = RetryPolicy {
            attempts: 0,
            backoff_ms: 0,
        }
        .run("z", |_| {
            tries += 1;
            bail!("nope")
        });
        assert!(format!("{:#}", r.unwrap_err()).contains("after 1 attempt(s)"));
        assert_eq!(tries, 1);
    }

    #[test]
    fn shutdown_unblocks_and_signals_eof() {
        // after shutdown, sends fail loudly instead of writing nowhere
        let mut t = PipeTransport::new(std::io::empty(), Vec::new());
        t.shutdown();
        assert!(t.send(b"late").is_err());
    }
}
