//! Length-prefixed binary wire format for the multi-process shard engine
//! (protocol **v5**).
//!
//! The coordinator and its `rpel shard-worker` processes exchange frames
//! of `[u32 LE length][payload]` over a [`transport::Transport`] — the
//! stdin/stdout pipe pair (`--transport pipe`, the default) or a stream
//! socket (`--transport socket` for unix-domain, `tcp` for loopback TCP;
//! both sit behind the same [`transport::Listener`] code so workers can
//! later live on other hosts). Payloads are built from a handful of
//! primitives — LE integers, IEEE-754 bit patterns for floats, and
//! `u32`-length-prefixed sequences — so every message has exactly one
//! byte representation and `encode ∘ decode = id` **bit-wise** (floats
//! round-trip through `to_bits`/`from_bits`, never through text). That
//! byte-exactness is what lets a shipped [`proto`] round payload
//! reproduce the in-process engine's results to the last ulp *on either
//! transport*; it is pinned by golden-vector and property tests in
//! `rust/tests/wire_roundtrip.rs` and the (transport × procs × shards ×
//! threads) grid in `rust/tests/determinism.rs`.
//!
//! ## v5 frame layout
//!
//! Every frame is `[u32 LE length][u8 tag][body]`; handshake frames
//! (`Init` `0x01`, `InitOk` `0x81`, `PeerHello` `0x40`) carry
//! [`proto::PROTOCOL_VERSION`] right after the tag and both sides verify
//! it before anything else, so a version-skewed peer fails loudly at
//! connect time. Row blocks — the `Snapshot` and `PullReply` bodies that
//! dominate traffic — are `[u32 rows][u32 d][rows · stride bytes]`,
//! where the per-row stride is set by the **compression level** from the
//! `[wire]` config section (see [`codec`]):
//!
//! ```text
//! none  [d × f32 LE]            stride 4d   (the v3 byte stream, exactly)
//! f16   [d × u16 LE]            stride 2d   binary16 delta vs digest mean
//! q8    [f32 LE scale][d × i8]  stride 4+d  saturating symmetric quantize
//! ```
//!
//! The level is ambient from the shared config (shipped in `Init`), not
//! a per-frame byte: at `none` every frame is byte-identical to protocol
//! v3 except the version field itself. `Aggregate` and `RoundDone` row
//! blocks always travel raw — they carry already-decoded or committed
//! state, never freshly published rows.
//!
//! ## Compression is a modeled knob, not FP noise
//!
//! `f16`/`q8` rows are encoded **once** at the publish point as deltas
//! against the round's reference (the previous round's digest mean as
//! f32), with round-to-nearest-even f16 conversion and per-row-scale q8
//! quantization specified bit-exactly in [`codec`]. The **decode is part
//! of the wire spec**: the publisher overwrites its own rows with the
//! decoded bits and every consumer — in-process shards, `rpel
//! shard-worker`, `PeerClient`/`RowServer`, the virtual backend —
//! aggregates those decoded bits on every path. Quantization therefore
//! changes *the experiment* (a measurable accuracy-vs-bits trade-off,
//! swept in `experiments`), never the agreement between two runs: a
//! fixed level stays bit-identical across the whole (transport × procs ×
//! shards × threads × participation) grid. Raw-vs-encoded traffic lands
//! in [`crate::metrics::History`]'s `wire_raw_bytes_per_round` /
//! `wire_encoded_bytes_per_round` ledgers.
//!
//! The two transports differ in **who ships the round tables**, not in
//! the codec (see [`proto`] for the sequence diagrams):
//!
//! * **pipe** — the coordinator broadcasts the full O(h·d) half-step
//!   table to every worker in `Aggregate`;
//! * **socket** — the coordinator ships only the folded digest plus the
//!   per-round pull **routing table** (`AggregateRouted`), and workers
//!   serve each other the referenced rows directly (`PullRequest` /
//!   `PullReply` on each worker's own listener), dropping the per-worker
//!   coordinator traffic from O(h·d) to O(s·d + routing table). The
//!   reduction is *measured* by the per-round bytes ledger in
//!   [`crate::metrics::History`].
//!
//! The codec is deliberately std-only (the offline crate set has no serde)
//! and paranoid on the read side: every length is bounds-checked against
//! the remaining buffer before allocation, truncated frames and trailing
//! bytes are errors, and a [`MAX_FRAME`] cap turns stream corruption into
//! an actionable error instead of an absurd allocation. Fault injection
//! (short writes, split reads, mid-frame EOF, delayed and stale replies)
//! is covered by [`crate::testkit::chaos`] + `rust/tests/transport_faults.rs`.

pub mod codec;
pub mod proto;
pub mod transport;

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Upper bound on one frame's payload (1 GiB). Honest payloads are
/// `O(h·d·4)` bytes; anything near the cap is stream corruption.
pub const MAX_FRAME: usize = 1 << 30;

/// Append-only payload builder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// IEEE-754 bit pattern, LE — bit-exact, never text.
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// IEEE-754 bit pattern, LE — bit-exact, never text.
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Raw bytes, no length prefix — the caller frames them (the
    /// [`codec`] row blocks carry their own `[rows][d]` header).
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// `u32` length prefix + raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// UTF-8 string as [`Writer::put_bytes`].
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    /// `u32` count + per-element LE `u32`s (usize values must fit).
    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u32(x);
        }
    }

    /// `u32` count + per-element f64 bit patterns.
    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_f64(x);
        }
    }

    /// `u32` count + per-element LE `u64`s (checkpoint ledgers, vclock
    /// state).
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u64(x);
        }
    }

    /// Sparse f32 row set: `[u32 n][n · u8 present][f32 row block of the
    /// present rows]`. Carries per-node optional state (async carry rows,
    /// virtual-node momentum) where absent ≠ all-zeros.
    pub fn put_opt_f32_rows(&mut self, rows: &[Option<Vec<f32>>]) {
        self.put_u32(rows.len() as u32);
        for row in rows {
            self.put_u8(row.is_some() as u8);
        }
        let present: Vec<&[f32]> = rows.iter().flatten().map(|r| r.as_slice()).collect();
        self.put_f32_rows(&present);
    }

    /// Rectangular f32 row block: `[u32 rows][u32 d][rows·d f32]`.
    /// Every row must have the same length.
    pub fn put_f32_rows<R: AsRef<[f32]>>(&mut self, rows: &[R]) {
        let d = rows.first().map(|r| r.as_ref().len()).unwrap_or(0);
        self.put_u32(rows.len() as u32);
        self.put_u32(d as u32);
        for row in rows {
            let row = row.as_ref();
            debug_assert_eq!(row.len(), d, "ragged row block");
            for &x in row {
                self.put_f32(x);
            }
        }
    }
}

/// Bounds-checked payload cursor.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "wire: truncated payload (need {n} bytes, {} left)",
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).context("wire: invalid UTF-8 string")
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).context("wire: u32 count overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(8).context("wire: f64 count overflow")?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|b| {
                f64::from_bits(u64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ]))
            })
            .collect())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(8).context("wire: u64 count overflow")?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|b| {
                u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
            })
            .collect())
    }

    /// Inverse of [`Writer::put_opt_f32_rows`]. The presence flags are
    /// bounds-checked before allocation and must agree with the row count
    /// of the trailing block.
    pub fn opt_f32_rows(&mut self) -> Result<Vec<Option<Vec<f32>>>> {
        let n = self.u32()? as usize;
        let flags = self.take(n)?.to_vec();
        let present = self.f32_rows()?;
        let want = flags.iter().filter(|&&f| f != 0).count();
        if present.len() != want {
            bail!(
                "wire: sparse row set carries {} rows but flags mark {want} present",
                present.len()
            );
        }
        let mut rows = present.into_iter();
        Ok(flags
            .into_iter()
            .map(|f| if f != 0 { rows.next() } else { None })
            .collect())
    }

    /// Inverse of [`Writer::put_f32_rows`].
    pub fn f32_rows(&mut self) -> Result<Vec<Vec<f32>>> {
        let rows = self.u32()? as usize;
        let d = self.u32()? as usize;
        if rows > 0 && d == 0 {
            // zero-width rows never occur on the encode side; without
            // this check a corrupt (rows=u32::MAX, d=0) header would
            // pass the byte-level bounds check and allocate ~4G rows
            bail!("wire: zero-width row block with {rows} rows");
        }
        let total = rows
            .checked_mul(d)
            .and_then(|n| n.checked_mul(4))
            .context("wire: row block size overflow")?;
        let raw = self.take(total)?;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row: Vec<f32> = raw[r * d * 4..(r + 1) * d * 4]
                .chunks_exact(4)
                .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
                .collect();
            out.push(row);
        }
        Ok(out)
    }

    /// Error on trailing bytes — every message must consume its payload
    /// exactly, so version skew fails loudly instead of silently.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("wire: {} trailing bytes after message", self.remaining());
        }
        Ok(())
    }
}

/// Write one `[u32 length][payload]` frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("wire: frame of {} bytes exceeds cap {MAX_FRAME}", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary (the
/// peer closed the stream between messages — an orderly shutdown).
pub fn read_frame_opt(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let k = r.read(&mut header[got..])?;
        if k == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("wire: stream closed mid-frame header");
        }
        got += k;
    }
    let len = u32::from_le_bytes(header) as usize;
    if len > MAX_FRAME {
        bail!("wire: frame length {len} exceeds cap {MAX_FRAME} (corrupt stream?)");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .context("wire: stream closed mid-frame body")?;
    Ok(Some(buf))
}

/// Read one frame; EOF anywhere is an error (the peer died mid-protocol).
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    read_frame_opt(r)?.context("wire: unexpected end of stream")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip_bitwise() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(-0.0);
        w.put_f64(f64::MIN_POSITIVE);
        w.put_str("héllo");
        w.put_u32s(&[0, 1, u32::MAX]);
        w.put_f64s(&[1.5, -2.25]);
        w.put_f32_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), f64::MIN_POSITIVE.to_bits());
        assert_eq!(r.string().unwrap(), "héllo");
        assert_eq!(r.u32s().unwrap(), vec![0, 1, u32::MAX]);
        assert_eq!(r.f64s().unwrap(), vec![1.5, -2.25]);
        assert_eq!(
            r.f32_rows().unwrap(),
            vec![vec![1.0f32, 2.0], vec![3.0, 4.0]]
        );
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.put_f64s(&[1.0, 2.0, 3.0]);
        let buf = w.into_bytes();
        for cut in 0..buf.len() {
            let mut r = Reader::new(&buf[..cut]);
            assert!(r.f64s().is_err(), "cut={cut} should fail");
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut w = Writer::new();
        w.put_u32(1);
        w.put_u8(0xAA);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        r.u32().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn absurd_length_prefix_bounded() {
        // a corrupt u32 length must not trigger a giant allocation
        let buf = u32::MAX.to_le_bytes();
        let mut r = Reader::new(&buf);
        assert!(r.f64s().is_err());
        assert!(Reader::new(&buf).f32_rows().is_err());
        // zero-width rows sidestep the byte bound: must still be rejected
        let mut zw = Vec::new();
        zw.extend_from_slice(&u32::MAX.to_le_bytes()); // rows
        zw.extend_from_slice(&0u32.to_le_bytes()); // d = 0
        assert!(Reader::new(&zw).f32_rows().is_err());
        // while the legitimate empty block still decodes
        let mut empty = Vec::new();
        empty.extend_from_slice(&0u32.to_le_bytes());
        empty.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(Reader::new(&empty).f32_rows().unwrap(), Vec::<Vec<f32>>::new());
    }

    #[test]
    fn frame_io_round_trips_and_handles_eof() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"abc").unwrap();
        write_frame(&mut stream, b"").unwrap();
        let mut cur = std::io::Cursor::new(stream);
        assert_eq!(read_frame(&mut cur).unwrap(), b"abc");
        assert_eq!(read_frame(&mut cur).unwrap(), b"");
        assert!(read_frame_opt(&mut cur).unwrap().is_none());
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn oversized_frame_header_rejected() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(stream);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn mid_header_eof_is_an_error() {
        let mut cur = std::io::Cursor::new(vec![1u8, 0]);
        assert!(read_frame_opt(&mut cur).is_err());
    }
}
