//! Round-protocol messages for the multi-process shard engine.
//!
//! One worker process owns one contiguous honest shard and converses with
//! the coordinator in strict request/reply lockstep:
//!
//! ```text
//! coordinator → worker     worker → coordinator
//! ------------------       --------------------
//! Init                     InitOk | Failed        (handshake, once)
//! HalfStep{round}          Snapshot{losses,halves}  (phase 1: the shipped
//!                                                    RoundDigest payload)
//! Aggregate{round,         RoundDone{byz_seen,
//!   digest, halves}          received, params}    (phases 3–5)
//! Shutdown (or EOF)        —                      (worker exits 0)
//! ```
//!
//! `Snapshot` is the promoted [`crate::coordinator::Trainer`] round
//! digest: the shard's half-step rows in ascending honest order plus its
//! per-node losses. The coordinator folds all shards' snapshots — in
//! ascending honest-node order, exactly as the in-process engine folds
//! borrowed rows — into the global [`HonestDigest`], then broadcasts that
//! digest and the full half-step table back in `Aggregate` so every
//! worker can serve its victims' pulls and craft against the same
//! omniscient context. All floats travel as IEEE bit patterns, so a
//! multi-process run is bit-identical with its in-process twin.
//!
//! Any processing error on the worker is reported as `Failed{message}`
//! before the worker exits, so the coordinator surfaces the root cause
//! rather than a bare broken pipe.

use super::{Reader, Writer};
use crate::attacks::HonestDigest;
use anyhow::{bail, Result};

/// Bumped on any layout change; both sides verify it in the handshake.
pub const PROTOCOL_VERSION: u32 = 1;

mod tag {
    pub const INIT: u8 = 0x01;
    pub const HALF_STEP: u8 = 0x02;
    pub const AGGREGATE: u8 = 0x03;
    pub const SHUTDOWN: u8 = 0x04;
    pub const INIT_OK: u8 = 0x81;
    pub const SNAPSHOT: u8 = 0x82;
    pub const ROUND_DONE: u8 = 0x83;
    pub const FAILED: u8 = 0xFF;
}

/// Coordinator → worker.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// Handshake: the full experiment config (TOML text), this worker's
    /// index, and the total process-shard count it partitions against.
    Init {
        config_toml: String,
        worker: u32,
        procs: u32,
    },
    /// Run phase 1 (local half-steps) for round `round`.
    HalfStep { round: u64 },
    /// Phases 3–5: the folded honest digest plus the full half-step
    /// table (h rows, ascending honest order) to serve pulls from.
    Aggregate {
        round: u64,
        digest: WireDigest,
        halves: Vec<Vec<f32>>,
    },
    /// Orderly exit (EOF on stdin means the same).
    Shutdown,
}

/// Worker → coordinator.
#[derive(Clone, Debug, PartialEq)]
pub enum FromWorker {
    /// Handshake echo: the shard range the worker derived for itself.
    InitOk { start: u64, len: u64, d: u64 },
    /// The shipped round digest: per-node losses + half-step rows for
    /// the worker's shard, ascending honest order. `round` echoes the
    /// request, so a reply stranded by an aborted round can never be
    /// silently consumed as a later round's.
    Snapshot {
        round: u64,
        losses: Vec<f64>,
        halves: Vec<Vec<f32>>,
    },
    /// Round completed: per-node Byzantine-rows-seen and delivered-model
    /// counts, plus the committed params (the coordinator's mirror rows).
    /// `round` echoes the request (see [`FromWorker::Snapshot`]).
    RoundDone {
        round: u64,
        byz_seen: Vec<u32>,
        received: Vec<u32>,
        params: Vec<Vec<f32>>,
    },
    /// Terminal worker-side error, shipped before exiting.
    Failed { message: String },
}

/// [`HonestDigest`] as a wire payload (f64 bit patterns).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireDigest {
    pub count: u64,
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub prev_mean: Vec<f64>,
}

impl WireDigest {
    pub fn from_digest(d: &HonestDigest) -> WireDigest {
        WireDigest {
            count: d.count as u64,
            mean: d.mean.clone(),
            std: d.std.clone(),
            prev_mean: d.prev_mean.clone(),
        }
    }

    pub fn into_digest(self) -> HonestDigest {
        HonestDigest {
            count: self.count as usize,
            mean: self.mean,
            std: self.std,
            prev_mean: self.prev_mean,
        }
    }
}

fn put_digest(w: &mut Writer, count: u64, mean: &[f64], std: &[f64], prev_mean: &[f64]) {
    w.put_u64(count);
    w.put_f64s(mean);
    w.put_f64s(std);
    w.put_f64s(prev_mean);
}

fn read_digest(r: &mut Reader<'_>) -> Result<WireDigest> {
    Ok(WireDigest {
        count: r.u64()?,
        mean: r.f64s()?,
        std: r.f64s()?,
        prev_mean: r.f64s()?,
    })
}

// ---------------------------------------------------------------------------
// Allocation-light encoders for the per-round hot paths (take references;
// the enum encoders below delegate to these).
// ---------------------------------------------------------------------------

pub fn encode_init(config_toml: &str, worker: u32, procs: u32) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::INIT);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u32(worker);
    w.put_u32(procs);
    w.put_str(config_toml);
    w.into_bytes()
}

pub fn encode_half_step(round: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::HALF_STEP);
    w.put_u64(round);
    w.into_bytes()
}

pub fn encode_aggregate<R: AsRef<[f32]>>(
    round: u64,
    digest: &HonestDigest,
    halves: &[R],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::AGGREGATE);
    w.put_u64(round);
    put_digest(
        &mut w,
        digest.count as u64,
        &digest.mean,
        &digest.std,
        &digest.prev_mean,
    );
    w.put_f32_rows(halves);
    w.into_bytes()
}

pub fn encode_shutdown() -> Vec<u8> {
    vec![tag::SHUTDOWN]
}

pub fn encode_init_ok(start: u64, len: u64, d: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::INIT_OK);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u64(start);
    w.put_u64(len);
    w.put_u64(d);
    w.into_bytes()
}

pub fn encode_snapshot<R: AsRef<[f32]>>(round: u64, losses: &[f64], halves: &[R]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::SNAPSHOT);
    w.put_u64(round);
    w.put_f64s(losses);
    w.put_f32_rows(halves);
    w.into_bytes()
}

pub fn encode_round_done<R: AsRef<[f32]>>(
    round: u64,
    byz_seen: &[u32],
    received: &[u32],
    params: &[R],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::ROUND_DONE);
    w.put_u64(round);
    w.put_u32s(byz_seen);
    w.put_u32s(received);
    w.put_f32_rows(params);
    w.into_bytes()
}

pub fn encode_failed(message: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::FAILED);
    w.put_str(message);
    w.into_bytes()
}

// ---------------------------------------------------------------------------
// Enum encode/decode (decode side of the protocol; encode kept for tests
// and symmetry)
// ---------------------------------------------------------------------------

pub fn encode_to_worker(msg: &ToWorker) -> Vec<u8> {
    match msg {
        ToWorker::Init {
            config_toml,
            worker,
            procs,
        } => encode_init(config_toml, *worker, *procs),
        ToWorker::HalfStep { round } => encode_half_step(*round),
        ToWorker::Aggregate {
            round,
            digest,
            halves,
        } => {
            let mut w = Writer::new();
            w.put_u8(tag::AGGREGATE);
            w.put_u64(*round);
            put_digest(
                &mut w,
                digest.count,
                &digest.mean,
                &digest.std,
                &digest.prev_mean,
            );
            w.put_f32_rows(halves);
            w.into_bytes()
        }
        ToWorker::Shutdown => encode_shutdown(),
    }
}

pub fn decode_to_worker(buf: &[u8]) -> Result<ToWorker> {
    let mut r = Reader::new(buf);
    let msg = match r.u8()? {
        tag::INIT => {
            let version = r.u32()?;
            if version != PROTOCOL_VERSION {
                bail!(
                    "wire: protocol version mismatch (peer {version}, ours {PROTOCOL_VERSION})"
                );
            }
            let worker = r.u32()?;
            let procs = r.u32()?;
            let config_toml = r.string()?;
            ToWorker::Init {
                config_toml,
                worker,
                procs,
            }
        }
        tag::HALF_STEP => ToWorker::HalfStep { round: r.u64()? },
        tag::AGGREGATE => {
            let round = r.u64()?;
            let digest = read_digest(&mut r)?;
            let halves = r.f32_rows()?;
            ToWorker::Aggregate {
                round,
                digest,
                halves,
            }
        }
        tag::SHUTDOWN => ToWorker::Shutdown,
        other => bail!("wire: unknown coordinator message tag {other:#04x}"),
    };
    r.finish()?;
    Ok(msg)
}

pub fn encode_from_worker(msg: &FromWorker) -> Vec<u8> {
    match msg {
        FromWorker::InitOk { start, len, d } => encode_init_ok(*start, *len, *d),
        FromWorker::Snapshot {
            round,
            losses,
            halves,
        } => encode_snapshot(*round, losses, halves),
        FromWorker::RoundDone {
            round,
            byz_seen,
            received,
            params,
        } => encode_round_done(*round, byz_seen, received, params),
        FromWorker::Failed { message } => encode_failed(message),
    }
}

pub fn decode_from_worker(buf: &[u8]) -> Result<FromWorker> {
    let mut r = Reader::new(buf);
    let msg = match r.u8()? {
        tag::INIT_OK => {
            let version = r.u32()?;
            if version != PROTOCOL_VERSION {
                bail!(
                    "wire: protocol version mismatch (peer {version}, ours {PROTOCOL_VERSION})"
                );
            }
            FromWorker::InitOk {
                start: r.u64()?,
                len: r.u64()?,
                d: r.u64()?,
            }
        }
        tag::SNAPSHOT => FromWorker::Snapshot {
            round: r.u64()?,
            losses: r.f64s()?,
            halves: r.f32_rows()?,
        },
        tag::ROUND_DONE => FromWorker::RoundDone {
            round: r.u64()?,
            byz_seen: r.u32s()?,
            received: r.u32s()?,
            params: r.f32_rows()?,
        },
        tag::FAILED => FromWorker::Failed {
            message: r.string()?,
        },
        other => bail!("wire: unknown worker message tag {other:#04x}"),
    };
    r.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_worker_messages_round_trip() {
        let msgs = [
            ToWorker::Init {
                config_toml: "task = \"tiny\"".into(),
                worker: 1,
                procs: 3,
            },
            ToWorker::HalfStep { round: 42 },
            ToWorker::Aggregate {
                round: 7,
                digest: WireDigest {
                    count: 5,
                    mean: vec![0.5, -0.25],
                    std: vec![1.0, 0.0],
                    prev_mean: vec![-0.0, 2.0],
                },
                halves: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            },
            ToWorker::Shutdown,
        ];
        for msg in &msgs {
            let buf = encode_to_worker(msg);
            assert_eq!(&decode_to_worker(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn from_worker_messages_round_trip() {
        let msgs = [
            FromWorker::InitOk {
                start: 3,
                len: 4,
                d: 10,
            },
            FromWorker::Snapshot {
                round: 11,
                losses: vec![0.125, 2.0],
                halves: vec![vec![-1.5f32; 3], vec![0.0f32; 3]],
            },
            FromWorker::RoundDone {
                round: 12,
                byz_seen: vec![0, 2],
                received: vec![6, 6],
                params: vec![vec![9.0f32, 8.0], vec![7.0, 6.0]],
            },
            FromWorker::Failed {
                message: "boom".into(),
            },
        ];
        for msg in &msgs {
            let buf = encode_from_worker(msg);
            assert_eq!(&decode_from_worker(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn digest_conversion_is_lossless() {
        let mut d = HonestDigest::new(3);
        let r1 = [1.0f32, 2.0, 3.0];
        let r2 = [3.0f32, 2.0, 1.0];
        d.recompute(&[&r1, &r2], &[&r2, &r1], true);
        let back = WireDigest::from_digest(&d).into_digest();
        assert_eq!(back.count, d.count);
        assert_eq!(back.mean, d.mean);
        assert_eq!(back.std, d.std);
        assert_eq!(back.prev_mean, d.prev_mean);
    }

    #[test]
    fn version_mismatch_detected() {
        let mut buf = encode_init("x", 0, 1);
        buf[1] ^= 0x40; // corrupt the version field
        assert!(decode_to_worker(&buf).is_err());
    }

    #[test]
    fn unknown_tags_and_truncations_error() {
        assert!(decode_to_worker(&[0x7E]).is_err());
        assert!(decode_from_worker(&[0x00]).is_err());
        let full = encode_to_worker(&ToWorker::HalfStep { round: 1 });
        for cut in 0..full.len() {
            assert!(decode_to_worker(&full[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage rejected
        let mut padded = full.clone();
        padded.push(0);
        assert!(decode_to_worker(&padded).is_err());
    }
}
