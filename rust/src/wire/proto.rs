//! Round-protocol messages for the multi-process shard engine.
//!
//! One worker process owns one contiguous honest shard and converses with
//! the coordinator in strict request/reply lockstep. On the **pipe**
//! transport (stdin/stdout, the default):
//!
//! ```text
//! coordinator → worker     worker → coordinator
//! ------------------       --------------------
//! Init                     InitOk | Failed        (handshake, once; both
//!                                                  directions carry and
//!                                                  verify PROTOCOL_VERSION
//!                                                  = 5 before anything else;
//!                                                  Init may carry a resume
//!                                                  payload — see below)
//! HalfStep{round}          Snapshot{losses,halves}  (phase 1: the shipped
//!                                                    RoundDigest payload;
//!                                                    rows at the configured
//!                                                    compression level)
//! Aggregate{round,         RoundDone{byz_seen, received,
//!   digest, halves}          peer_bytes, params}  (phases 3–5; both row
//!                                                  blocks always raw f32)
//! Shutdown (or EOF)        —                      (worker exits 0)
//! GetState{round}          State{params, momentum,
//!                            carried}             (recovery state sync; sent
//!                                                  only when checkpointing
//!                                                  or restart supervision
//!                                                  is live)
//! ```
//!
//! On the **socket** transport each worker additionally binds its own
//! listener and *serves pulls to its peers directly*, so the coordinator
//! never broadcasts the O(h·d) table — only the digest and the per-round
//! pull **routing table**:
//!
//! ```text
//! worker → coordinator      coordinator → worker     worker w → worker v
//! --------------------      ------------------       -------------------
//! PeerHello{worker,                                  (control connect;
//!   incarnation, listen}                              version-checked, v5;
//!                                                     incarnation > 0 marks
//!                                                     a supervised respawn)
//!                           Init                     (version-checked, v5)
//! InitOk | Failed
//!                           Peers{start,len,addr}*   (the address book)
//!                           HalfStep{round}
//! Snapshot{losses,halves}                            (compressed rows)
//!                           AggregateRouted{round,
//!                             digest, routes}        PeerHello{worker}
//!                                                    PullRequest{round,rows}
//!                                                    ← PullReply{round,rows}
//!                                                      (compressed rows)
//!                                                      | Deny{message}
//! RoundDone{...}
//!                           Shutdown (or EOF)
//! ```
//!
//! `Snapshot` is the promoted [`crate::coordinator::Trainer`] round
//! digest: the shard's half-step rows in ascending honest order plus its
//! per-node losses. The coordinator folds all shards' snapshots — in
//! ascending honest-node order, exactly as the in-process engine folds
//! borrowed rows — into the global [`HonestDigest`]. On the pipe path it
//! then broadcasts that digest and the full half-step table back in
//! `Aggregate`; on the socket path it ships `AggregateRouted` instead —
//! the digest plus, per owned victim, the ordered list of global node
//! ids the victim receives from this round — and each worker fetches the
//! honest rows it needs from the owning peer's listener. Rows travel as
//! IEEE bit patterns either way and per-victim receive order is dictated
//! by the routing table, so both transports are bit-identical with the
//! in-process engine.
//!
//! Every worker reply echoes the request's round, and `PullReply` echoes
//! the `PullRequest` round, so a reply stranded by an aborted round can
//! never be silently consumed as a later round's. Any processing error
//! on the worker is reported as `Failed{message}` (or `Deny{message}`
//! peer-side) before the stream closes, so the coordinator surfaces the
//! root cause rather than a bare broken pipe.

use super::codec::{self, EncodedRows, RowCodec};
use super::{Reader, Writer};
use crate::attacks::HonestDigest;
use anyhow::{bail, Result};

/// Bumped on any layout change; every side verifies it in its handshake
/// (`Init`/`InitOk` on the control channel, `PeerHello` peer-side).
/// v2: socket transport — `PeerHello`/`Peers`/`AggregateRouted`/
/// `PullRequest`/`PullReply`; `RoundDone` gained `peer_bytes`.
/// v3: asynchronous rounds — `AsyncRound` carries the virtual-clock
/// staleness schedule ahead of each `HalfStep` when `[async]` is live.
/// v4: row-block compression — `Snapshot`/`PullReply` row blocks travel
/// at the configured `[wire] compression` level (`none`/`f16`/`q8`,
/// ambient from the `Init` config; see [`super::codec`]). At `none`
/// every frame is byte-identical to v3 except this version field.
/// v5: crash recovery — `Init` carries a resume payload (`resume_round`
/// + the shard's committed params/momentum/carried rows + the
/// compression delta reference; all empty on a fresh start), `PeerHello`
/// carries the worker's incarnation number (0 = first spawn) so stale
/// peers are identified after a supervised respawn, `RoundDone` reports
/// the peer-pull retry count, and the `GetState`/`State` pair syncs
/// worker state to the coordinator for durable checkpoints and restart
/// mirrors. See [`crate::coordinator::checkpoint`].
pub const PROTOCOL_VERSION: u32 = 5;

mod tag {
    pub const INIT: u8 = 0x01;
    pub const HALF_STEP: u8 = 0x02;
    pub const AGGREGATE: u8 = 0x03;
    pub const SHUTDOWN: u8 = 0x04;
    pub const PEERS: u8 = 0x05;
    pub const AGGREGATE_ROUTED: u8 = 0x06;
    pub const ASYNC_ROUND: u8 = 0x07;
    pub const GET_STATE: u8 = 0x08;
    pub const PEER_HELLO: u8 = 0x40;
    pub const PULL_REQUEST: u8 = 0x41;
    pub const PULL_REPLY: u8 = 0x42;
    pub const PEER_DENY: u8 = 0x43;
    pub const INIT_OK: u8 = 0x81;
    pub const SNAPSHOT: u8 = 0x82;
    pub const ROUND_DONE: u8 = 0x83;
    pub const STATE: u8 = 0x84;
    pub const FAILED: u8 = 0xFF;
}

/// The resume payload an `Init` may carry (v5): the boundary state a
/// respawned or checkpoint-resumed worker installs before its first
/// round. `round` is the number of *completed* rounds; the worker
/// replays its data-RNG cursor deterministically through rounds
/// `0..round` (one `next_batches` per PARTICIPATE-active round — the
/// only stateful draw on the shard path), so nothing about the RNG needs
/// to travel. The default value (`round = 0`, everything empty) is a
/// fresh start and costs a handful of bytes on the wire.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireResume {
    /// Rounds completed at the boundary this state captures.
    pub round: u64,
    /// Compression delta reference (previous round's digest mean as f32);
    /// empty at `compression = none` or round 0.
    pub wire_ref: Vec<f32>,
    /// Committed params rows for the shard's honest range, ascending.
    pub params: Vec<Vec<f32>>,
    /// Momentum rows, same shape as `params`. Momentum is the one piece
    /// of worker state the coordinator cannot recompute, which is why it
    /// travels here and in `State`.
    pub momentum: Vec<Vec<f32>>,
    /// Async carry rows (`None` = nothing carried for that node).
    pub carried: Vec<Option<Vec<f32>>>,
}

impl WireResume {
    /// True for the default payload: a fresh (non-resumed) start.
    pub fn is_fresh(&self) -> bool {
        self.round == 0 && self.params.is_empty()
    }
}

fn put_resume(w: &mut Writer, res: &WireResume) {
    w.put_u64(res.round);
    w.put_u32(res.wire_ref.len() as u32);
    for &x in &res.wire_ref {
        w.put_f32(x);
    }
    w.put_f32_rows(&res.params);
    w.put_f32_rows(&res.momentum);
    w.put_opt_f32_rows(&res.carried);
}

fn read_resume(r: &mut Reader<'_>) -> Result<WireResume> {
    let round = r.u64()?;
    let n = r.u32()? as usize;
    if n > r.remaining() / 4 {
        bail!(
            "wire: resume reference claims {n} coords with only {} bytes left",
            r.remaining()
        );
    }
    let mut wire_ref = Vec::with_capacity(n);
    for _ in 0..n {
        wire_ref.push(r.f32()?);
    }
    Ok(WireResume {
        round,
        wire_ref,
        params: r.f32_rows()?,
        momentum: r.f32_rows()?,
        carried: r.opt_f32_rows()?,
    })
}

/// Coordinator → worker.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// Handshake: the full experiment config (TOML text), this worker's
    /// index, the total process-shard count it partitions against, and
    /// the resume payload (fresh default on a first spawn; the boundary
    /// state to install on a checkpoint resume or supervised respawn).
    Init {
        config_toml: String,
        worker: u32,
        procs: u32,
        resume: WireResume,
    },
    /// Run phase 1 (local half-steps) for round `round`.
    HalfStep { round: u64 },
    /// Virtual-clock schedule for round `round` (async engine only; sent
    /// before `HalfStep`): per owned honest node (ascending), its
    /// staleness — 0 = fresh this round, `k ≥ 1` = last fresh `k` rounds
    /// ago, capped at `max_staleness + 1` (beyond the bound). The worker
    /// applies the served-row policy to its own rows before publishing
    /// its snapshot and discards non-fresh aggregation results after
    /// commit.
    AsyncRound { round: u64, stale: Vec<u32> },
    /// Phases 3–5 (pipe transport): the folded honest digest plus the
    /// full half-step table (h rows, ascending honest order) to serve
    /// pulls from.
    Aggregate {
        round: u64,
        digest: WireDigest,
        halves: Vec<Vec<f32>>,
    },
    /// Peer address book (socket transport, once after `InitOk`): per
    /// worker process, the honest range it owns and the listener address
    /// it serves pulls on.
    Peers { peers: Vec<PeerEntry> },
    /// Phases 3–5 (socket transport): the folded honest digest plus the
    /// per-round pull **routing table** — per owned victim (ascending),
    /// the ordered global node ids it receives from this round. The
    /// worker crafts Byzantine rows against the digest and fetches the
    /// honest rows it lacks from the owning peers' listeners; no
    /// committed row travels that the table does not require.
    AggregateRouted {
        round: u64,
        digest: WireDigest,
        routes: Vec<Vec<u32>>,
    },
    /// Recovery state sync: report the boundary state after `round`
    /// completed rounds (checkpointing / restart supervision only). The
    /// worker answers with `State` from its current committed state;
    /// any earlier queued reply frames precede it on the stream, which
    /// is what lets the coordinator use the exchange as a drain barrier
    /// before re-driving a failed round.
    GetState { round: u64 },
    /// Orderly exit (EOF on stdin means the same).
    Shutdown,
}

/// One worker's entry in the `Peers` address book.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeerEntry {
    /// First honest index of the worker's contiguous range.
    pub start: u64,
    /// Honest nodes in the range.
    pub len: u64,
    /// Textual listener address (`unix:<path>` / `tcp:<host:port>`).
    pub addr: String,
}

/// Worker ↔ worker pull-serving protocol (socket transport only).
#[derive(Clone, Debug, PartialEq)]
pub enum PeerMsg {
    /// Connection opener, both on the coordinator control socket and on
    /// peer pull connections: identifies the dialing worker (and, on the
    /// control socket, the listener address it serves pulls on).
    /// Version-checked like `Init`. `incarnation` counts supervised
    /// respawns of the worker (0 = first spawn): the coordinator's
    /// respawn accept verifies it, so a zombie from a previous
    /// incarnation can never complete the handshake.
    Hello {
        worker: u32,
        incarnation: u32,
        listen: String,
    },
    /// Fetch the given honest rows (global honest indices, owned by the
    /// serving worker) of round `round`'s half-step table.
    PullRequest { round: u64, rows: Vec<u32> },
    /// The requested rows, in request order. `round` echoes the request.
    PullReply { round: u64, rows: Vec<Vec<f32>> },
    /// Refusal with a root cause (stale round, out-of-range row, …).
    Deny { message: String },
}

/// Worker → coordinator.
#[derive(Clone, Debug, PartialEq)]
pub enum FromWorker {
    /// Handshake echo: the shard range the worker derived for itself.
    InitOk { start: u64, len: u64, d: u64 },
    /// The shipped round digest: per-node losses + half-step rows for
    /// the worker's shard, ascending honest order. `round` echoes the
    /// request, so a reply stranded by an aborted round can never be
    /// silently consumed as a later round's.
    Snapshot {
        round: u64,
        losses: Vec<f64>,
        halves: Vec<Vec<f32>>,
    },
    /// Round completed: per-node Byzantine-rows-seen and delivered-model
    /// counts, the bytes this worker **fetched from peers' listeners**
    /// this round (pull requests + replies + one-time hellos; 0 on the
    /// pipe transport — each peer transfer is counted exactly once, on
    /// the pulling side, so serving workers report 0 for rows they
    /// shipped), plus the committed params (the coordinator's mirror
    /// rows). `round` echoes the request (see [`FromWorker::Snapshot`]).
    RoundDone {
        round: u64,
        byz_seen: Vec<u32>,
        received: Vec<u32>,
        peer_bytes: u64,
        /// Extra peer-pull/dial attempts the retry policy consumed this
        /// round (0 = every pull succeeded first try) — the worker-side
        /// half of the `peer_retries_per_round` ledger.
        retries: u32,
        params: Vec<Vec<f32>>,
    },
    /// Recovery state sync reply (see [`ToWorker::GetState`]): the
    /// worker's boundary state after `round` completed rounds, in the
    /// same shape as [`WireResume`] minus the delta reference (the
    /// coordinator owns the digest and derives it).
    State {
        round: u64,
        params: Vec<Vec<f32>>,
        momentum: Vec<Vec<f32>>,
        carried: Vec<Option<Vec<f32>>>,
    },
    /// Terminal worker-side error, shipped before exiting. Not always
    /// fatal to the *run*: the supervisor treats a `Failed` during the
    /// aggregate phase as a round abort and re-drives the round if the
    /// restart budget allows.
    Failed { message: String },
}

/// [`HonestDigest`] as a wire payload (f64 bit patterns).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireDigest {
    pub count: u64,
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub prev_mean: Vec<f64>,
}

impl WireDigest {
    pub fn from_digest(d: &HonestDigest) -> WireDigest {
        WireDigest {
            count: d.count as u64,
            mean: d.mean.clone(),
            std: d.std.clone(),
            prev_mean: d.prev_mean.clone(),
        }
    }

    pub fn into_digest(self) -> HonestDigest {
        HonestDigest {
            count: self.count as usize,
            mean: self.mean,
            std: self.std,
            prev_mean: self.prev_mean,
        }
    }
}

fn put_digest(w: &mut Writer, count: u64, mean: &[f64], std: &[f64], prev_mean: &[f64]) {
    w.put_u64(count);
    w.put_f64s(mean);
    w.put_f64s(std);
    w.put_f64s(prev_mean);
}

fn read_digest(r: &mut Reader<'_>) -> Result<WireDigest> {
    Ok(WireDigest {
        count: r.u64()?,
        mean: r.f64s()?,
        std: r.f64s()?,
        prev_mean: r.f64s()?,
    })
}

// ---------------------------------------------------------------------------
// Allocation-light encoders for the per-round hot paths (take references;
// the enum encoders below delegate to these).
// ---------------------------------------------------------------------------

pub fn encode_init(config_toml: &str, worker: u32, procs: u32, resume: &WireResume) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::INIT);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u32(worker);
    w.put_u32(procs);
    w.put_str(config_toml);
    put_resume(&mut w, resume);
    w.into_bytes()
}

pub fn encode_get_state(round: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::GET_STATE);
    w.put_u64(round);
    w.into_bytes()
}

pub fn encode_state<R: AsRef<[f32]>>(
    round: u64,
    params: &[R],
    momentum: &[R],
    carried: &[Option<Vec<f32>>],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::STATE);
    w.put_u64(round);
    w.put_f32_rows(params);
    w.put_f32_rows(momentum);
    w.put_opt_f32_rows(carried);
    w.into_bytes()
}

pub fn encode_half_step(round: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::HALF_STEP);
    w.put_u64(round);
    w.into_bytes()
}

pub fn encode_async_round(round: u64, stale: &[u32]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::ASYNC_ROUND);
    w.put_u64(round);
    w.put_u32s(stale);
    w.into_bytes()
}

pub fn encode_aggregate<R: AsRef<[f32]>>(
    round: u64,
    digest: &HonestDigest,
    halves: &[R],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::AGGREGATE);
    w.put_u64(round);
    put_digest(
        &mut w,
        digest.count as u64,
        &digest.mean,
        &digest.std,
        &digest.prev_mean,
    );
    w.put_f32_rows(halves);
    w.into_bytes()
}

pub fn encode_shutdown() -> Vec<u8> {
    vec![tag::SHUTDOWN]
}

pub fn encode_init_ok(start: u64, len: u64, d: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::INIT_OK);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u64(start);
    w.put_u64(len);
    w.put_u64(d);
    w.into_bytes()
}

pub fn encode_snapshot<R: AsRef<[f32]>>(round: u64, losses: &[f64], halves: &[R]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::SNAPSHOT);
    w.put_u64(round);
    w.put_f64s(losses);
    w.put_f32_rows(halves);
    w.into_bytes()
}

/// `Snapshot` with a pre-encoded row block (compression on): the worker
/// encodes its rows once at the publish point and this frames the cached
/// block verbatim. Byte-identical to [`encode_snapshot`] at `none`.
pub fn encode_snapshot_block(round: u64, losses: &[f64], block: &EncodedRows) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::SNAPSHOT);
    w.put_u64(round);
    w.put_f64s(losses);
    codec::put_block(&mut w, block);
    w.into_bytes()
}

/// `PullReply` from cached encoded segments (compression on; see
/// [`encode_snapshot_block`]). Byte-identical to [`encode_pull_reply`]
/// at `none`.
pub fn encode_pull_reply_block(round: u64, block: &EncodedRows) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PULL_REPLY);
    w.put_u64(round);
    codec::put_block(&mut w, block);
    w.into_bytes()
}

pub fn encode_round_done<R: AsRef<[f32]>>(
    round: u64,
    byz_seen: &[u32],
    received: &[u32],
    peer_bytes: u64,
    retries: u32,
    params: &[R],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::ROUND_DONE);
    w.put_u64(round);
    w.put_u32s(byz_seen);
    w.put_u32s(received);
    w.put_u64(peer_bytes);
    w.put_u32(retries);
    w.put_f32_rows(params);
    w.into_bytes()
}

pub fn encode_peers(peers: &[PeerEntry]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PEERS);
    w.put_u32(peers.len() as u32);
    for p in peers {
        w.put_u64(p.start);
        w.put_u64(p.len);
        w.put_str(&p.addr);
    }
    w.into_bytes()
}

/// Routing table encoding: `[u32 victims]` then per victim a `u32`-count
/// list of global node ids (the ordered receive set).
fn put_routes(w: &mut Writer, routes: &[Vec<u32>]) {
    w.put_u32(routes.len() as u32);
    for r in routes {
        w.put_u32s(r);
    }
}

fn read_routes(r: &mut Reader<'_>) -> Result<Vec<Vec<u32>>> {
    let n = r.u32()? as usize;
    // each victim row costs at least its 4-byte count prefix: bound the
    // allocation before trusting a corrupt count
    if n > r.remaining() / 4 {
        bail!(
            "wire: routing table claims {n} victims with only {} bytes left",
            r.remaining()
        );
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.u32s()?);
    }
    Ok(out)
}

/// Socket-transport aggregate kick-off: digest + routing table, no rows.
pub fn encode_aggregate_routed(
    round: u64,
    digest: &HonestDigest,
    routes: &[Vec<u32>],
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::AGGREGATE_ROUTED);
    w.put_u64(round);
    put_digest(
        &mut w,
        digest.count as u64,
        &digest.mean,
        &digest.std,
        &digest.prev_mean,
    );
    put_routes(&mut w, routes);
    w.into_bytes()
}

// --- peer protocol (worker ↔ worker pull serving) --------------------------

pub fn encode_peer_hello(worker: u32, incarnation: u32, listen: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PEER_HELLO);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u32(worker);
    w.put_u32(incarnation);
    w.put_str(listen);
    w.into_bytes()
}

pub fn encode_pull_request(round: u64, rows: &[u32]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PULL_REQUEST);
    w.put_u64(round);
    w.put_u32s(rows);
    w.into_bytes()
}

pub fn encode_pull_reply<R: AsRef<[f32]>>(round: u64, rows: &[R]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PULL_REPLY);
    w.put_u64(round);
    w.put_f32_rows(rows);
    w.into_bytes()
}

pub fn encode_peer_deny(message: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::PEER_DENY);
    w.put_str(message);
    w.into_bytes()
}

pub fn encode_peer(msg: &PeerMsg) -> Vec<u8> {
    match msg {
        PeerMsg::Hello {
            worker,
            incarnation,
            listen,
        } => encode_peer_hello(*worker, *incarnation, listen),
        PeerMsg::PullRequest { round, rows } => encode_pull_request(*round, rows),
        PeerMsg::PullReply { round, rows } => encode_pull_reply(*round, rows),
        PeerMsg::Deny { message } => encode_peer_deny(message),
    }
}

/// Decode a peer message at the round's [`RowCodec`]: `PullReply` row
/// blocks are decoded against the codec's reference (the decode is part
/// of the wire spec — the returned rows are the bits to aggregate).
pub fn decode_peer_c(buf: &[u8], rc: &RowCodec<'_>) -> Result<PeerMsg> {
    let mut r = Reader::new(buf);
    let msg = match r.u8()? {
        tag::PEER_HELLO => {
            let version = r.u32()?;
            if version != PROTOCOL_VERSION {
                bail!(
                    "wire: protocol version mismatch (peer {version}, ours {PROTOCOL_VERSION})"
                );
            }
            PeerMsg::Hello {
                worker: r.u32()?,
                incarnation: r.u32()?,
                listen: r.string()?,
            }
        }
        tag::PULL_REQUEST => PeerMsg::PullRequest {
            round: r.u64()?,
            rows: r.u32s()?,
        },
        tag::PULL_REPLY => PeerMsg::PullReply {
            round: r.u64()?,
            rows: codec::read_rows(&mut r, rc)?,
        },
        tag::PEER_DENY => PeerMsg::Deny {
            message: r.string()?,
        },
        other => bail!("wire: unknown peer message tag {other:#04x}"),
    };
    r.finish()?;
    Ok(msg)
}

/// [`decode_peer_c`] at `compression = none` (v3-compatible blocks).
pub fn decode_peer(buf: &[u8]) -> Result<PeerMsg> {
    decode_peer_c(buf, &RowCodec::none())
}

pub fn encode_failed(message: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(tag::FAILED);
    w.put_str(message);
    w.into_bytes()
}

// ---------------------------------------------------------------------------
// Enum encode/decode (decode side of the protocol; encode kept for tests
// and symmetry)
// ---------------------------------------------------------------------------

pub fn encode_to_worker(msg: &ToWorker) -> Vec<u8> {
    match msg {
        ToWorker::Init {
            config_toml,
            worker,
            procs,
            resume,
        } => encode_init(config_toml, *worker, *procs, resume),
        ToWorker::HalfStep { round } => encode_half_step(*round),
        ToWorker::AsyncRound { round, stale } => encode_async_round(*round, stale),
        ToWorker::Aggregate {
            round,
            digest,
            halves,
        } => {
            let mut w = Writer::new();
            w.put_u8(tag::AGGREGATE);
            w.put_u64(*round);
            put_digest(
                &mut w,
                digest.count,
                &digest.mean,
                &digest.std,
                &digest.prev_mean,
            );
            w.put_f32_rows(halves);
            w.into_bytes()
        }
        ToWorker::Peers { peers } => encode_peers(peers),
        ToWorker::AggregateRouted {
            round,
            digest,
            routes,
        } => {
            let mut w = Writer::new();
            w.put_u8(tag::AGGREGATE_ROUTED);
            w.put_u64(*round);
            put_digest(
                &mut w,
                digest.count,
                &digest.mean,
                &digest.std,
                &digest.prev_mean,
            );
            put_routes(&mut w, routes);
            w.into_bytes()
        }
        ToWorker::GetState { round } => encode_get_state(*round),
        ToWorker::Shutdown => encode_shutdown(),
    }
}

pub fn decode_to_worker(buf: &[u8]) -> Result<ToWorker> {
    let mut r = Reader::new(buf);
    let msg = match r.u8()? {
        tag::INIT => {
            let version = r.u32()?;
            if version != PROTOCOL_VERSION {
                bail!(
                    "wire: protocol version mismatch (peer {version}, ours {PROTOCOL_VERSION})"
                );
            }
            let worker = r.u32()?;
            let procs = r.u32()?;
            let config_toml = r.string()?;
            let resume = read_resume(&mut r)?;
            ToWorker::Init {
                config_toml,
                worker,
                procs,
                resume,
            }
        }
        tag::HALF_STEP => ToWorker::HalfStep { round: r.u64()? },
        tag::ASYNC_ROUND => ToWorker::AsyncRound {
            round: r.u64()?,
            stale: r.u32s()?,
        },
        tag::AGGREGATE => {
            let round = r.u64()?;
            let digest = read_digest(&mut r)?;
            let halves = r.f32_rows()?;
            ToWorker::Aggregate {
                round,
                digest,
                halves,
            }
        }
        tag::PEERS => {
            let n = r.u32()? as usize;
            // each entry costs at least start+len+addr-count = 20 bytes
            if n > r.remaining() / 20 {
                bail!(
                    "wire: peer book claims {n} entries with only {} bytes left",
                    r.remaining()
                );
            }
            let mut peers = Vec::with_capacity(n);
            for _ in 0..n {
                peers.push(PeerEntry {
                    start: r.u64()?,
                    len: r.u64()?,
                    addr: r.string()?,
                });
            }
            ToWorker::Peers { peers }
        }
        tag::AGGREGATE_ROUTED => {
            let round = r.u64()?;
            let digest = read_digest(&mut r)?;
            let routes = read_routes(&mut r)?;
            ToWorker::AggregateRouted {
                round,
                digest,
                routes,
            }
        }
        tag::GET_STATE => ToWorker::GetState { round: r.u64()? },
        tag::SHUTDOWN => ToWorker::Shutdown,
        other => bail!("wire: unknown coordinator message tag {other:#04x}"),
    };
    r.finish()?;
    Ok(msg)
}

pub fn encode_from_worker(msg: &FromWorker) -> Vec<u8> {
    match msg {
        FromWorker::InitOk { start, len, d } => encode_init_ok(*start, *len, *d),
        FromWorker::Snapshot {
            round,
            losses,
            halves,
        } => encode_snapshot(*round, losses, halves),
        FromWorker::RoundDone {
            round,
            byz_seen,
            received,
            peer_bytes,
            retries,
            params,
        } => encode_round_done(*round, byz_seen, received, *peer_bytes, *retries, params),
        FromWorker::State {
            round,
            params,
            momentum,
            carried,
        } => encode_state(*round, params, momentum, carried),
        FromWorker::Failed { message } => encode_failed(message),
    }
}

/// Decode a worker message at the round's [`RowCodec`]: `Snapshot` row
/// blocks are decoded against the codec's reference (the decode is part
/// of the wire spec — the returned rows are the bits to aggregate).
/// `RoundDone` params always travel raw.
pub fn decode_from_worker_c(buf: &[u8], rc: &RowCodec<'_>) -> Result<FromWorker> {
    let mut r = Reader::new(buf);
    let msg = match r.u8()? {
        tag::INIT_OK => {
            let version = r.u32()?;
            if version != PROTOCOL_VERSION {
                bail!(
                    "wire: protocol version mismatch (peer {version}, ours {PROTOCOL_VERSION})"
                );
            }
            FromWorker::InitOk {
                start: r.u64()?,
                len: r.u64()?,
                d: r.u64()?,
            }
        }
        tag::SNAPSHOT => FromWorker::Snapshot {
            round: r.u64()?,
            losses: r.f64s()?,
            halves: codec::read_rows(&mut r, rc)?,
        },
        tag::ROUND_DONE => FromWorker::RoundDone {
            round: r.u64()?,
            byz_seen: r.u32s()?,
            received: r.u32s()?,
            peer_bytes: r.u64()?,
            retries: r.u32()?,
            params: r.f32_rows()?,
        },
        tag::STATE => FromWorker::State {
            round: r.u64()?,
            params: r.f32_rows()?,
            momentum: r.f32_rows()?,
            carried: r.opt_f32_rows()?,
        },
        tag::FAILED => FromWorker::Failed {
            message: r.string()?,
        },
        other => bail!("wire: unknown worker message tag {other:#04x}"),
    };
    r.finish()?;
    Ok(msg)
}

/// [`decode_from_worker_c`] at `compression = none` (v3-compatible
/// blocks).
pub fn decode_from_worker(buf: &[u8]) -> Result<FromWorker> {
    decode_from_worker_c(buf, &RowCodec::none())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_worker_messages_round_trip() {
        let msgs = [
            ToWorker::Init {
                config_toml: "task = \"tiny\"".into(),
                worker: 1,
                procs: 3,
                resume: WireResume::default(),
            },
            ToWorker::Init {
                config_toml: "task = \"tiny\"".into(),
                worker: 0,
                procs: 2,
                resume: WireResume {
                    round: 17,
                    wire_ref: vec![0.5, -1.25],
                    params: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                    momentum: vec![vec![0.1, 0.2], vec![-0.0, 0.4]],
                    carried: vec![None, Some(vec![9.0, 8.0])],
                },
            },
            ToWorker::HalfStep { round: 42 },
            ToWorker::GetState { round: 42 },
            ToWorker::AsyncRound {
                round: 42,
                stale: vec![0, 3, 1, 0],
            },
            ToWorker::Aggregate {
                round: 7,
                digest: WireDigest {
                    count: 5,
                    mean: vec![0.5, -0.25],
                    std: vec![1.0, 0.0],
                    prev_mean: vec![-0.0, 2.0],
                },
                halves: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            },
            ToWorker::Peers {
                peers: vec![
                    PeerEntry {
                        start: 0,
                        len: 5,
                        addr: "unix:/tmp/w0.sock".into(),
                    },
                    PeerEntry {
                        start: 5,
                        len: 4,
                        addr: "tcp:127.0.0.1:9009".into(),
                    },
                ],
            },
            ToWorker::AggregateRouted {
                round: 8,
                digest: WireDigest {
                    count: 3,
                    mean: vec![1.0],
                    std: vec![0.5],
                    prev_mean: vec![0.0],
                },
                routes: vec![vec![4, 1, 9], vec![], vec![2]],
            },
            ToWorker::Shutdown,
        ];
        for msg in &msgs {
            let buf = encode_to_worker(msg);
            assert_eq!(&decode_to_worker(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn from_worker_messages_round_trip() {
        let msgs = [
            FromWorker::InitOk {
                start: 3,
                len: 4,
                d: 10,
            },
            FromWorker::Snapshot {
                round: 11,
                losses: vec![0.125, 2.0],
                halves: vec![vec![-1.5f32; 3], vec![0.0f32; 3]],
            },
            FromWorker::RoundDone {
                round: 12,
                byz_seen: vec![0, 2],
                received: vec![6, 6],
                peer_bytes: 12345,
                retries: 2,
                params: vec![vec![9.0f32, 8.0], vec![7.0, 6.0]],
            },
            FromWorker::State {
                round: 12,
                params: vec![vec![9.0f32, 8.0], vec![7.0, 6.0]],
                momentum: vec![vec![0.5f32, 0.0], vec![-1.0, 2.0]],
                carried: vec![Some(vec![1.0, -1.0]), None],
            },
            FromWorker::Failed {
                message: "boom".into(),
            },
        ];
        for msg in &msgs {
            let buf = encode_from_worker(msg);
            assert_eq!(&decode_from_worker(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn peer_messages_round_trip() {
        let msgs = [
            PeerMsg::Hello {
                worker: 2,
                incarnation: 3,
                listen: "unix:/tmp/w2.sock".into(),
            },
            PeerMsg::PullRequest {
                round: 3,
                rows: vec![0, 7, 4],
            },
            PeerMsg::PullReply {
                round: 3,
                rows: vec![vec![1.5f32, -0.0], vec![2.0, 4.0]],
            },
            PeerMsg::Deny {
                message: "stale round".into(),
            },
        ];
        for msg in &msgs {
            let buf = encode_peer(msg);
            assert_eq!(&decode_peer(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn peer_hello_version_mismatch_detected() {
        let mut buf = encode_peer_hello(1, 0, "unix:/x");
        buf[1] ^= 0x10;
        let err = decode_peer(&buf).unwrap_err().to_string();
        assert!(err.contains("version mismatch"), "{err}");
    }

    #[test]
    fn corrupt_resume_counts_bounded() {
        // an absurd delta-reference count in Init must not allocate: the
        // count is bounds-checked against the remaining payload. A fresh
        // resume payload is the 40-byte tail [round u64][ref n u32]
        // [params rows,d][momentum rows,d][carried n u32][present rows,d],
        // so the ref count sits at tail_start + 8.
        let mut corrupt = encode_init("task = \"tiny\"", 0, 1, &WireResume::default());
        let tail = corrupt.len() - 40;
        corrupt[tail + 8..tail + 12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_to_worker(&corrupt).unwrap_err().to_string();
        assert!(err.contains("resume reference"), "{err}");

        // a flags/row-count mismatch in the sparse carried set is named:
        // flip the second presence flag on, so the flags claim 2 rows
        // while the block carries 1. The carried set is the frame tail
        // [n u32=2][flag][flag][rows=1][d=2][2·f32], putting the second
        // flag 17 bytes from the end.
        let res = WireResume {
            carried: vec![Some(vec![1.0f32, 2.0]), None],
            ..WireResume::default()
        };
        let mut buf = encode_init("task = \"tiny\"", 0, 1, &res);
        let flag2 = buf.len() - 17;
        assert_eq!(buf[flag2], 0);
        buf[flag2] = 1;
        let err = decode_to_worker(&buf).unwrap_err().to_string();
        assert!(err.contains("flags mark 2 present"), "{err}");
    }

    #[test]
    fn corrupt_route_and_peer_counts_bounded() {
        // absurd victim count in AggregateRouted must not allocate
        let digest = HonestDigest::new(1);
        let mut buf = encode_aggregate_routed(1, &digest, &[]);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_to_worker(&buf).is_err());
        // absurd peer count in Peers likewise
        let mut buf = encode_peers(&[]);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_to_worker(&buf).is_err());
    }

    #[test]
    fn compressed_snapshot_and_pull_reply_round_trip() {
        let reference = [0.5f32, -1.0, 2.0];
        for comp in [codec::Compression::F16, codec::Compression::Q8] {
            let rc = RowCodec::new(comp, &reference);
            let mut rows = vec![vec![1.0f32, 2.0, 3.0], vec![0.5, -1.0, 2.0]];
            let block = codec::transform_rows(&rc, &mut rows).unwrap();
            let snap = encode_snapshot_block(9, &[0.5, 0.25], &block);
            match decode_from_worker_c(&snap, &rc).unwrap() {
                FromWorker::Snapshot {
                    round,
                    losses,
                    halves,
                } => {
                    assert_eq!(round, 9);
                    assert_eq!(losses, vec![0.5, 0.25]);
                    // the wire decode reproduces the publish transform
                    assert_eq!(halves, rows);
                }
                other => panic!("expected Snapshot, got {other:?}"),
            }
            let reply = encode_pull_reply_block(9, &block.gather(&[1, 0]).unwrap());
            match decode_peer_c(&reply, &rc).unwrap() {
                PeerMsg::PullReply { round, rows: got } => {
                    assert_eq!(round, 9);
                    assert_eq!(got, vec![rows[1].clone(), rows[0].clone()]);
                }
                other => panic!("expected PullReply, got {other:?}"),
            }
        }
    }

    #[test]
    fn none_block_frames_are_byte_identical_to_legacy() {
        let rows = vec![vec![1.0f32, -2.0], vec![0.0, 4.5]];
        let block = codec::encode_rows(&RowCodec::none(), &rows);
        assert_eq!(
            encode_snapshot_block(3, &[1.0], &block),
            encode_snapshot(3, &[1.0], &rows)
        );
        assert_eq!(encode_pull_reply_block(3, &block), encode_pull_reply(3, &rows));
    }

    #[test]
    fn digest_conversion_is_lossless() {
        let mut d = HonestDigest::new(3);
        let r1 = [1.0f32, 2.0, 3.0];
        let r2 = [3.0f32, 2.0, 1.0];
        d.recompute(&[&r1, &r2], &[&r2, &r1], true);
        let back = WireDigest::from_digest(&d).into_digest();
        assert_eq!(back.count, d.count);
        assert_eq!(back.mean, d.mean);
        assert_eq!(back.std, d.std);
        assert_eq!(back.prev_mean, d.prev_mean);
    }

    #[test]
    fn version_mismatch_detected() {
        let mut buf = encode_init("x", 0, 1, &WireResume::default());
        buf[1] ^= 0x40; // corrupt the version field
        assert!(decode_to_worker(&buf).is_err());
    }

    #[test]
    fn unknown_tags_and_truncations_error() {
        assert!(decode_to_worker(&[0x7E]).is_err());
        assert!(decode_from_worker(&[0x00]).is_err());
        let full = encode_to_worker(&ToWorker::HalfStep { round: 1 });
        for cut in 0..full.len() {
            assert!(decode_to_worker(&full[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage rejected
        let mut padded = full.clone();
        padded.push(0);
        assert!(decode_to_worker(&padded).is_err());
    }
}
