//! The lint engine: walks a source tree (or a single in-memory source),
//! lexes each file, carves out regions that are out of scope for a rule
//! (`#[cfg(test)]` bodies, excluded inline modules, skipped macro
//! invocations), applies every in-scope rule's matcher, and honors
//! per-rule exemption markers on the same or preceding line.

use std::collections::BTreeSet;
use std::fs;
use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::lexer::{lex, Lexed, Token};
use super::rules::{Rule, Severity};

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the analysis root, `/`-separated.
    pub file: String,
    /// 1-based line of the first matched token.
    pub line: u32,
    pub rule: &'static str,
    pub severity: Severity,
    pub message: String,
    /// The trimmed source line, for human reports.
    pub snippet: String,
}

/// The result of linting a tree.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub rules_run: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Lint a single source text as if it lived at `rel_path` under the
/// analysis root. This is the unit-testable core: fixtures call it with
/// virtual paths (`"coordinator/fixture.rs"`) to pick rule scopes.
pub fn lint_source(rel_path: &str, src: &str, rules: &[Rule]) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = &lexed.tokens;
    let lines: Vec<&str> = src.lines().collect();
    let test_ranges = attr_ranges(toks, &["cfg", "(", "test", ")"]);
    let mut findings = Vec::new();

    for rule in rules.iter().filter(|r| r.applies_to(rel_path)) {
        let mut skip = test_ranges.clone();
        for (suffix, mod_name) in rule.exclude_mods {
            if rel_path.ends_with(suffix) {
                skip.extend(mod_ranges(toks, mod_name));
            }
        }
        for mac in rule.skip_macros {
            skip.extend(macro_ranges(toks, mac));
        }
        let marker = rule.marker();
        let mut flagged: BTreeSet<u32> = BTreeSet::new();
        for i in 0..toks.len() {
            if skip.iter().any(|r| r.contains(&i)) {
                continue;
            }
            let Some(what) = rule.matcher.matches_at(toks, i) else {
                continue;
            };
            let line = toks[i].line;
            if flagged.contains(&line) || lexed.exempted(&marker, line) {
                continue;
            }
            flagged.insert(line);
            findings.push(Finding {
                file: rel_path.to_string(),
                line,
                rule: rule.id,
                severity: rule.severity,
                message: format!("{what}: {}", rule.invariant),
                snippet: lines
                    .get(line as usize - 1)
                    .map(|l| l.trim().to_string())
                    .unwrap_or_default(),
            });
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

/// Lint every `.rs` file under `root` (recursively, in sorted order so
/// reports are deterministic). If `root` contains a `rust/src` directory
/// — i.e. the repo root was passed — the walk descends into it, so rule
/// scopes stay relative to the source root either way.
pub fn lint_tree(root: &Path, rules: &[Rule]) -> Result<Report> {
    let src_root = resolve_root(root);
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)
        .with_context(|| format!("walking {}", src_root.display()))?;
    files.sort();
    let mut report = Report {
        findings: Vec::new(),
        files_scanned: files.len(),
        rules_run: rules.len(),
    };
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        report.findings.extend(lint_source(&rel, &src, rules));
    }
    Ok(report)
}

/// Map a user-supplied path to the analysis root: repo root → `rust/src`.
pub fn resolve_root(root: &Path) -> PathBuf {
    let nested = root.join("rust").join("src");
    if nested.is_dir() {
        nested
    } else {
        root.to_path_buf()
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Token-index ranges of items annotated `#[<attr tokens>]` (e.g.
/// `cfg ( test )`), spanning the attribute through the item's body.
/// Any further attributes between the match and the body are included.
fn attr_ranges(toks: &[Token], attr: &[&str]) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#"
            && tok_text(toks, i + 1) == Some("[")
            && attr
                .iter()
                .enumerate()
                .all(|(k, want)| tok_text(toks, i + 2 + k) == Some(want))
            && tok_text(toks, i + 2 + attr.len()) == Some("]")
        {
            let mut j = i + 3 + attr.len();
            // Skip any further attributes before the item itself.
            while tok_text(toks, j) == Some("#") && tok_text(toks, j + 1) == Some("[") {
                let mut depth = 0usize;
                j += 1;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
            let end = item_end(toks, j);
            out.push(i..end);
            i = end;
        } else {
            i += 1;
        }
    }
    out
}

/// Token-index ranges of `mod <name> { … }` bodies.
fn mod_ranges(toks: &[Token], name: &str) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].text == "mod"
            && tok_text(toks, i + 1) == Some(name)
            && tok_text(toks, i + 2) == Some("{")
        {
            let end = match_delim(toks, i + 2, "{", "}");
            out.push(i..end);
            i = end;
        } else {
            i += 1;
        }
    }
    out
}

/// Token-index ranges of `<name>! { … }` / `(...)` / `[...]` invocations.
fn macro_ranges(toks: &[Token], name: &str) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].text == name && tok_text(toks, i + 1) == Some("!") {
            let (open, close) = match tok_text(toks, i + 2) {
                Some("{") => ("{", "}"),
                Some("(") => ("(", ")"),
                Some("[") => ("[", "]"),
                _ => {
                    i += 1;
                    continue;
                }
            };
            let end = match_delim(toks, i + 2, open, close);
            out.push(i..end);
            i = end;
        } else {
            i += 1;
        }
    }
    out
}

/// End (exclusive) of the item starting at `toks[i]`: the matching `}` of
/// its first brace, or the first `;` for braceless items (`use`, statics).
fn item_end(toks: &[Token], i: usize) -> usize {
    let mut j = i;
    let mut nest = 0usize; // [] / () nesting; `;` inside (e.g. `[u8; 4]`) is not an item end
    while j < toks.len() {
        match toks[j].text.as_str() {
            "{" => return match_delim(toks, j, "{", "}"),
            "[" | "(" => nest += 1,
            "]" | ")" => nest = nest.saturating_sub(1),
            ";" if nest == 0 => return j + 1,
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// `i` points at `open`; returns the index past its matching `close`.
fn match_delim(toks: &[Token], i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut j = i;
    while j < toks.len() {
        if toks[j].text == open {
            depth += 1;
        } else if toks[j].text == close {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    toks.len()
}

fn tok_text<'t>(toks: &'t [Token], i: usize) -> Option<&'t str> {
    toks.get(i).map(|t| t.text.as_str())
}

/// Re-export for callers that only need marker lookups.
pub fn lex_for_markers(src: &str) -> Lexed {
    lex(src)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::default_rules;

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "fn live() { let t = Instant::now(); }\n\
                   #[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); }\n}\n";
        let f = lint_source("coordinator/x.rs", src, &default_rules());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn excluded_mod_is_out_of_scope_only_in_named_file() {
        let src = "pub mod perf {\n    static C: AtomicU64 = AtomicU64::new(0);\n}\n";
        assert!(lint_source("aggregation/mod.rs", src, &default_rules()).is_empty());
        let f = lint_source("metrics/mod.rs", src, &default_rules());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "global-state");
    }

    #[test]
    fn thread_local_statics_are_not_global_state() {
        let src = "thread_local! {\n    static SCRATCH: RefCell<Vec<f32>> = \
                   RefCell::new(Vec::new());\n}\n";
        assert!(lint_source("util/x.rs", src, &default_rules()).is_empty());
    }

    #[test]
    fn repo_root_resolves_to_rust_src() {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR"));
        assert_eq!(resolve_root(repo), repo.join("rust").join("src"));
        let already = repo.join("rust").join("src");
        assert_eq!(resolve_root(&already), already);
    }
}
