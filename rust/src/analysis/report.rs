//! Finding reporters: a human-readable text rendering and a
//! machine-readable JSON document (built on [`crate::util::json`], since
//! serde is not in the offline crate set). The JSON shape is stable for
//! CI artifact consumers:
//!
//! ```json
//! {
//!   "count": 1,
//!   "files_scanned": 70,
//!   "rules_run": 7,
//!   "findings": [
//!     {"file": "coordinator/x.rs", "line": 12, "rule": "wall-clock",
//!      "severity": "deny", "message": "…", "snippet": "…"}
//!   ]
//! }
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::engine::Report;
use crate::util::json::Json;

/// Human rendering: one block per finding plus a summary line.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        let _ = writeln!(
            out,
            "{}:{}: [{}] {}",
            f.file, f.line, f.rule, f.message
        );
        if !f.snippet.is_empty() {
            let _ = writeln!(out, "    {}", f.snippet);
        }
        let _ = writeln!(
            out,
            "    note: silence with `// lint: {}-exempt (reason)` on this or the preceding line",
            f.rule
        );
    }
    let files: std::collections::BTreeSet<&str> =
        report.findings.iter().map(|f| f.file.as_str()).collect();
    if report.clean() {
        let _ = writeln!(
            out,
            "rpel lint: clean ({} files, {} rules)",
            report.files_scanned, report.rules_run
        );
    } else {
        let _ = writeln!(
            out,
            "rpel lint: {} finding(s) in {} file(s) ({} files scanned, {} rules)",
            report.findings.len(),
            files.len(),
            report.files_scanned,
            report.rules_run
        );
    }
    out
}

/// Machine rendering; see the module docs for the shape.
pub fn render_json(report: &Report) -> String {
    let findings = report
        .findings
        .iter()
        .map(|f| {
            let mut obj = BTreeMap::new();
            obj.insert("file".to_string(), Json::Str(f.file.clone()));
            obj.insert("line".to_string(), Json::Num(f.line as f64));
            obj.insert("rule".to_string(), Json::Str(f.rule.to_string()));
            obj.insert(
                "severity".to_string(),
                Json::Str(f.severity.as_str().to_string()),
            );
            obj.insert("message".to_string(), Json::Str(f.message.clone()));
            obj.insert("snippet".to_string(), Json::Str(f.snippet.clone()));
            Json::Obj(obj)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert(
        "count".to_string(),
        Json::Num(report.findings.len() as f64),
    );
    doc.insert(
        "files_scanned".to_string(),
        Json::Num(report.files_scanned as f64),
    );
    doc.insert("rules_run".to_string(), Json::Num(report.rules_run as f64));
    doc.insert("findings".to_string(), Json::Arr(findings));
    Json::Obj(doc).to_string_compact()
}
