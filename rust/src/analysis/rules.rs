//! The rule catalogue: each rule encodes one written invariant of the
//! repo as a matcher over the lexed token stream. See the module docs in
//! [`crate::analysis`] for the full catalogue with rationale and the
//! exemption-marker syntax.

use super::lexer::{TokKind, Token};

/// Finding severity. Every shipped rule is `Deny` (nonzero exit);
/// `Warn` is reserved for advisory rules that report but do not fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Deny,
    Warn,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
        }
    }
}

/// One lint rule. `include` holds path prefixes relative to the analysis
/// root (`rust/src`); an empty string scopes the rule to the whole tree.
/// `exclude_mods` names `(path-suffix, mod-name)` pairs whose inline
/// module bodies are out of scope (e.g. `aggregation::perf` for
/// `global-state`). `skip_macros` names macro invocations whose bodies
/// are out of scope (e.g. `thread_local!` statics are per-thread scratch,
/// not process-global state).
pub struct Rule {
    pub id: &'static str,
    pub severity: Severity,
    pub invariant: &'static str,
    pub include: &'static [&'static str],
    pub exclude_mods: &'static [(&'static str, &'static str)],
    pub skip_macros: &'static [&'static str],
    pub matcher: Matcher,
}

impl Rule {
    /// The comment marker that exempts a line from this rule.
    pub fn marker(&self) -> String {
        format!("{}-exempt", self.id)
    }

    /// True if `rel_path` (.rs file path relative to the analysis root,
    /// `/`-separated) is in this rule's scope.
    pub fn applies_to(&self, rel_path: &str) -> bool {
        self.include.iter().any(|p| rel_path.starts_with(p))
    }
}

/// Matching strategy over the token stream.
pub enum Matcher {
    /// Fires when any of the listed token-text sequences occurs
    /// (lifetime tokens never match, so `'static` is not `static`).
    AnySeq(&'static [&'static [&'static str]]),
    /// f32 fold-order hazards: `sum::<f32>`, `product::<f32>`, or a
    /// `fold(` whose initial accumulator is an `f32`-suffixed literal.
    FoldF32,
    /// Allocation-sizing calls (`with_capacity`, `reserve`,
    /// `reserve_exact`, `vec![…; n]`) whose arguments contain bare `*`
    /// or `+` arithmetic with no `checked_*`/`saturating_*` guard.
    UncheckedAlloc,
    /// `static mut`, or a `static` item whose type has interior
    /// mutability (atomics, locks, cells, once-types).
    GlobalState,
}

impl Matcher {
    /// If a violation is anchored at `toks[i]`, return a short
    /// description of what matched.
    pub fn matches_at(&self, toks: &[Token], i: usize) -> Option<String> {
        match self {
            Matcher::AnySeq(seqs) => seqs.iter().find_map(|seq| {
                let window = toks.get(i..i + seq.len())?;
                let hit = window
                    .iter()
                    .zip(seq.iter())
                    .all(|(t, want)| t.kind != TokKind::Lifetime && t.text == *want);
                hit.then(|| format!("`{}`", seq.concat()))
            }),
            Matcher::FoldF32 => match_fold_f32(toks, i),
            Matcher::UncheckedAlloc => match_unchecked_alloc(toks, i),
            Matcher::GlobalState => match_global_state(toks, i),
        }
    }
}

fn tok_is(toks: &[Token], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

fn match_fold_f32(toks: &[Token], i: usize) -> Option<String> {
    for head in ["sum", "product"] {
        if tok_is(toks, i, head)
            && tok_is(toks, i + 1, "::")
            && tok_is(toks, i + 2, "<")
            && tok_is(toks, i + 3, "f32")
            && tok_is(toks, i + 4, ">")
        {
            return Some(format!("`{head}::<f32>`"));
        }
    }
    if tok_is(toks, i, "fold") && tok_is(toks, i + 1, "(") {
        let acc = toks.get(i + 2)?;
        if acc.kind == TokKind::Num && acc.text.ends_with("f32") {
            return Some(format!("`fold({}, …)` with an f32 accumulator", acc.text));
        }
    }
    None
}

/// `*` counts as multiplication (not a deref) only when the previous
/// token can end an operand.
fn is_binary_star_context(prev: &Token) -> bool {
    matches!(prev.kind, TokKind::Ident | TokKind::Num) || prev.text == ")" || prev.text == "]"
}

fn match_unchecked_alloc(toks: &[Token], i: usize) -> Option<String> {
    let (what, open_at) = if matches!(
        toks.get(i).map(|t| t.text.as_str()),
        Some("with_capacity" | "reserve" | "reserve_exact")
    ) && tok_is(toks, i + 1, "(")
    {
        (toks[i].text.clone(), i + 1)
    } else if tok_is(toks, i, "vec")
        && tok_is(toks, i + 1, "!")
        && (tok_is(toks, i + 2, "[") || tok_is(toks, i + 2, "("))
    {
        ("vec!".to_string(), i + 2)
    } else {
        return None;
    };
    let close = match toks[open_at].text.as_str() {
        "[" => "]",
        _ => ")",
    };
    let open = toks[open_at].text.clone();
    let mut depth = 1usize;
    let mut j = open_at + 1;
    let mut bare_arith = false;
    let mut guarded = false;
    while j < toks.len() && depth > 0 {
        let t = &toks[j];
        match t.text.as_str() {
            x if x == open => depth += 1,
            x if x == close => depth -= 1,
            "*" if is_binary_star_context(&toks[j - 1]) => bare_arith = true,
            "+" => bare_arith = true,
            "<" if tok_is(toks, j + 1, "<") => bare_arith = true,
            _ => {
                if t.kind == TokKind::Ident
                    && (t.text.starts_with("checked_") || t.text.starts_with("saturating_"))
                {
                    guarded = true;
                }
            }
        }
        j += 1;
    }
    (bare_arith && !guarded).then(|| format!("unguarded arithmetic in `{what}` size"))
}

/// Types whose statics constitute mutable process-global state.
const INTERIOR_MUT: &[&str] = &[
    "AtomicBool", "AtomicU8", "AtomicU16", "AtomicU32", "AtomicU64", "AtomicUsize", "AtomicI8",
    "AtomicI16", "AtomicI32", "AtomicI64", "AtomicIsize", "AtomicPtr", "Mutex", "RwLock",
    "OnceLock", "OnceCell", "LazyLock", "Cell", "RefCell", "UnsafeCell",
];

fn match_global_state(toks: &[Token], i: usize) -> Option<String> {
    let t = toks.get(i)?;
    if t.kind != TokKind::Ident || t.text != "static" {
        return None;
    }
    if tok_is(toks, i + 1, "mut") {
        return Some("`static mut`".to_string());
    }
    // static NAME: <type tokens> = …;  — scan the type for interior
    // mutability. Bounded lookahead keeps pathological input cheap.
    let mut j = i + 1;
    let end = (i + 64).min(toks.len());
    while j < end && !tok_is(toks, j, ":") {
        j += 1;
    }
    while j < end && !tok_is(toks, j, "=") && !tok_is(toks, j, ";") {
        if toks[j].kind == TokKind::Ident && INTERIOR_MUT.contains(&toks[j].text.as_str()) {
            return Some(format!("process-global `static … : {}`", toks[j].text));
        }
        j += 1;
    }
    None
}

/// The shipped rule set, in catalogue order. Kept in sync with the
/// catalogue in the [`crate::analysis`] module docs and mirrored (rules
/// 1–3) by `clippy.toml`'s `disallowed-methods`/`disallowed-types`.
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule {
            id: "wall-clock",
            severity: Severity::Deny,
            invariant: "deterministic modules model time on the virtual clock \
                        (util::vclock); wall-clock reads change results across hosts",
            include: &["coordinator/", "aggregation/", "sampling/"],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::AnySeq(&[&["Instant"], &["SystemTime"]]),
        },
        Rule {
            id: "hash-order",
            severity: Severity::Deny,
            invariant: "seeded hash tables iterate in nondeterministic order; use \
                        BTreeMap/BTreeSet or exempt-mark lookup-only uses",
            include: &["coordinator/", "aggregation/", "sampling/"],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::AnySeq(&[&["HashMap"], &["HashSet"], &["RandomState"]]),
        },
        Rule {
            id: "ambient-rng",
            severity: Severity::Deny,
            invariant: "ambient nondeterminism; draw randomness from counter-keyed \
                        util::rng streams and take configuration via flags",
            include: &["coordinator/", "aggregation/", "sampling/", "wire/"],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::AnySeq(&[
                &["thread_rng"],
                &["from_entropy"],
                &["env", "::", "var"],
                &["env", "::", "var_os"],
                &["env", "::", "vars"],
                &["env", "::", "temp_dir"],
                &["env", "::", "current_exe"],
                &["process", "::", "id"],
            ]),
        },
        Rule {
            id: "panic-path",
            severity: Severity::Deny,
            invariant: "decode paths and the worker loop return named errors \
                        (bail!/ensure!/context); a panic kills the whole shard",
            include: &[
                "wire/",
                "coordinator/proc.rs",
                "coordinator/peer.rs",
                "coordinator/checkpoint.rs",
            ],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::AnySeq(&[
                &["unwrap"],
                &["expect"],
                &["panic"],
                &["unreachable"],
                &["todo"],
                &["unimplemented"],
            ]),
        },
        Rule {
            id: "unchecked-alloc",
            severity: Severity::Deny,
            invariant: "attacker-supplied counts size allocations in the wire codec; \
                        size math must go through checked_* per the 1 GiB frame cap",
            include: &["wire/"],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::UncheckedAlloc,
        },
        Rule {
            id: "f32-fold",
            severity: Severity::Deny,
            invariant: "f32 reductions reassociate under vectorization; stage them \
                        through the documented f64 kernels (util::vecmath)",
            include: &["aggregation/", "coordinator/"],
            exclude_mods: &[],
            skip_macros: &[],
            matcher: Matcher::FoldF32,
        },
        Rule {
            id: "global-state",
            severity: Severity::Deny,
            invariant: "process-global mutable state breaks run isolation; thread \
                        scratch belongs in thread_local!, counters in aggregation::perf",
            include: &[""],
            exclude_mods: &[("aggregation/mod.rs", "perf")],
            skip_macros: &["thread_local"],
            matcher: Matcher::GlobalState,
        },
    ]
}
