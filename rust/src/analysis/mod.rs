//! `rpel lint` — a determinism & panic-safety static-analysis pass over
//! this source tree.
//!
//! Every guarantee the repo ships — bit-identical rounds across the
//! (transport × procs × shards × threads × participation) grid — rests on
//! a handful of written invariants: time is modeled on the virtual clock,
//! iteration orders are total, randomness comes from counter-keyed
//! streams, decode paths return named errors, and size math is checked.
//! The runtime determinism suites enforce those invariants *after the
//! fact*, at grid-run cost. This pass enforces them at `cargo test`
//! speed, on the token stream itself.
//!
//! The pipeline: [`lexer`] turns a file into a token stream with all
//! comments and string/char literals removed (so prose and format strings
//! can never fire a rule) while collecting exemption markers from
//! comments; [`engine`] carves out `#[cfg(test)]` bodies, excluded inline
//! modules, and skipped macro invocations, then applies each in-scope
//! rule from [`rules`]; [`report`] renders findings as human text or
//! machine JSON. The CLI front-end is `rpel lint [--json] [path]`, which
//! exits nonzero on any finding; the same engine backs the
//! `no_wall_clock_reads_in_deterministic_modules` test and the
//! whole-tree assertion in `rust/tests/lint.rs`.
//!
//! # Rule catalogue
//!
//! | id | scope | invariant |
//! |----|-------|-----------|
//! | `wall-clock` | `coordinator/`, `aggregation/`, `sampling/` | No `Instant`/`SystemTime`: deterministic modules model time on `util::vclock`. Wall-clock reads change round closure across hosts. |
//! | `hash-order` | `coordinator/`, `aggregation/`, `sampling/` | No `HashMap`/`HashSet`/`RandomState`: seeded hash tables iterate in nondeterministic order. Use `BTreeMap`/`BTreeSet`, or exempt-mark lookup-only tables whose iteration order is never observed. |
//! | `ambient-rng` | `coordinator/`, `aggregation/`, `sampling/`, `wire/` | No `thread_rng`/`from_entropy`, `std::env` reads (`var`, `vars`, `var_os`, `temp_dir`, `current_exe`), or `process::id`: randomness comes from counter-keyed `util::rng` streams, configuration from flags. |
//! | `panic-path` | `wire/`, `coordinator/proc.rs`, `coordinator/peer.rs`, `coordinator/checkpoint.rs` | No `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` on decode paths, in the shard-worker loop, or in checkpoint decode: malformed frames, peer failures, and corrupt checkpoint files must surface as named errors (`bail!`/`ensure!`/`context`), not kill the process. |
//! | `unchecked-alloc` | `wire/` | Allocation sizing (`with_capacity`, `reserve`, `vec![…; n]`) fed by arithmetic must use `checked_*`/`saturating_*`: counts are attacker-supplied and the codec's 1 GiB frame cap depends on overflow-free size math. |
//! | `f32-fold` | `aggregation/`, `coordinator/` | No ad-hoc f32 reductions (`sum::<f32>`, `product::<f32>`, `fold(0.0f32, …)`): f32 folds reassociate under vectorization; stage through the documented f64 kernels in `util::vecmath`. |
//! | `global-state` | whole tree, except `mod perf` in `aggregation/mod.rs` | No `static mut` and no `static` of an interior-mutable type (atomics, locks, cells, once-types): process-global state breaks run isolation. Thread scratch belongs in `thread_local!` (always allowed); sanctioned perf counters live in `aggregation::perf`. |
//!
//! # Exemption markers
//!
//! A finding is silenced by a comment marker on the **same line** or the
//! **line directly above**:
//!
//! ```text
//! let t0 = Instant::now(); // lint: wall-clock-exempt (reporting only)
//! ```
//!
//! The marker is `lint: <rule-id>-exempt`; anything after it is free-form
//! rationale and is strongly encouraged. Markers are read from comments
//! only (a marker inside a string literal does nothing), are per-rule
//! (a `wall-clock-exempt` never silences `hash-order`), and are honored
//! by both the CLI and the test-tier entry points. `#[cfg(test)]` bodies
//! need no markers — the engine skips them wholesale, since tests may
//! freely time things and build scratch hash tables.
//!
//! Rules 1–3 are additionally mirrored by `clippy.toml`
//! (`disallowed-methods` / `disallowed-types`), so `cargo clippy` backs
//! up this pass with type-resolved matching where the lexer only sees
//! names.

pub mod engine;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::Path;

use anyhow::Result;

pub use engine::{lint_source, lint_tree, Finding, Report};
pub use rules::{default_rules, Rule, Severity};

/// Lint `root` (a source tree or repo root) with the default rule set.
pub fn run_lint(root: &Path) -> Result<Report> {
    lint_tree(root, &default_rules())
}
