//! A minimal Rust lexer for the lint pass.
//!
//! The goal is not fidelity to `rustc`'s grammar — it is to turn source
//! text into a token stream in which comments, string/char literals, and
//! raw strings have been *removed*, so rule matchers can never fire on
//! prose ("this would panic"), format strings, or doc examples. Along the
//! way the lexer records, per line, every `lint: <marker>` annotation it
//! finds inside comments; the engine uses those to honor per-rule
//! exemptions (`// lint: <rule-id>-exempt`).
//!
//! Handled literal forms: `//`/`///`/`//!` line comments, nested
//! `/* .. */` block comments, `"…"` strings (with escapes and escaped
//! newlines), `b"…"` byte strings, `r"…"`/`r#"…"#`/`br#"…"#` raw strings
//! with any hash depth, `'x'`/`'\n'`/`b'x'` char literals, and the
//! char-vs-lifetime ambiguity (`'a>` lexes as a lifetime token, `'a'` as
//! a char literal). Identifiers are maximal (`unwrap_or` is one token and
//! is *not* a match for `unwrap`); `r#ident` raw identifiers lex as the
//! bare identifier. `::` is merged into a single punctuation token; every
//! other punctuation char is its own token.

use std::collections::BTreeMap;

/// Token class. Matchers use it to tell `static` (ident) from `'static`
/// (lifetime) and to recognize `f32`-suffixed numeric literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Num,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub text: String,
    pub kind: TokKind,
    pub line: u32,
}

/// Lexer output: the literal-free token stream plus every `lint:` marker
/// found in comments, keyed by the line the marker appears on.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub markers: BTreeMap<u32, Vec<String>>,
}

impl Lexed {
    /// True if `marker` (e.g. `"wall-clock-exempt"`) appears in a comment
    /// on `line` or the line directly above it.
    pub fn exempted(&self, marker: &str, line: u32) -> bool {
        let on = |ln: u32| {
            self.markers
                .get(&ln)
                .is_some_and(|ms| ms.iter().any(|m| m == marker))
        };
        on(line) || (line > 1 && on(line - 1))
    }
}

/// Lex `src` into tokens + comment markers. Never fails: unterminated
/// literals simply consume to end of input.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            collect_markers(&chars[start..i], line, &mut out.markers);
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            i = skip_block_comment(&chars, i, &mut line, &mut out.markers);
        } else if c == '"' {
            i = skip_string(&chars, i, &mut line);
        } else if c == '\'' {
            i = char_or_lifetime(&chars, i, line, &mut out);
        } else if c == 'r' || c == 'b' {
            i = raw_or_ident(&chars, i, &mut line, &mut out);
        } else if c == '_' || c.is_ascii_alphabetic() {
            let start = i;
            while i < n && (chars[i] == '_' || chars[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            out.tokens.push(Token {
                text: chars[start..i].iter().collect(),
                kind: TokKind::Ident,
                line,
            });
        } else if c.is_ascii_digit() {
            i = lex_number(&chars, i, line, &mut out);
        } else if c == ':' && i + 1 < n && chars[i + 1] == ':' {
            out.tokens.push(Token {
                text: "::".to_string(),
                kind: TokKind::Punct,
                line,
            });
            i += 2;
        } else {
            out.tokens.push(Token {
                text: c.to_string(),
                kind: TokKind::Punct,
                line,
            });
            i += 1;
        }
    }
    out
}

/// Scan a comment's text for `lint: <word>` annotations and record each
/// word under `line`. Multiple `lint:` markers in one comment all count.
fn collect_markers(comment: &[char], line: u32, markers: &mut BTreeMap<u32, Vec<String>>) {
    let text: String = comment.iter().collect();
    let mut rest = text.as_str();
    while let Some(pos) = rest.find("lint:") {
        rest = &rest[pos + "lint:".len()..];
        let word: String = rest
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if !word.is_empty() {
            markers.entry(line).or_default().push(word);
        }
    }
}

/// `i` points at `/*`. Returns the index past the matching (nested) close;
/// records markers per line inside the comment.
fn skip_block_comment(
    chars: &[char],
    mut i: usize,
    line: &mut u32,
    markers: &mut BTreeMap<u32, Vec<String>>,
) -> usize {
    let n = chars.len();
    let mut depth = 1usize;
    i += 2;
    let mut seg = i;
    while i < n && depth > 0 {
        if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
            depth += 1;
            i += 2;
        } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
            depth -= 1;
            i += 2;
        } else if chars[i] == '\n' {
            collect_markers(&chars[seg..i], *line, markers);
            *line += 1;
            i += 1;
            seg = i;
        } else {
            i += 1;
        }
    }
    collect_markers(&chars[seg..i.min(n)], *line, markers);
    i
}

/// `i` points at the opening `"`. Returns the index past the closing quote.
fn skip_string(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = chars.len();
    i += 1;
    while i < n {
        match chars[i] {
            '\\' => {
                if i + 1 < n && chars[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// `i` points at `'`. Distinguishes char literals from lifetimes: `'x'`
/// and `'\…'` are literals (skipped); `'ident` not followed by a closing
/// quote is a lifetime token.
fn char_or_lifetime(chars: &[char], i: usize, line: u32, out: &mut Lexed) -> usize {
    let n = chars.len();
    if i + 1 >= n {
        return n;
    }
    if chars[i + 1] == '\\' {
        // Escaped char literal: the char after the backslash is consumed
        // unconditionally (it may itself be a quote, as in '\''), then we
        // scan to the closing quote (covers multi-char escapes like \u{…}).
        let mut j = (i + 3).min(n);
        while j < n && chars[j] != '\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
        return i + 3; // plain char literal 'x'
    }
    if chars[i + 1] == '_' || chars[i + 1].is_ascii_alphabetic() {
        let start = i + 1;
        let mut j = start;
        while j < n && (chars[j] == '_' || chars[j].is_ascii_alphanumeric()) {
            j += 1;
        }
        out.tokens.push(Token {
            text: chars[start..j].iter().collect(),
            kind: TokKind::Lifetime,
            line,
        });
        return j;
    }
    i + 1 // stray quote; skip it
}

/// `i` points at `r` or `b`. Handles raw strings, byte strings, byte
/// chars, and raw identifiers; anything else lexes as a plain identifier.
fn raw_or_ident(chars: &[char], i: usize, line: &mut u32, out: &mut Lexed) -> usize {
    let n = chars.len();
    let c = chars[i];
    let next = chars.get(i + 1).copied();
    if c == 'b' {
        match next {
            Some('"') => return skip_string(chars, i + 1, line),
            Some('\'') => return char_or_lifetime(chars, i + 1, *line, out),
            Some('r') => {
                let after = chars.get(i + 2).copied();
                if after == Some('"') || after == Some('#') {
                    return skip_raw_string(chars, i + 2, line);
                }
            }
            _ => {}
        }
    } else if next == Some('"') || next == Some('#') {
        // r"…", r#"…"#, or a raw identifier r#ident.
        if next == Some('"') {
            return skip_raw_string(chars, i + 1, line);
        }
        let mut k = i + 1;
        while k < n && chars[k] == '#' {
            k += 1;
        }
        if k < n && chars[k] == '"' {
            return skip_raw_string(chars, i + 1, line);
        }
        if k == i + 2 && k < n && (chars[k] == '_' || chars[k].is_ascii_alphabetic()) {
            // raw identifier: lex the bare ident after `r#`.
            let start = k;
            let mut j = start;
            while j < n && (chars[j] == '_' || chars[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            out.tokens.push(Token {
                text: chars[start..j].iter().collect(),
                kind: TokKind::Ident,
                line: *line,
            });
            return j;
        }
    }
    // Plain identifier starting with r/b.
    let start = i;
    let mut j = i;
    while j < n && (chars[j] == '_' || chars[j].is_ascii_alphanumeric()) {
        j += 1;
    }
    out.tokens.push(Token {
        text: chars[start..j].iter().collect(),
        kind: TokKind::Ident,
        line: *line,
    });
    j
}

/// `i` points at the first `#` (or the `"` when there are no hashes) of a
/// raw string body marker. Returns the index past the closing delimiter.
fn skip_raw_string(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = chars.len();
    let mut hashes = 0usize;
    while i < n && chars[i] == '#' {
        hashes += 1;
        i += 1;
    }
    if i >= n || chars[i] != '"' {
        return i; // malformed; bail without consuming further
    }
    i += 1;
    while i < n {
        if chars[i] == '\n' {
            *line += 1;
            i += 1;
        } else if chars[i] == '"' {
            let mut k = 0usize;
            while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
            i += 1;
        } else {
            i += 1;
        }
    }
    i
}

/// `i` points at an ASCII digit. Consumes a maximal numeric literal,
/// including `_` separators, type suffixes (`4u64`, `0.5f32`), hex/octal
/// prefixes, a decimal point when followed by a digit, and signed
/// exponents (`1e-6`). Range dots (`0..n`) are not consumed.
fn lex_number(chars: &[char], i: usize, line: u32, out: &mut Lexed) -> usize {
    let n = chars.len();
    let start = i;
    let mut j = i;
    while j < n {
        let c = chars[j];
        if c == '_' || c.is_ascii_alphanumeric() {
            j += 1;
        } else if c == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
            j += 1;
        } else if (c == '+' || c == '-')
            && j > start
            && (chars[j - 1] == 'e' || chars[j - 1] == 'E')
        {
            j += 1;
        } else {
            break;
        }
    }
    out.tokens.push(Token {
        text: chars[start..j].iter().collect(),
        kind: TokKind::Num,
        line,
    });
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        let src = r##"
            // Instant in a line comment
            /* HashMap in /* a nested */ block */
            let msg = "calling unwrap() would panic";
            let raw = r#"SystemTime "quoted" inside"#;
            let c = 'u';
        "##;
        let t = texts(src);
        assert!(!t.iter().any(|x| x == "Instant" || x == "HashMap"));
        assert!(!t.iter().any(|x| x == "unwrap" || x == "SystemTime"));
        assert!(t.iter().any(|x| x == "msg"));
    }

    #[test]
    fn identifiers_are_maximal() {
        let t = texts("x.unwrap_or(0); y.unwrap();");
        assert!(t.iter().any(|x| x == "unwrap_or"));
        assert_eq!(t.iter().filter(|x| *x == "unwrap").count(), 1);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let lexed = lex("fn f<'a>(x: &'a str) -> char { 's' }");
        assert!(lexed
            .tokens
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "a"));
        // the char literal 's' must not appear as any token
        assert!(!lexed
            .tokens
            .iter()
            .any(|t| t.text == "s" && t.kind != TokKind::Lifetime));
        let lexed = lex("let t: &'static str = x;");
        assert!(lexed
            .tokens
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "static"));
    }

    #[test]
    fn line_numbers_track_all_literal_forms() {
        let src = "a\n\"two\nlines\"\nb /* c\nc2 */ d\ne";
        let lexed = lex(src);
        let find = |name: &str| lexed.tokens.iter().find(|t| t.text == name).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 4);
        assert_eq!(find("d"), 5);
        assert_eq!(find("e"), 6);
    }

    #[test]
    fn markers_collected_per_line() {
        let src =
            "let x = 1; // lint: wall-clock-exempt (reporting)\n// lint: hash-order-exempt\nlet y = 2;";
        let lexed = lex(src);
        assert!(lexed.exempted("wall-clock-exempt", 1));
        assert!(lexed.exempted("hash-order-exempt", 2));
        // preceding-line rule: line 3 inherits line 2's marker
        assert!(lexed.exempted("hash-order-exempt", 3));
        assert!(!lexed.exempted("wall-clock-exempt", 3));
    }

    #[test]
    fn numbers_keep_suffixes_and_stop_at_range_dots() {
        let t = texts("fold(0.0f32, |a, b| a + b); for i in 0..rows {}");
        assert!(t.iter().any(|x| x == "0.0f32"));
        assert!(t.iter().any(|x| x == "0"));
        assert!(t.iter().any(|x| x == "rows"));
    }

    #[test]
    fn double_colon_is_one_token() {
        let t = texts("std::env::var(\"X\")");
        assert_eq!(
            t,
            vec!["std", "::", "env", "::", "var", "(", ")"]
                .into_iter()
                .map(String::from)
                .collect::<Vec<_>>()
        );
    }
}
