//! Training metrics: per-round history, average/worst-client accuracy
//! (figures 4–7 report both), CSV export, and paper-style series printing.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// One evaluation snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalPoint {
    pub round: usize,
    /// mean test accuracy over honest nodes
    pub avg_acc: f64,
    /// worst honest node's accuracy (fairness metric, figs 5/7)
    pub worst_acc: f64,
    /// mean test loss over honest nodes
    pub avg_loss: f64,
}

/// Full history of one training run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct History {
    pub name: String,
    /// mean honest training loss per round
    pub train_loss: Vec<f64>,
    /// §4.2 telemetry: max Byzantine rows any honest node received, per
    /// round — the *observed* b̂ (must stay ≤ the Algorithm-2 b̂ whp)
    pub observed_byz_max: Vec<usize>,
    pub evals: Vec<EvalPoint>,
    /// communication accounting (paper's headline axis): the protocol's
    /// **nominal** per-round budget and its running total
    pub messages_per_round: usize,
    pub total_messages: usize,
    /// model rows honest nodes **actually received**, per round — the
    /// delivered ledger. It diverges from the nominal budget exactly in
    /// the adversarial regimes the paper characterizes: DoS withholds
    /// every Byzantine response, push mode wastes pushes to Byzantine
    /// recipients, and the nominal epidemic budget n·s also counts the
    /// Byzantine nodes' own pulls.
    pub delivered_per_round: Vec<usize>,
    pub total_delivered: usize,
    /// Bytes-on-the-wire ledger (multi-process engine; all zeros for
    /// in-process runs), per round. `wire_coord_out_per_round` is what
    /// the coordinator shipped to shard workers — the axis the socket
    /// transport shrinks from O(h·d) per worker (pipe broadcast) to
    /// O(s·d + routing table); `wire_coord_in_per_round` is the upstream
    /// snapshot/commit traffic; `wire_peer_per_round` is what workers
    /// served each other directly (socket transport only). Measured, not
    /// asserted: `rust/tests/message_accounting.rs` pins each against
    /// independent recomputation from the routing table.
    pub wire_coord_out_per_round: Vec<usize>,
    pub wire_coord_in_per_round: Vec<usize>,
    pub wire_peer_per_round: Vec<usize>,
    /// Row-codec ledger (multi-process engine; all zeros for in-process
    /// runs), per round: row-payload bytes of the blocks that travel at
    /// the configured `[wire] compression` — `Snapshot` rows on both
    /// transports plus worker-served `PullReply` rows on the socket
    /// transport (block headers and frame overhead excluded). `raw` is
    /// what the same rows would cost at 4 bytes/coordinate; the two are
    /// equal at `compression = none`, and their ratio is the realized
    /// compression factor (~2× f16, ~4·d/(d+4)× q8).
    /// `rust/tests/message_accounting.rs` pins both byte-exact against
    /// independent recomputation from the routing table.
    pub wire_raw_bytes_per_round: Vec<u64>,
    pub wire_encoded_bytes_per_round: Vec<u64>,
    /// Async-round ledgers (populated only when the `[async]` config is
    /// live; empty for synchronous runs). Per round: how many honest
    /// nodes made the quorum close (fresh), and the virtual time the
    /// round closed at. `staleness_hist[k]` counts node-rounds served at
    /// staleness `k` over the whole run (bucket `max_staleness + 1` is
    /// the params-fallback regime), so the buckets sum to
    /// `rounds × h`. All three are recomputable from the counter-keyed
    /// latency/churn streams alone — `rust/tests/async_rounds.rs` pins
    /// them byte-exact against that independent recomputation.
    pub participation_per_round: Vec<u32>,
    pub virtual_close_per_round: Vec<f64>,
    pub staleness_hist: Vec<u64>,
    /// Sparse-engine ledgers (populated only when `participation < 1` or
    /// the virtual-node backend is live; empty for dense
    /// full-participation runs). Per round: honest nodes whose
    /// PARTICIPATE coin made them active (`active_per_round` recomputes
    /// byte-exactly from the public stream — `rust/tests/sparse_engine.rs`
    /// pins it), nodes whose full params/momentum state was materialized
    /// this round (= h for the dense engine, |active ∪ pulled| for the
    /// virtual backend), and the committed-state bytes resident after the
    /// round (delta logs + arenas + momentum + data + per-node seeds for
    /// the virtual backend; n·d·4 params + momentum for dense). The
    /// resident ledger is the memory-diet referee of the n = 10⁶ test in
    /// `rust/tests/large_n.rs`.
    pub active_per_round: Vec<u32>,
    pub materialized_per_round: Vec<u32>,
    pub resident_bytes_per_round: Vec<u64>,
    /// Crash-recovery ledgers (populated only when the `[recovery]`
    /// machinery acts; all zeros on an unfaulted run). Per round: shard
    /// workers respawned by the supervisor, extra peer-pull/dial attempts
    /// consumed by the deterministic retry policy (0 = every pull
    /// succeeded first try), and bytes of the durable checkpoint written
    /// after the round (0 = no checkpoint this round). Recovery traffic
    /// is deliberately *not* folded into the wire ledgers above — those
    /// stay byte-exact against their routing-table recomputation; these
    /// measure the recovery tax separately.
    pub worker_restarts_per_round: Vec<u32>,
    pub peer_retries_per_round: Vec<u32>,
    pub checkpoint_bytes_per_round: Vec<u64>,
    /// wall-clock seconds of the run (perf bookkeeping)
    pub wall_secs: f64,
}

impl History {
    pub fn new(name: &str, messages_per_round: usize) -> Self {
        History {
            name: name.to_string(),
            messages_per_round,
            ..Default::default()
        }
    }

    pub fn final_avg_accuracy(&self) -> f64 {
        self.evals.last().map(|e| e.avg_acc).unwrap_or(0.0)
    }

    pub fn final_worst_accuracy(&self) -> f64 {
        self.evals.last().map(|e| e.worst_acc).unwrap_or(0.0)
    }

    /// Best average accuracy over the run's evaluations. Empty history
    /// returns NaN — the same convention as [`History::final_train_loss`]
    /// — so "no evals yet" is never conflated with a genuine 0% run.
    pub fn best_avg_accuracy(&self) -> f64 {
        // f64::max ignores NaN, so the seed vanishes on non-empty input
        self.evals.iter().map(|e| e.avg_acc).fold(f64::NAN, f64::max)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.train_loss.last().copied().unwrap_or(f64::NAN)
    }

    /// Observed b̂ over the whole run (max Byzantine rows any honest node
    /// ever received) — comparable against the Algorithm-2 prediction.
    pub fn observed_bhat(&self) -> usize {
        self.observed_byz_max.iter().copied().max().unwrap_or(0)
    }

    /// CSV rows: round,avg_acc,worst_acc,avg_loss.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("round,avg_acc,worst_acc,avg_loss\n");
        for e in &self.evals {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6}\n",
                e.round, e.avg_acc, e.worst_acc, e.avg_loss
            ));
        }
        out
    }

    /// JSON export (results/ directory artifacts).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(self.name.clone()));
        obj.insert(
            "messages_per_round".into(),
            Json::Num(self.messages_per_round as f64),
        );
        obj.insert(
            "total_messages".into(),
            Json::Num(self.total_messages as f64),
        );
        obj.insert(
            "total_delivered".into(),
            Json::Num(self.total_delivered as f64),
        );
        obj.insert(
            "delivered_per_round".into(),
            Json::Arr(
                self.delivered_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        let bytes_arr = |xs: &[usize]| {
            Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
        };
        obj.insert(
            "wire_coord_out_per_round".into(),
            bytes_arr(&self.wire_coord_out_per_round),
        );
        obj.insert(
            "wire_coord_in_per_round".into(),
            bytes_arr(&self.wire_coord_in_per_round),
        );
        obj.insert(
            "wire_peer_per_round".into(),
            bytes_arr(&self.wire_peer_per_round),
        );
        obj.insert(
            "wire_raw_bytes_per_round".into(),
            Json::Arr(
                self.wire_raw_bytes_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "wire_encoded_bytes_per_round".into(),
            Json::Arr(
                self.wire_encoded_bytes_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "participation_per_round".into(),
            Json::Arr(
                self.participation_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "virtual_close_per_round".into(),
            Json::Arr(
                self.virtual_close_per_round
                    .iter()
                    .map(|&x| Json::Num(x))
                    .collect(),
            ),
        );
        obj.insert(
            "staleness_hist".into(),
            Json::Arr(
                self.staleness_hist
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "active_per_round".into(),
            Json::Arr(
                self.active_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "materialized_per_round".into(),
            Json::Arr(
                self.materialized_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "resident_bytes_per_round".into(),
            Json::Arr(
                self.resident_bytes_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "worker_restarts_per_round".into(),
            Json::Arr(
                self.worker_restarts_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "peer_retries_per_round".into(),
            Json::Arr(
                self.peer_retries_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "checkpoint_bytes_per_round".into(),
            Json::Arr(
                self.checkpoint_bytes_per_round
                    .iter()
                    .map(|&x| Json::Num(x as f64))
                    .collect(),
            ),
        );
        obj.insert("wall_secs".into(), Json::Num(self.wall_secs));
        obj.insert(
            "train_loss".into(),
            Json::Arr(self.train_loss.iter().map(|&x| Json::Num(x)).collect()),
        );
        obj.insert(
            "evals".into(),
            Json::Arr(
                self.evals
                    .iter()
                    .map(|e| {
                        let mut m = BTreeMap::new();
                        m.insert("round".into(), Json::Num(e.round as f64));
                        m.insert("avg_acc".into(), Json::Num(e.avg_acc));
                        m.insert("worst_acc".into(), Json::Num(e.worst_acc));
                        m.insert("avg_loss".into(), Json::Num(e.avg_loss));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    /// Serialize every field except `wall_secs` into a wire payload for
    /// the durable checkpoint (see [`crate::coordinator::checkpoint`]).
    /// `wall_secs` is the one field that is *not* a deterministic
    /// function of the run — resume-vs-straight-through bit-equality is
    /// defined over everything else, so the clock reading stays out of
    /// the durable state entirely (a resumed run reports its own).
    pub fn encode_wire(&self, w: &mut crate::wire::Writer) {
        w.put_str(&self.name);
        w.put_f64s(&self.train_loss);
        let byz: Vec<u64> = self.observed_byz_max.iter().map(|&x| x as u64).collect();
        w.put_u64s(&byz);
        w.put_u32(self.evals.len() as u32);
        for e in &self.evals {
            w.put_u64(e.round as u64);
            w.put_f64(e.avg_acc);
            w.put_f64(e.worst_acc);
            w.put_f64(e.avg_loss);
        }
        w.put_u64(self.messages_per_round as u64);
        w.put_u64(self.total_messages as u64);
        let delivered: Vec<u64> = self.delivered_per_round.iter().map(|&x| x as u64).collect();
        w.put_u64s(&delivered);
        w.put_u64(self.total_delivered as u64);
        for ledger in [
            &self.wire_coord_out_per_round,
            &self.wire_coord_in_per_round,
            &self.wire_peer_per_round,
        ] {
            let xs: Vec<u64> = ledger.iter().map(|&x| x as u64).collect();
            w.put_u64s(&xs);
        }
        w.put_u64s(&self.wire_raw_bytes_per_round);
        w.put_u64s(&self.wire_encoded_bytes_per_round);
        w.put_u32s(&self.participation_per_round);
        w.put_f64s(&self.virtual_close_per_round);
        w.put_u64s(&self.staleness_hist);
        w.put_u32s(&self.active_per_round);
        w.put_u32s(&self.materialized_per_round);
        w.put_u64s(&self.resident_bytes_per_round);
        w.put_u32s(&self.worker_restarts_per_round);
        w.put_u32s(&self.peer_retries_per_round);
        w.put_u64s(&self.checkpoint_bytes_per_round);
    }

    /// Inverse of [`History::encode_wire`]; the decoded history has
    /// `wall_secs = 0`.
    pub fn decode_wire(r: &mut crate::wire::Reader) -> anyhow::Result<History> {
        let mut h = History {
            name: r.string()?,
            train_loss: r.f64s()?,
            ..Default::default()
        };
        h.observed_byz_max = r.u64s()?.into_iter().map(|x| x as usize).collect();
        let n_evals = r.u32()? as usize;
        for _ in 0..n_evals {
            h.evals.push(EvalPoint {
                round: r.u64()? as usize,
                avg_acc: r.f64()?,
                worst_acc: r.f64()?,
                avg_loss: r.f64()?,
            });
        }
        h.messages_per_round = r.u64()? as usize;
        h.total_messages = r.u64()? as usize;
        h.delivered_per_round = r.u64s()?.into_iter().map(|x| x as usize).collect();
        h.total_delivered = r.u64()? as usize;
        h.wire_coord_out_per_round = r.u64s()?.into_iter().map(|x| x as usize).collect();
        h.wire_coord_in_per_round = r.u64s()?.into_iter().map(|x| x as usize).collect();
        h.wire_peer_per_round = r.u64s()?.into_iter().map(|x| x as usize).collect();
        h.wire_raw_bytes_per_round = r.u64s()?;
        h.wire_encoded_bytes_per_round = r.u64s()?;
        h.participation_per_round = r.u32s()?;
        h.virtual_close_per_round = r.f64s()?;
        h.staleness_hist = r.u64s()?;
        h.active_per_round = r.u32s()?;
        h.materialized_per_round = r.u32s()?;
        h.resident_bytes_per_round = r.u64s()?;
        h.worker_restarts_per_round = r.u32s()?;
        h.peer_retries_per_round = r.u32s()?;
        h.checkpoint_bytes_per_round = r.u64s()?;
        Ok(h)
    }

    /// One line in the paper-style series report. A history with no
    /// evaluations prints `best=   n/a` rather than a fake 0% (or NaN).
    pub fn report_line(&self) -> String {
        let best = self.best_avg_accuracy();
        let best = if best.is_nan() {
            "   n/a".to_string()
        } else {
            format!("{best:>6.3}")
        };
        format!(
            "{:<36} final_acc={:>6.3} worst={:>6.3} best={best} loss={:>7.4} msgs/round={} delivered={} ({:.1}s)",
            self.name,
            self.final_avg_accuracy(),
            self.final_worst_accuracy(),
            self.final_train_loss(),
            self.messages_per_round,
            self.total_delivered,
            self.wall_secs,
        )
    }
}

/// Write a set of histories as one CSV per series under `dir`.
pub fn write_histories(dir: &str, histories: &[History]) -> std::io::Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    for h in histories {
        let safe: String = h
            .name
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
            .collect();
        let path = format!("{dir}/{safe}.csv");
        std::fs::write(&path, h.to_csv())?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> History {
        let mut h = History::new("test/alie", 120);
        h.train_loss = vec![2.3, 1.1, 0.6];
        h.evals = vec![
            EvalPoint {
                round: 0,
                avg_acc: 0.1,
                worst_acc: 0.05,
                avg_loss: 2.3,
            },
            EvalPoint {
                round: 10,
                avg_acc: 0.8,
                worst_acc: 0.7,
                avg_loss: 0.5,
            },
        ];
        h.total_messages = 1200;
        h.delivered_per_round = vec![110, 110, 110];
        h.total_delivered = 330;
        h
    }

    #[test]
    fn accessors() {
        let h = sample();
        assert_eq!(h.final_avg_accuracy(), 0.8);
        assert_eq!(h.final_worst_accuracy(), 0.7);
        assert_eq!(h.best_avg_accuracy(), 0.8);
        assert_eq!(h.final_train_loss(), 0.6);
    }

    #[test]
    fn empty_history_is_safe() {
        let h = History::new("empty", 0);
        assert_eq!(h.final_avg_accuracy(), 0.0);
        assert!(h.final_train_loss().is_nan());
        // "no evals yet" must be NaN, not a fake 0% (same convention as
        // final_train_loss) — and report_line must stay printable
        assert!(h.best_avg_accuracy().is_nan());
        assert!(h.report_line().contains("best=   n/a"));
    }

    #[test]
    fn best_accuracy_distinguishes_empty_from_genuine_zero() {
        let mut h = History::new("zero_run", 10);
        h.evals = vec![EvalPoint {
            round: 1,
            avg_acc: 0.0,
            worst_acc: 0.0,
            avg_loss: 9.0,
        }];
        // a real 0%-accuracy run reports 0.0, not NaN
        assert_eq!(h.best_avg_accuracy(), 0.0);
        assert!(h.report_line().contains("best= 0.000"));
    }

    #[test]
    fn delivered_ledger_exported() {
        let h = sample();
        let j = h.to_json();
        let parsed = crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(
            parsed.get("total_delivered").unwrap().as_f64().unwrap(),
            330.0
        );
        assert_eq!(
            parsed
                .get("delivered_per_round")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            3
        );
        assert!(h.report_line().contains("delivered=330"));
    }

    #[test]
    fn wire_ledger_exported() {
        let mut h = sample();
        h.wire_coord_out_per_round = vec![640, 640, 640];
        h.wire_coord_in_per_round = vec![900, 900, 900];
        h.wire_peer_per_round = vec![128, 128, 128];
        h.wire_raw_bytes_per_round = vec![4000, 4000, 4000];
        h.wire_encoded_bytes_per_round = vec![1004, 1004, 1004];
        let parsed = crate::util::json::parse(&h.to_json().to_string_compact()).unwrap();
        for key in [
            "wire_coord_out_per_round",
            "wire_coord_in_per_round",
            "wire_peer_per_round",
            "wire_raw_bytes_per_round",
            "wire_encoded_bytes_per_round",
        ] {
            assert_eq!(parsed.get(key).unwrap().as_arr().unwrap().len(), 3, "{key}");
        }
        assert_eq!(
            parsed
                .get("wire_encoded_bytes_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_f64()
                .unwrap(),
            1004.0
        );
    }

    #[test]
    fn async_ledgers_exported() {
        let mut h = sample();
        h.participation_per_round = vec![6, 7, 5];
        h.virtual_close_per_round = vec![1.0, 4.0, 1.0];
        h.staleness_hist = vec![18, 2, 1];
        let parsed = crate::util::json::parse(&h.to_json().to_string_compact()).unwrap();
        assert_eq!(
            parsed
                .get("participation_per_round")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            3
        );
        assert_eq!(
            parsed
                .get("virtual_close_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[1]
                .as_f64()
                .unwrap(),
            4.0
        );
        assert_eq!(
            parsed.get("staleness_hist").unwrap().as_arr().unwrap()[0]
                .as_f64()
                .unwrap(),
            18.0
        );
    }

    #[test]
    fn sparse_ledgers_exported() {
        let mut h = sample();
        h.active_per_round = vec![4, 6, 5];
        h.materialized_per_round = vec![9, 11, 10];
        h.resident_bytes_per_round = vec![4096, 5120, 5120];
        let parsed = crate::util::json::parse(&h.to_json().to_string_compact()).unwrap();
        assert_eq!(
            parsed.get("active_per_round").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(
            parsed
                .get("materialized_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[1]
                .as_f64()
                .unwrap(),
            11.0
        );
        assert_eq!(
            parsed
                .get("resident_bytes_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_f64()
                .unwrap(),
            4096.0
        );
    }

    #[test]
    fn recovery_ledgers_exported() {
        let mut h = sample();
        h.worker_restarts_per_round = vec![0, 1, 0];
        h.peer_retries_per_round = vec![0, 2, 0];
        h.checkpoint_bytes_per_round = vec![0, 8192, 0];
        let parsed = crate::util::json::parse(&h.to_json().to_string_compact()).unwrap();
        assert_eq!(
            parsed
                .get("worker_restarts_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[1]
                .as_f64()
                .unwrap(),
            1.0
        );
        assert_eq!(
            parsed.get("peer_retries_per_round").unwrap().as_arr().unwrap()[1]
                .as_f64()
                .unwrap(),
            2.0
        );
        assert_eq!(
            parsed
                .get("checkpoint_bytes_per_round")
                .unwrap()
                .as_arr()
                .unwrap()[1]
                .as_f64()
                .unwrap(),
            8192.0
        );
    }

    #[test]
    fn wire_serde_round_trips_everything_but_wall_secs() {
        let mut h = sample();
        h.observed_byz_max = vec![2, 3, 1];
        h.wire_coord_out_per_round = vec![640, 640, 640];
        h.wire_raw_bytes_per_round = vec![4000, 4000, 4000];
        h.wire_encoded_bytes_per_round = vec![1004, 1004, 1004];
        h.participation_per_round = vec![6, 7, 5];
        h.virtual_close_per_round = vec![1.0, 4.0, 1.0];
        h.staleness_hist = vec![18, 2, 1];
        h.active_per_round = vec![4, 6, 5];
        h.resident_bytes_per_round = vec![4096, 5120, 5120];
        h.worker_restarts_per_round = vec![0, 1, 0];
        h.peer_retries_per_round = vec![0, 2, 0];
        h.checkpoint_bytes_per_round = vec![0, 8192, 0];
        h.wall_secs = 12.5;
        let mut w = crate::wire::Writer::new();
        h.encode_wire(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::wire::Reader::new(&bytes);
        let back = History::decode_wire(&mut r).unwrap();
        r.finish().unwrap();
        // wall_secs is deliberately not durable state
        assert_eq!(back.wall_secs, 0.0);
        let mut want = h.clone();
        want.wall_secs = 0.0;
        assert_eq!(back, want);
    }

    #[test]
    fn csv_format() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[2].starts_with("10,0.8"));
    }

    #[test]
    fn json_roundtrips() {
        let j = sample().to_json();
        let parsed = crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "test/alie");
        assert_eq!(
            parsed.get("evals").unwrap().as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    fn write_histories_sanitizes_names() {
        let dir = std::env::temp_dir().join("rpel_metrics_test");
        let dir = dir.to_str().unwrap();
        let paths = write_histories(dir, &[sample()]).unwrap();
        assert!(paths[0].ends_with("test_alie.csv"));
        assert!(std::path::Path::new(&paths[0]).exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn report_line_contains_key_numbers() {
        let line = sample().report_line();
        assert!(line.contains("0.800"));
        assert!(line.contains("msgs/round=120"));
    }
}
