//! Typed executors over the AOT artifacts: shape-checked wrappers around
//! `PjRtLoadedExecutable::execute` with Literal marshalling.
//!
//! All graphs were lowered with `return_tuple=True`, so every output is a
//! tuple literal (1-, 2- or 3-ary).

use super::manifest::ArtifactEntry;
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;
use xla::{Literal, PjRtLoadedExecutable};

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    ensure!(n as usize == data.len(), "literal shape mismatch");
    Ok(Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("{e:?}"))?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    ensure!(n as usize == data.len(), "literal shape mismatch");
    Ok(Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("{e:?}"))?)
}

fn run(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Literal> {
    let result = exe.execute::<Literal>(args).map_err(|e| anyhow!("{e:?}"))?;
    result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))
}

fn scalar_f32(lit: &Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("{e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar literal"))
}

/// `init_<arch>`: seed → flat params.
pub struct InitExec {
    exe: Arc<PjRtLoadedExecutable>,
    pub entry: ArtifactEntry,
}

impl InitExec {
    pub(super) fn new(exe: Arc<PjRtLoadedExecutable>, entry: ArtifactEntry) -> Self {
        InitExec { exe, entry }
    }

    pub fn run(&self, seed: i32) -> Result<Vec<f32>> {
        let out = run(&self.exe, &[Literal::scalar(seed)])?;
        let flat = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let v = flat.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        ensure!(v.len() == self.entry.d, "init returned wrong size");
        Ok(v)
    }
}

/// `train_<arch>_b<B>_k<K>`: momentum-SGD half-step (Algorithm 1 l.3–6).
pub struct TrainExec {
    exe: Arc<PjRtLoadedExecutable>,
    pub entry: ArtifactEntry,
}

/// Result of one train step.
pub struct StepOut {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    pub loss: f32,
}

impl TrainExec {
    pub(super) fn new(exe: Arc<PjRtLoadedExecutable>, entry: ArtifactEntry) -> Self {
        TrainExec { exe, entry }
    }

    /// Expected x length = local_steps * batch * prod(input_shape).
    pub fn x_len(&self) -> usize {
        let per: usize = self.entry.input_shape.iter().product();
        self.entry.local_steps * self.entry.batch * per
    }

    pub fn y_len(&self) -> usize {
        self.entry.local_steps * self.entry.batch
    }

    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        params: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        beta: f32,
        wd: f32,
    ) -> Result<StepOut> {
        let e = &self.entry;
        ensure!(params.len() == e.d && momentum.len() == e.d, "param size");
        ensure!(x.len() == self.x_len() && y.len() == self.y_len(), "batch size");
        let mut xdims: Vec<i64> = Vec::new();
        let mut ydims: Vec<i64> = Vec::new();
        if e.local_steps > 1 {
            xdims.push(e.local_steps as i64);
            ydims.push(e.local_steps as i64);
        }
        xdims.push(e.batch as i64);
        ydims.push(e.batch as i64);
        xdims.extend(e.input_shape.iter().map(|&v| v as i64));
        let args = [
            lit_f32(params, &[e.d as i64])?,
            lit_f32(momentum, &[e.d as i64])?,
            lit_f32(x, &xdims)?,
            lit_i32(y, &ydims)?,
            Literal::scalar(lr),
            Literal::scalar(beta),
            Literal::scalar(wd),
        ];
        let out = run(&self.exe, &args)?;
        let (p, m, l) = out.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        Ok(StepOut {
            params: p.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            momentum: m.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            loss: scalar_f32(&l)?,
        })
    }
}

/// `eval_<arch>_n<E>`: (params, x, y) → (#correct, loss_sum).
pub struct EvalExec {
    exe: Arc<PjRtLoadedExecutable>,
    pub entry: ArtifactEntry,
}

impl EvalExec {
    pub(super) fn new(exe: Arc<PjRtLoadedExecutable>, entry: ArtifactEntry) -> Self {
        EvalExec { exe, entry }
    }

    pub fn eval_n(&self) -> usize {
        self.entry.eval_n
    }

    pub fn run(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let e = &self.entry;
        ensure!(params.len() == e.d, "param size");
        let per: usize = e.input_shape.iter().product();
        ensure!(x.len() == e.eval_n * per && y.len() == e.eval_n, "eval size");
        let mut xdims = vec![e.eval_n as i64];
        xdims.extend(e.input_shape.iter().map(|&v| v as i64));
        let args = [
            lit_f32(params, &[e.d as i64])?,
            lit_f32(x, &xdims)?,
            lit_i32(y, &[e.eval_n as i64])?,
        ];
        let out = run(&self.exe, &args)?;
        let (c, l) = out.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        Ok((scalar_f32(&c)? as f64, scalar_f32(&l)? as f64))
    }
}

/// `aggregate_<arch>_m<m>_b<b̂>`: the Pallas NNM∘CWTM rule, X[m,d] → [d].
pub struct AggregateExec {
    exe: Arc<PjRtLoadedExecutable>,
    pub entry: ArtifactEntry,
    /// row-major staging buffer reused across calls; a Mutex (not RefCell)
    /// so the executor stays `Sync` for the parallel round engine —
    /// uncontended locking is noise next to a PJRT dispatch
    staging: std::sync::Mutex<Vec<f32>>,
}

impl AggregateExec {
    pub(super) fn new(exe: Arc<PjRtLoadedExecutable>, entry: ArtifactEntry) -> Self {
        let cap = entry.m * entry.d;
        AggregateExec {
            exe,
            entry,
            staging: std::sync::Mutex::new(Vec::with_capacity(cap)),
        }
    }

    pub fn m(&self) -> usize {
        self.entry.m
    }

    pub fn bhat(&self) -> usize {
        self.entry.bhat
    }

    /// Aggregate `rows` (must be exactly m rows of d) into a fresh vector.
    pub fn run(&self, rows: &[&[f32]]) -> Result<Vec<f32>> {
        let e = &self.entry;
        ensure!(
            rows.len() == e.m,
            "aggregate expects m={} rows, got {}",
            e.m,
            rows.len()
        );
        let mut staging = self.staging.lock().unwrap();
        staging.clear();
        for r in rows {
            ensure!(r.len() == e.d, "row length {} != d={}", r.len(), e.d);
            staging.extend_from_slice(r);
        }
        let x = lit_f32(&staging, &[e.m as i64, e.d as i64])?;
        let out = run(&self.exe, &[x])?;
        let flat = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let v = flat.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        ensure!(v.len() == e.d, "aggregate returned wrong size");
        Ok(v)
    }
}
