//! `artifacts/manifest.json` parsing — the index of every AOT artifact
//! emitted by `python/compile/aot.py`.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::path::Path;

/// One artifact record.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "init" | "train" | "eval" | "aggregate"
    pub kind: String,
    pub arch: String,
    /// flat parameter count
    pub d: usize,
    /// per-example input shape (empty for aggregate)
    pub input_shape: Vec<usize>,
    pub classes: usize,
    /// train: batch size; 0 otherwise
    pub batch: usize,
    /// train: local steps (1 = plain step); 0 otherwise
    pub local_steps: usize,
    /// eval: eval-set size; 0 otherwise
    pub eval_n: usize,
    /// aggregate: m = s+1 and b̂; 0 otherwise
    pub m: usize,
    pub bhat: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub scale: String,
    entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = doc
            .get("version")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1.0 {
            return Err(anyhow!("unsupported manifest version {version}"));
        }
        let scale = doc
            .get("scale")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            let req_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let opt_usize = |k: &str| a.get(k).and_then(Json::as_usize).unwrap_or(0);
            entries.push(ArtifactEntry {
                name: req_str("name")?,
                file: req_str("file")?,
                kind: req_str("kind")?,
                arch: req_str("arch")?,
                d: opt_usize("d"),
                input_shape: a
                    .get("input_shape")
                    .and_then(Json::as_i64_vec)
                    .unwrap_or_default()
                    .into_iter()
                    .map(|x| x as usize)
                    .collect(),
                classes: opt_usize("classes"),
                batch: opt_usize("batch"),
                local_steps: opt_usize("local_steps"),
                eval_n: opt_usize("eval_n"),
                m: opt_usize("m"),
                bhat: opt_usize("bhat"),
            });
        }
        Ok(Manifest { scale, entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Manifest::parse(&text)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.iter()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn find(&self, pred: impl Fn(&ArtifactEntry) -> bool) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| pred(e))
    }

    /// Flat parameter count for an architecture (from any of its entries).
    pub fn param_count(&self, arch: &str) -> Option<usize> {
        self.find(|e| e.arch == arch && e.d > 0).map(|e| e.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "scale": "tiny",
      "artifacts": [
        {"name": "init_mlp_tiny", "file": "init_mlp_tiny.hlo.txt",
         "kind": "init", "arch": "mlp_tiny", "d": 340,
         "input_shape": [16], "classes": 4},
        {"name": "train_mlp_tiny_b8_k1", "file": "train_mlp_tiny_b8_k1.hlo.txt",
         "kind": "train", "arch": "mlp_tiny", "d": 340,
         "input_shape": [16], "classes": 4, "batch": 8, "local_steps": 1},
        {"name": "aggregate_mlp_tiny_m8_b2", "file": "aggregate_mlp_tiny_m8_b2.hlo.txt",
         "kind": "aggregate", "arch": "mlp_tiny", "d": 340, "m": 8, "bhat": 2}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.scale, "tiny");
        let init = m.get("init_mlp_tiny").unwrap();
        assert_eq!(init.kind, "init");
        assert_eq!(init.d, 340);
        assert_eq!(init.input_shape, vec![16]);
    }

    #[test]
    fn typed_lookups() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m
            .find(|e| e.kind == "train" && e.arch == "mlp_tiny" && e.local_steps == 1)
            .is_some());
        assert!(m
            .find(|e| e.kind == "aggregate" && e.m == 8 && e.bhat == 2)
            .is_some());
        assert!(m.find(|e| e.kind == "eval").is_none());
        assert_eq!(m.param_count("mlp_tiny"), Some(340));
        assert_eq!(m.param_count("nope"), None);
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"version": 2, "artifacts": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(
            Manifest::parse(r#"{"version": 1, "artifacts": [{"name": "x"}]}"#).is_err()
        );
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(path).exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.len() >= 10);
            assert!(m.find(|e| e.kind == "aggregate").is_some());
        }
    }
}
