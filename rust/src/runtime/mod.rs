//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. The interchange
//! format is **HLO text** (not serialized protos — jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids). See /opt/xla-example/README.md and DESIGN.md §3.

// The executable cache is keyed lookup only (never iterated), and the
// runtime is outside the rpel-lint hash-order scope.
#![allow(clippy::disallowed_types)]

pub mod executors;
pub mod manifest;

pub use executors::{AggregateExec, EvalExec, InitExec, TrainExec};
pub use manifest::{ArtifactEntry, Manifest};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded PJRT engine over one artifacts directory: lazily compiles and
/// caches executables by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::load(&manifest_path).with_context(|| {
            format!(
                "cannot load {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(
        &mut self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Typed init executor for an architecture.
    pub fn init_exec(&mut self, arch: &str) -> Result<InitExec> {
        let entry = self
            .manifest
            .find(|e| e.kind == "init" && e.arch == arch)
            .ok_or_else(|| anyhow!("no init artifact for arch '{arch}'"))?
            .clone();
        let exe = self.executable(&entry.name)?;
        Ok(InitExec::new(exe, entry))
    }

    /// Typed train-step executor (arch + local_steps must match an
    /// emitted artifact).
    pub fn train_exec(&mut self, arch: &str, local_steps: usize) -> Result<TrainExec> {
        let entry = self
            .manifest
            .find(|e| e.kind == "train" && e.arch == arch && e.local_steps == local_steps)
            .ok_or_else(|| {
                anyhow!("no train artifact for arch '{arch}' with local_steps={local_steps}")
            })?
            .clone();
        let exe = self.executable(&entry.name)?;
        Ok(TrainExec::new(exe, entry))
    }

    /// Typed eval executor.
    pub fn eval_exec(&mut self, arch: &str) -> Result<EvalExec> {
        let entry = self
            .manifest
            .find(|e| e.kind == "eval" && e.arch == arch)
            .ok_or_else(|| anyhow!("no eval artifact for arch '{arch}'"))?
            .clone();
        let exe = self.executable(&entry.name)?;
        Ok(EvalExec::new(exe, entry))
    }

    /// Typed Pallas-aggregation executor for (arch, m = s+1, b̂).
    pub fn aggregate_exec(&mut self, arch: &str, m: usize, bhat: usize) -> Result<AggregateExec> {
        let entry = self
            .manifest
            .find(|e| e.kind == "aggregate" && e.arch == arch && e.m == m && e.bhat == bhat)
            .ok_or_else(|| {
                anyhow!(
                    "no aggregate artifact for arch '{arch}' m={m} b̂={bhat}; \
                     available: {:?}",
                    self.manifest
                        .iter()
                        .filter(|e| e.kind == "aggregate" && e.arch == arch)
                        .map(|e| (e.m, e.bhat))
                        .collect::<Vec<_>>()
                )
            })?
            .clone();
        let exe = self.executable(&entry.name)?;
        Ok(AggregateExec::new(exe, entry))
    }
}

/// True when a usable artifacts directory exists (integration tests skip
/// HLO paths otherwise).
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").exists()
}
