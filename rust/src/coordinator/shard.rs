//! Shard-owned node state for the round engine.
//!
//! A [`NodeShard`] owns a **contiguous range of honest nodes** — their
//! params, momentum, data shards, and the per-round half-step / next-model
//! buffers — and steps through the explicit round protocol driven by the
//! coordinator ([`crate::coordinator::Trainer`]):
//!
//! 1. `half_step` — every owned node's local train step writes into the
//!    shard's half buffers;
//! 2. `publish` — the shard exposes a read-only [`RoundDigest`] of its
//!    half-steps and round-start params; the coordinator folds all shard
//!    digests (in ascending shard order = ascending honest-node order)
//!    into the global [`crate::attacks::HonestDigest`];
//! 3. `pull/craft/aggregate` — victims in any shard pull exactly the rows
//!    they sampled from the published snapshots and write into the
//!    shard's next buffers;
//! 4. `commit` — the synchronous swap of next into params.
//!
//! # Why the digest fold is centralized
//!
//! Per-shard f64 partial sums combined across shards would make the mean
//! depend on the shard grouping (f64 addition is not associative), so the
//! coordinator instead folds the published rows in ascending honest-node
//! order regardless of shard boundaries — that single O(h·d) serial pass
//! is what makes results **bit-identical for every (shards × threads)
//! combination**, and it is the same fold a future multi-process engine
//! can reproduce from shipped shard snapshots.

use crate::data::Shard;

/// State owned by one honest node.
pub(crate) struct NodeState {
    /// global node id in [0, n)
    pub id: usize,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    /// the node's local data shard
    pub shard: Shard,
}

/// A contiguous range of honest nodes plus their round buffers. All
/// honest-node state lives in exactly one shard; the coordinator is an
/// orchestrator over `Vec<NodeShard>` and owns no node state itself.
pub(crate) struct NodeShard {
    /// first honest index owned by this shard (honest indices are global:
    /// shard k owns `[start, start + len)`)
    pub start: usize,
    pub nodes: Vec<NodeState>,
    /// half-step models x^{t+1/2}, one row per owned node
    pub halves: Vec<Vec<f32>>,
    /// aggregated next models x^{t+1}, committed at the end of the round
    pub next: Vec<Vec<f32>>,
    /// per-node train loss of the last half-step phase
    pub losses: Vec<f64>,
    /// per-node count of Byzantine rows received in the last round
    pub byz_seen: Vec<usize>,
}

/// What a shard publishes after its half-step phase: read-only views of
/// its half-steps and round-start params, tagged with the global range.
/// Within one process this is a borrow; a multi-process engine would ship
/// the same payload as the shard's round snapshot.
pub(crate) struct RoundDigest<'a> {
    pub start: usize,
    pub halves: &'a [Vec<f32>],
    pub nodes: &'a [NodeState],
}

impl NodeShard {
    pub fn new(start: usize, nodes: Vec<NodeState>, d: usize) -> NodeShard {
        let len = nodes.len();
        NodeShard {
            start,
            nodes,
            halves: vec![vec![0.0f32; d]; len],
            next: vec![vec![0.0f32; d]; len],
            losses: vec![0.0f64; len],
            byz_seen: vec![0usize; len],
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Read-only round snapshot for the digest fold and peer pulls.
    pub fn publish(&self) -> RoundDigest<'_> {
        RoundDigest {
            start: self.start,
            halves: &self.halves,
            nodes: &self.nodes,
        }
    }

    /// Split borrows for the pull/craft/aggregate phase: immutable node
    /// state + published halves alongside the mutable output slots.
    #[allow(clippy::type_complexity)]
    pub fn split_aggregate(
        &mut self,
    ) -> (&[NodeState], &[Vec<f32>], &mut [Vec<f32>], &mut [usize]) {
        (
            &self.nodes,
            &self.halves,
            &mut self.next,
            &mut self.byz_seen,
        )
    }

    /// Synchronous swap: commit the aggregated next models.
    pub fn commit(&mut self) {
        for (node, next) in self.nodes.iter_mut().zip(&self.next) {
            node.params.copy_from_slice(next);
        }
    }
}
