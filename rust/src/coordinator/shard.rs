//! Shard backends for the round engine: the trait both the in-process
//! and the multi-process shard speak, plus the in-process implementation.
//!
//! [`ShardBackend`] is the coordinator's view of one contiguous range of
//! honest nodes. [`crate::coordinator::Trainer`] owns the **round tables**
//! (half-step rows, committed-params mirror, per-node losses / byz-seen /
//! delivered counts, all in ascending honest order) and drives every
//! backend through the same five-phase protocol:
//!
//! 1. `half_step_begin` / `half_step_end` — every owned node's local
//!    train step; the backend fills its slice of the coordinator's
//!    half-step table ([`NodeShard`] computes in place on the worker
//!    pool; [`super::proc::ProcessShard`] ships a `HalfStep` request and
//!    receives the shard's `Snapshot` — the **shipped round digest**);
//! 2. the coordinator folds the table rows, in ascending honest-node
//!    order, into the global [`crate::attacks::HonestDigest`];
//! 3. `serve_pulls` / `aggregate_begin` / `aggregate_end` — per victim:
//!    pull `S_i^t`, craft malicious rows against the digest, robustly
//!    aggregate (in-process: on the pool against the shared tables;
//!    pipe remote: the worker receives the digest + full half-step
//!    table; socket remote: `serve_pulls` ships only the digest + the
//!    routing table and the worker fetches the referenced rows from the
//!    owning peers — see [`super::peer`]); `aggregate_end` collects the
//!    per-node byz-seen / delivered counts;
//! 4. `commit` — the synchronous swap; the backend refreshes its slice
//!    of the coordinator's committed-params mirror (remote shards ship
//!    their committed rows, which is what keeps evaluation and
//!    `params_of` local and O(1) in both engines).
//!
//! The begin/end split exists for the remote backend: the coordinator
//! first *sends* a phase request to every worker, then *collects* replies
//! in shard order — all worker processes compute concurrently while the
//! in-process backends run on the coordinator's own pool.
//!
//! # Why the digest fold is centralized
//!
//! Per-shard f64 partial sums combined across shards would make the mean
//! depend on the shard grouping (f64 addition is not associative), so the
//! coordinator folds raw rows in ascending honest-node order regardless
//! of shard boundaries — one O(h·d) serial pass. Because the wire codec
//! ships rows as IEEE bit patterns, a remote shard's rows are the same
//! bytes its in-process twin would have published by borrow, and the fold
//! (hence every result) is **bit-identical across the whole
//! (procs × shards × threads) grid** — `rust/tests/determinism.rs`
//! enforces it.

use crate::aggregation::gossip::GossipAggregator as _;
use crate::aggregation::{Aggregator as _, DistCache, RowCtx};
use crate::attacks::{Attack, AttackContext, HonestDigest};
use crate::coordinator::engine::ComputeEngine;
use crate::coordinator::{AggBackend, PullSampler};
use crate::data::Shard;
use crate::util::pool::WorkerPool;
use crate::util::rng::{stream_tag, Rng};
use anyhow::Result;
use std::cell::RefCell;

/// State owned by one honest node.
pub(crate) struct NodeState {
    /// global node id in [0, n)
    pub id: usize,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    /// the node's local data shard
    pub shard: Shard,
}

/// Immutable per-round inputs to the half-step phase.
pub(crate) struct StepCtx<'a> {
    pub engine: &'a dyn ComputeEngine,
    pub lr: f32,
    pub beta: f32,
    pub wd: f32,
    pub local_steps: usize,
    pub batch: usize,
    /// Partial-participation key: a node whose
    /// `(seed, round, id, PARTICIPATE)` coin lands at or above
    /// `participation` skips the step entirely — no compute, no data-RNG
    /// or momentum advance — and publishes its committed params instead.
    /// Checked per job by global node id inside the dispatch, so every
    /// backend (in-process, worker process, virtual) derives the same
    /// active set independently. `participation = 1.0` short-circuits.
    pub seed: u64,
    pub round: usize,
    pub participation: f64,
}

/// Immutable round context for the pull/craft/aggregate phase — the
/// published half-step table plus everything the omniscient adversary
/// and the aggregation rule condition on. Identical between backends: a
/// remote worker reconstructs the same struct from the wire payload.
pub(crate) struct AggCtx<'a> {
    pub agg: &'a AggBackend,
    pub attack: Option<&'a dyn Attack>,
    pub digest: &'a HonestDigest,
    /// all honest half-steps, ascending honest order (the round table).
    /// On a routed (socket-transport) worker this is sparse: only the
    /// rows the routing table references are populated — own rows plus
    /// the rows fetched from owning peers.
    pub halves: &'a [Vec<f32>],
    /// push mode: per-victim sender lists (honest-indexed)
    pub push_recv: Option<&'a [Vec<usize>]>,
    /// Routing table `(first_victim, per-victim receive sets)`: the
    /// ordered global node ids each victim receives from this round.
    /// `Some` on the routed paths (coordinator with socket transport;
    /// worker executing `AggregateRouted`), where it *replaces* the
    /// local pull-set / push-route / neighborhood derivation — receive
    /// order is dictated by the table, so both derivations are
    /// bit-identical by construction.
    pub routes: Option<(usize, &'a [Vec<usize>])>,
    pub byz: &'a [bool],
    pub node_of: &'a [usize],
    pub sampler: Option<PullSampler>,
    pub gossip_rows: Option<&'a [Vec<(usize, f64)>]>,
    pub seed: u64,
    pub n: usize,
    pub b: usize,
    /// push topology (Byzantine senders flood every honest node)
    pub push: bool,
    pub dos: bool,
    /// Round-scoped honest↔honest distance memo shared by every victim
    /// this address space aggregates (cleared each round by its owner —
    /// the coordinator or the worker shard). `None` disables
    /// memoization; results are byte-identical either way, because hits
    /// return exactly the bits a miss computes (see
    /// [`crate::aggregation::DistCache`]). Rows the cache may serve are
    /// keyed by honest index; per-victim crafted rows are never cached.
    pub dist_cache: Option<&'a DistCache>,
    /// Lazily encoded `Aggregate` wire frame for this round: the payload
    /// (digest + table) is identical for every pipe-transport worker, so
    /// the first remote backend encodes it once and the rest reuse the
    /// bytes (`OnceLock` keeps the ctx shareable across pool threads).
    pub wire_frame: std::sync::OnceLock<Vec<u8>>,
    /// Partial-participation fraction (see [`StepCtx::participation`]):
    /// an inactive victim pulls nothing and keeps its committed params as
    /// the round's output, with zeroed byz-seen / delivered counts.
    pub participation: f64,
}

/// One contiguous range of honest nodes, driven through the round phases
/// by either the coordinator (in-process backend) or a
/// `rpel shard-worker` process (each worker owns exactly one).
/// `Send` keeps the orchestrator movable across threads with either
/// backend inside.
pub(crate) trait ShardBackend: Send {
    /// First honest index owned by this backend.
    fn start(&self) -> usize;
    /// Number of owned honest nodes.
    fn len(&self) -> usize;
    /// Async engine only: ship the round's virtual-clock staleness
    /// schedule for this backend's owned range (remote: send the
    /// `AsyncRound` frame before `HalfStep`; local: no-op — the
    /// coordinator applies the served-row policy to its own tables).
    fn begin_round_async(&mut self, _round: usize, _stale: &[u32]) -> Result<()> {
        Ok(())
    }
    /// Kick off phase 1 (remote: send the request; local: no-op).
    fn half_step_begin(&mut self, round: usize) -> Result<()>;
    /// Complete phase 1: fill this backend's slices of the half-step
    /// table and the loss table.
    fn half_step_end(
        &mut self,
        round: usize,
        ctx: &StepCtx<'_>,
        pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()>;
    /// The serve-pulls phase (socket transport only): ship the digest
    /// plus this worker's slice of the per-round pull routing table; the
    /// worker then fetches the referenced honest rows from the owning
    /// peers' listeners. No-op for in-process and pipe backends, which
    /// see the whole table in `aggregate_begin`.
    fn serve_pulls(&mut self, _round: usize, _ctx: &AggCtx<'_>) -> Result<()> {
        Ok(())
    }
    /// Kick off phases 3–4 (pipe remote: ship digest + full table;
    /// socket remote: no-op — `serve_pulls` already did; local: no-op).
    fn aggregate_begin(&mut self, round: usize, ctx: &AggCtx<'_>) -> Result<()>;
    /// Complete phases 3–4: fill byz-seen and delivered-model counts.
    fn aggregate_end(
        &mut self,
        round: usize,
        ctx: &AggCtx<'_>,
        pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()>;
    /// Phase 5: synchronous swap; refresh the committed-params mirror.
    fn commit(&mut self, params_out: &mut [Vec<f32>]) -> Result<()>;
    /// Downcast to the in-process shard, when this backend is one. The
    /// coordinator uses it to flatten all local shards' per-node jobs
    /// into **one** pool dispatch per phase (no per-shard barrier);
    /// remote backends return None.
    fn as_node_shard(&mut self) -> Option<&mut NodeShard> {
        None
    }
    /// Downcast to the virtual-node backend, when this backend is one.
    /// The coordinator uses it for the digest fold (committed prev-params
    /// live in the backend's materialized active set, not the trainer's
    /// mirror rows) and the sparse resident-state ledgers.
    fn as_virtual(&self) -> Option<&super::vnode::VirtualShard> {
        None
    }
    /// Drain this backend's wire-byte counters since the last call:
    /// `(coordinator→worker, worker→coordinator, peer-served)` bytes.
    /// In-process backends report zeros.
    fn take_wire_bytes(&mut self) -> (u64, u64, u64) {
        (0, 0, 0)
    }
    /// Install the row-codec delta reference for the coming round (the
    /// previous round's digest mean as f32; zeros before the first
    /// fold). Remote backends keep it to decode `Snapshot` blocks;
    /// in-process backends never see encoded bytes and ignore it.
    fn set_wire_ref(&mut self, _ref32: &[f32]) {}
    /// Drain this backend's row-codec byte ledgers since the last call:
    /// `(raw_bytes, encoded_bytes)` of row payloads that crossed the
    /// wire compressed (`Snapshot` always; `PullReply` on the socket
    /// transport). Equal at `compression = none`; zeros for in-process
    /// backends.
    fn take_codec_bytes(&mut self) -> (u64, u64) {
        (0, 0)
    }
    /// Drain the worker-side peer-pull retry counter accumulated since
    /// the last call (routed socket backends only; zero elsewhere). Fed
    /// by `RoundDone.retries` into the `peer_retries_per_round` ledger.
    fn take_retries(&mut self) -> u32 {
        0
    }
    /// Downcast to the multi-process backend, when this backend is one.
    /// The recovery supervisor uses it to probe worker liveness, sync
    /// the boundary-state mirror, and respawn crashed workers.
    fn as_process(&mut self) -> Option<&mut super::proc::ProcessShard> {
        None
    }
    /// Test hook: forcibly kill the backing worker process (remote
    /// backends only; returns false for in-process shards).
    fn kill_for_test(&mut self) -> bool {
        false
    }
    /// Test hook: wrap the backend's transport in the chaos fault
    /// injector (remote backends only; returns false otherwise).
    fn inject_chaos(&mut self, _plan: crate::testkit::chaos::ChaosPlan) -> bool {
        false
    }
}

/// One node's slot in the parallel half-step phase. `pub(crate)` so the
/// virtual backend ([`super::vnode`]) can stage jobs for its
/// (non-contiguous) materialized active set through the same dispatch.
pub(crate) struct HalfStepJob<'a> {
    pub node: &'a mut NodeState,
    pub half: &'a mut Vec<f32>,
    pub loss: &'a mut f64,
}

/// One victim's slot in the parallel pull/craft/aggregate phase. Carries
/// the owning node and its global honest index so jobs from many shards
/// can share a single flat dispatch.
pub(crate) struct AggJob<'a> {
    pub node: &'a NodeState,
    /// the victim's global honest index (contiguous partition)
    pub gi: usize,
    pub out: &'a mut Vec<f32>,
    pub byz_seen: &'a mut usize,
    pub received: &'a mut usize,
}

thread_local! {
    /// Per-worker crafting scratch (`b` rows of length d). Thread-local so
    /// the persistent pool's workers retain it across rounds instead of
    /// reallocating per dispatch.
    static CRAFT_ROWS: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
}

/// The in-process shard: owns its nodes' state and aggregation output
/// buffers; half-steps and per-victim aggregation run data-parallel on
/// the coordinator's persistent pool.
pub(crate) struct NodeShard {
    /// first honest index owned by this shard (honest indices are global:
    /// the shard owns `[start, start + len)`)
    pub start: usize,
    pub nodes: Vec<NodeState>,
    /// aggregated next models x^{t+1}, committed at the end of the round
    /// (row length d)
    pub next: Vec<Vec<f32>>,
}

impl NodeShard {
    pub fn new(start: usize, nodes: Vec<NodeState>, d: usize) -> NodeShard {
        let len = nodes.len();
        NodeShard {
            start,
            nodes,
            next: vec![vec![0.0f32; d]; len],
        }
    }

    pub fn shard_len(&self) -> usize {
        self.nodes.len()
    }

    /// Collect this shard's half-step jobs into a (possibly shared) flat
    /// job list.
    fn collect_half_jobs<'a>(
        &'a mut self,
        halves_out: &'a mut [Vec<f32>],
        losses_out: &'a mut [f64],
        jobs: &mut Vec<HalfStepJob<'a>>,
    ) {
        debug_assert_eq!(halves_out.len(), self.nodes.len());
        debug_assert_eq!(losses_out.len(), self.nodes.len());
        for ((node, half), loss) in self
            .nodes
            .iter_mut()
            .zip(halves_out.iter_mut())
            .zip(losses_out.iter_mut())
        {
            jobs.push(HalfStepJob { node, half, loss });
        }
    }

    /// Phase 1: every owned node's local train step, writing half-step
    /// rows and losses into the coordinator's tables.
    pub fn half_step(
        &mut self,
        ctx: &StepCtx<'_>,
        pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()> {
        let mut jobs = Vec::with_capacity(self.nodes.len());
        self.collect_half_jobs(halves_out, losses_out, &mut jobs);
        run_half_step_jobs(&mut jobs, ctx, pool)
    }

    /// Collect this shard's pull/craft/aggregate jobs into a (possibly
    /// shared) flat job list.
    fn collect_agg_jobs<'a>(
        &'a mut self,
        byz_seen_out: &'a mut [usize],
        received_out: &'a mut [usize],
        jobs: &mut Vec<AggJob<'a>>,
    ) {
        debug_assert_eq!(byz_seen_out.len(), self.nodes.len());
        debug_assert_eq!(received_out.len(), self.nodes.len());
        let start = self.start;
        for (i, (((node, out), byz_seen), received)) in self
            .nodes
            .iter()
            .zip(self.next.iter_mut())
            .zip(byz_seen_out.iter_mut())
            .zip(received_out.iter_mut())
            .enumerate()
        {
            jobs.push(AggJob {
                node,
                gi: start + i,
                out,
                byz_seen,
                received,
            });
        }
    }

    /// Phases 3–4: per owned victim — pull `S_i^t`, craft the malicious
    /// rows against the digest, robustly aggregate into the shard's next
    /// buffers. Parallel over victims; crafting scratch lives in
    /// per-worker thread-locals the persistent pool retains across rounds.
    pub fn aggregate(
        &mut self,
        round: usize,
        ctx: &AggCtx<'_>,
        pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()> {
        let mut jobs = Vec::with_capacity(self.nodes.len());
        self.collect_agg_jobs(byz_seen_out, received_out, &mut jobs);
        run_agg_jobs(&mut jobs, round, ctx, pool)
    }
}

/// Execute collected half-step jobs in one pool dispatch.
pub(crate) fn run_half_step_jobs(
    jobs: &mut Vec<HalfStepJob<'_>>,
    ctx: &StepCtx<'_>,
    pool: &WorkerPool,
) -> Result<()> {
    let engine = ctx.engine;
    let (k, batch) = (ctx.local_steps, ctx.batch);
    let (lr, beta, wd) = (ctx.lr, ctx.beta, ctx.wd);
    pool.try_for_each(jobs, |_, job| {
        if !super::vnode::is_active(ctx.seed, ctx.round, job.node.id, ctx.participation) {
            // inactive this round: no compute, no data-RNG or momentum
            // advance — peers see the committed params, and the zeroed
            // loss is excluded from the round's loss fold
            job.half.copy_from_slice(&job.node.params);
            *job.loss = 0.0;
            return Ok(());
        }
        job.half.copy_from_slice(&job.node.params);
        // batch draws come from the node's own shard stream — already
        // independent of scheduling order
        let b = job.node.shard.next_batches(k, batch);
        *job.loss = engine.train_step(
            job.half,
            &mut job.node.momentum,
            &b.x,
            &b.y,
            lr,
            beta,
            wd,
        )? as f64;
        Ok(())
    })
}

/// Execute collected pull/craft/aggregate jobs in one pool dispatch.
pub(crate) fn run_agg_jobs(
    jobs: &mut Vec<AggJob<'_>>,
    round: usize,
    ctx: &AggCtx<'_>,
    pool: &WorkerPool,
) -> Result<()> {
    // worst-case malicious rows per victim is b in every topology
    // (pull sets and graph neighborhoods are duplicate-free, and a
    // flooding push round delivers each Byzantine node once)
    let byz_rows_cap = ctx.b;
    pool.try_for_each(jobs, |_, job| {
            let node = job.node;
            let id = node.id;
            if !super::vnode::is_active(ctx.seed, round, id, ctx.participation) {
                // inactive victim: pulls nothing, aggregates nothing —
                // its committed params carry through the round unchanged
                job.out.copy_from_slice(&node.params);
                *job.byz_seen = 0;
                *job.received = 0;
                return Ok(());
            }
            // this victim's global honest index (contiguous partition)
            let gi = job.gi;
            let d = job.out.len();
            // receive set: the shipped routing table when present (routed
            // socket path — order is dictated by the table); otherwise the
            // (seed, round, id, PULL) stream, the precomputed push receive
            // row (borrowed, no clone), or the graph neighborhood
            let pulled: Vec<usize>;
            let peers: &[usize] = if let Some((first, rows)) = ctx.routes {
                &rows[gi - first]
            } else {
                match (ctx.sampler, ctx.push_recv, ctx.gossip_rows) {
                    (Some(sampler), _, _) => {
                        pulled = sampler.sample_at(ctx.seed, round, id);
                        &pulled
                    }
                    (None, Some(recv), _) => &recv[gi],
                    (None, None, Some(rows)) => {
                        pulled = rows[id]
                            .iter()
                            .map(|&(j, _)| j)
                            .filter(|&j| j != id)
                            .collect();
                        &pulled
                    }
                    _ => unreachable!(),
                }
            };

            // split into honest refs and byzantine slots
            let mut honest_rows: Vec<&[f32]> = Vec::with_capacity(peers.len());
            let mut byz_count = 0usize;
            for &p in peers {
                if ctx.byz[p] {
                    byz_count += 1;
                } else {
                    honest_rows.push(ctx.halves[ctx.node_of[p]].as_slice());
                }
            }
            if ctx.push && ctx.b > 0 && !ctx.dos {
                // flooding: every Byzantine node reaches every honest node
                // (push routes carry only honest senders, so this holds on
                // the routed path too)
                byz_count = ctx.b;
            }
            if ctx.dos {
                byz_count = 0; // withheld responses simply never arrive
            }
            *job.byz_seen = byz_count;
            // the delivered-messages ledger: model rows this victim
            // actually received (self excluded)
            *job.received = honest_rows.len() + byz_count;

            // craft per-victim malicious models into the worker's retained
            // scratch rows
            let mut byz_buf = CRAFT_ROWS.with(|cell| cell.take());
            if byz_rows_cap > 0 && (byz_buf.len() < byz_rows_cap || byz_buf[0].len() != d) {
                byz_buf = vec![vec![0.0f32; d]; byz_rows_cap];
            }
            if byz_count > 0 {
                if let Some(attack) = ctx.attack {
                    let actx = AttackContext {
                        victim_half: &ctx.halves[gi],
                        victim_prev: &node.params,
                        honest_received: &honest_rows,
                        digest: ctx.digest,
                        n: ctx.n,
                        b: ctx.b,
                    };
                    attack.craft(&actx, &mut byz_buf[..byz_count]);
                } else {
                    // b > 0 but attack "none": byzantine nodes behave as
                    // silent crashers; model them as sending the honest
                    // mean (benign)
                    for row in &mut byz_buf[..byz_count] {
                        for (o, &mu) in row.iter_mut().zip(ctx.digest.mean.iter()) {
                            *o = mu as f32;
                        }
                    }
                }
            }

            match ctx.agg {
                AggBackend::Native(rule) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    // row identities for the round distance cache: the
                    // victim's own row and every pulled honest row are
                    // published half-steps (keyed by honest index, the
                    // same key every victim derives); crafted Byzantine
                    // rows are per-victim and carry no id
                    let mut ids: Vec<Option<u32>> = Vec::with_capacity(1 + peers.len());
                    rows.push(ctx.halves[gi].as_slice());
                    ids.push(Some(gi as u32));
                    rows.extend_from_slice(&honest_rows);
                    for &p in peers {
                        if !ctx.byz[p] {
                            ids.push(Some(ctx.node_of[p] as u32));
                        }
                    }
                    debug_assert_eq!(ids.len(), rows.len());
                    for rbuf in &byz_buf[..byz_count] {
                        rows.push(rbuf);
                        ids.push(None);
                    }
                    if rows.len() < rule.min_inputs() {
                        // too few responses to aggregate robustly (push /
                        // DoS rounds): keep the local half-step
                        job.out.copy_from_slice(&ctx.halves[gi]);
                    } else {
                        let rctx = RowCtx { ids: &ids, cache: ctx.dist_cache };
                        rule.aggregate_with_ctx(&rows, &rctx, job.out);
                    }
                }
                AggBackend::Hlo(exec) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    rows.push(ctx.halves[gi].as_slice());
                    rows.extend_from_slice(&honest_rows);
                    for rbuf in &byz_buf[..byz_count] {
                        rows.push(rbuf);
                    }
                    let out = exec.run(&rows);
                    job.out.copy_from_slice(&out?);
                }
                AggBackend::Gossip(rule) => {
                    // gossip needs (model, weight) pairs in graph order
                    let rows = ctx.gossip_rows.unwrap();
                    let mut neigh: Vec<(&[f32], f64)> = Vec::with_capacity(peers.len());
                    let mut byz_used = 0usize;
                    for &(j, w) in &rows[id] {
                        if j == id {
                            continue;
                        }
                        if ctx.byz[j] {
                            // DoS: the withheld model simply never
                            // arrives — drop the edge this round
                            if ctx.dos {
                                continue;
                            }
                            neigh.push((byz_buf[byz_used].as_slice(), w));
                            byz_used += 1;
                        } else {
                            neigh.push((ctx.halves[ctx.node_of[j]].as_slice(), w));
                        }
                    }
                    rule.aggregate(&ctx.halves[gi], &neigh, job.out);
                }
            }
            CRAFT_ROWS.with(|cell| cell.replace(byz_buf));
            Ok(())
    })
}

impl NodeShard {
    /// Resume support: overwrite every owned node's state with
    /// checkpointed rows, then replay the data-RNG cursor through the
    /// first `rounds` rounds. The batch stream is the only hidden
    /// per-node state a checkpoint does not carry; each round in which
    /// the node was active consumed exactly one `next_batches` call, so
    /// re-drawing (and discarding) those batches leaves the cursor
    /// bit-identical to a straight-through run.
    #[allow(clippy::too_many_arguments)]
    pub fn install_resume(
        &mut self,
        params: &[Vec<f32>],
        momentum: &[Vec<f32>],
        rounds: u64,
        seed: u64,
        participation: f64,
        local_steps: usize,
        batch: usize,
    ) {
        debug_assert_eq!(params.len(), self.nodes.len());
        debug_assert_eq!(momentum.len(), self.nodes.len());
        for (node, (p, m)) in self.nodes.iter_mut().zip(params.iter().zip(momentum)) {
            node.params.copy_from_slice(p);
            node.momentum.copy_from_slice(m);
            for t in 0..rounds {
                if super::vnode::is_active(seed, t as usize, node.id, participation) {
                    let _ = node.shard.next_batches(local_steps, batch);
                }
            }
        }
    }

    /// Phase 5: synchronous swap — commit the aggregated next models and
    /// refresh the coordinator's committed-params mirror rows.
    pub fn commit_into(&mut self, params_out: &mut [Vec<f32>]) {
        debug_assert_eq!(params_out.len(), self.nodes.len());
        for ((node, next), out) in self.nodes.iter_mut().zip(&self.next).zip(params_out) {
            node.params.copy_from_slice(next);
            out.copy_from_slice(next);
        }
    }
}

/// Flat half-step dispatch across all in-process shards: every shard's
/// jobs in **one** pool dispatch (no per-shard barrier, no stragglers
/// idling the pool between shards).
pub(crate) fn half_step_shards<'a>(
    shards: Vec<(&'a mut NodeShard, &'a mut [Vec<f32>], &'a mut [f64])>,
    ctx: &StepCtx<'_>,
    pool: &WorkerPool,
) -> Result<()> {
    let mut jobs: Vec<HalfStepJob<'a>> =
        Vec::with_capacity(shards.iter().map(|(s, _, _)| s.shard_len()).sum());
    for (shard, halves_out, losses_out) in shards {
        shard.collect_half_jobs(halves_out, losses_out, &mut jobs);
    }
    run_half_step_jobs(&mut jobs, ctx, pool)
}

/// Flat pull/craft/aggregate dispatch across all in-process shards (see
/// [`half_step_shards`]).
pub(crate) fn aggregate_shards<'a>(
    shards: Vec<(&'a mut NodeShard, &'a mut [usize], &'a mut [usize])>,
    round: usize,
    ctx: &AggCtx<'_>,
    pool: &WorkerPool,
) -> Result<()> {
    let mut jobs: Vec<AggJob<'a>> =
        Vec::with_capacity(shards.iter().map(|(s, _, _)| s.shard_len()).sum());
    for (shard, byz_seen_out, received_out) in shards {
        shard.collect_agg_jobs(byz_seen_out, received_out, &mut jobs);
    }
    run_agg_jobs(&mut jobs, round, ctx, pool)
}

impl ShardBackend for NodeShard {
    fn start(&self) -> usize {
        self.start
    }

    fn len(&self) -> usize {
        self.shard_len()
    }

    fn half_step_begin(&mut self, _round: usize) -> Result<()> {
        Ok(())
    }

    fn half_step_end(
        &mut self,
        _round: usize,
        ctx: &StepCtx<'_>,
        pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()> {
        self.half_step(ctx, pool, halves_out, losses_out)
    }

    fn aggregate_begin(&mut self, _round: usize, _ctx: &AggCtx<'_>) -> Result<()> {
        Ok(())
    }

    fn aggregate_end(
        &mut self,
        round: usize,
        ctx: &AggCtx<'_>,
        pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()> {
        self.aggregate(round, ctx, pool, byz_seen_out, received_out)
    }

    fn commit(&mut self, params_out: &mut [Vec<f32>]) -> Result<()> {
        self.commit_into(params_out);
        Ok(())
    }

    fn as_node_shard(&mut self) -> Option<&mut NodeShard> {
        Some(self)
    }
}

/// Contiguous honest-index ranges for `parts` shards: the canonical
/// partition both the coordinator and every shard-worker process derive
/// independently (they must agree bit-for-bit on who owns what).
pub(crate) fn partition_ranges(h: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, h.max(1));
    let base = h / parts;
    let extra = h % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for k in 0..parts {
        let len = base + usize::from(k < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// Push-mode sender → recipient routes (the Appendix-D ablation): every
/// honest sender scatters to `s` recipients drawn from its
/// `(seed, round, id, PUSH)` stream; pushes to Byzantine recipients are
/// wasted messages. Iterates senders in ascending id order, so the
/// per-victim sender lists are identical however shards are hosted.
pub(crate) fn push_routes(
    seed: u64,
    round: usize,
    n: usize,
    s: usize,
    byz: &[bool],
    node_of: &[usize],
    h: usize,
) -> Vec<Vec<usize>> {
    let mut recv: Vec<Vec<usize>> = vec![Vec::new(); h];
    for id in 0..n {
        if byz[id] {
            continue;
        }
        let mut rng = Rng::stream(seed, round as u64, id as u64, stream_tag::PUSH);
        for dest in rng.sample_distinct_excluding(n, s, id) {
            if !byz[dest] {
                recv[node_of[dest]].push(id);
            }
            // pushes to Byzantine recipients are wasted messages
        }
    }
    recv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        for (h, parts) in [(10usize, 3usize), (7, 7), (5, 9), (1, 1), (12, 4)] {
            let ranges = partition_ranges(h, parts);
            assert_eq!(ranges.len(), parts.clamp(1, h));
            let mut next = 0usize;
            for &(start, len) in &ranges {
                assert_eq!(start, next);
                next += len;
            }
            assert_eq!(next, h, "h={h} parts={parts}");
            let min = ranges.iter().map(|&(_, l)| l).min().unwrap();
            let max = ranges.iter().map(|&(_, l)| l).max().unwrap();
            assert!(max - min <= 1, "balanced split");
        }
    }

    #[test]
    fn push_routes_exclude_byzantine_endpoints_and_are_pure() {
        let n = 9usize;
        let byz = vec![false, true, false, false, true, false, false, false, false];
        let mut node_of = vec![usize::MAX; n];
        let mut h = 0usize;
        for id in 0..n {
            if !byz[id] {
                node_of[id] = h;
                h += 1;
            }
        }
        let a = push_routes(7, 3, n, 4, &byz, &node_of, h);
        let b = push_routes(7, 3, n, 4, &byz, &node_of, h);
        assert_eq!(a, b, "pure function of its key");
        let total: usize = a.iter().map(|r| r.len()).sum();
        assert!(total <= h * 4, "at most s pushes per honest sender");
        for senders in &a {
            for &sender in senders {
                assert!(!byz[sender], "byzantine senders never use routes");
            }
        }
    }
}
