//! The sparse-activation **virtual-node** backend: million-node rounds
//! with lazily materialized per-node state.
//!
//! The dense engine keeps `params`, `momentum` and a materialized data
//! shard resident for every honest node — O(h·d) floats before the first
//! round runs. This backend inverts that: a node's committed state is a
//! *recipe*, not a buffer, and full vectors exist only for the nodes a
//! round actually touches.
//!
//! # Committed-state lifecycle
//!
//! ```text
//!  seed ──▶ shared init row (init_params is a pure function of the
//!  │        experiment seed, so every node starts from the SAME bits)
//!  │
//!  ├─ round t commits: delta = bits(x^{t+1}) XOR bits(x^t), appended to
//!  │  the node's delta log (all-zero deltas — skipped rounds, stale
//!  │  discards — are not stored)
//!  │
//!  ├─ log longer than COMPACT_AFTER ──▶ fold the log into a per-node
//!  │  compacted arena row, clear the log
//!  │
//!  └─ committed params of node i = (arena row | init row) XOR-folded
//!     with the log — **bit-exact**, because XOR of IEEE-754 bit patterns
//!     round-trips where f32 arithmetic would not. Materialization is a
//!     representation change, never FP noise.
//! ```
//!
//! Data is the same story: the world build snapshots each node's RNG
//! states (the `0x5AD + id` fork and the shared data stream's position)
//! plus its Dirichlet label multiset as bytes, and the actual `Shard` is
//! sampled on the node's **first** activation — producing bit-for-bit
//! the dataset the dense build would have produced — then kept (its
//! cursor/RNG must persist across activations).
//!
//! # The active set
//!
//! [`is_active`] draws the round's participation coin from the public
//! `(seed, round, node, PARTICIPATE)` stream, keyed by **global** node
//! id: the coordinator, every in-process shard, every worker process and
//! this backend derive the same active set independently, which is what
//! keeps results bit-identical across the whole transport × procs ×
//! shards × threads grid. Per round the backend:
//!
//! 1. computes the active set and materializes exactly those nodes
//!    (committed row + stored-or-zero momentum + stored-or-sampled
//!    shard);
//! 2. stages their half-step jobs through the SAME dispatch the dense
//!    engine uses ([`super::shard::run_half_step_jobs`]), then applies
//!    the async served-row transform to active rows (worker-style);
//! 3. populates the half-step table rows active victims will pull from
//!    inactive peers with those peers' committed params (pull sets are
//!    pure functions of `(seed, round, victim, PULL)`, so the set of
//!    touched rows is known before aggregation) — everything else stays
//!    an empty row;
//! 4. aggregates through [`super::shard::run_agg_jobs`] and commits by
//!    appending XOR deltas, returning momentum and shard to the store.
//!
//! Inactive nodes carry committed state at zero per-round cost: no
//! compute, no RNG or momentum advance, zero ledger counts, and peers
//! that pull them observe the committed params — exactly the dense
//! engine's `participation < 1` semantics, which is why dense and
//! virtual runs are bit-identical at every participation level.

use super::sampler::PullSampler;
use super::shard::{
    run_agg_jobs, run_half_step_jobs, AggCtx, AggJob, HalfStepJob, NodeState, ShardBackend,
    StepCtx,
};
use crate::data::{Shard, TaskInstance};
use crate::util::pool::WorkerPool;
use crate::util::rng::{stream_tag, Rng};
use crate::util::vclock::{serve_row, AsyncCfg};
use anyhow::Result;

/// Delta-log length at which a node's log is folded into its compacted
/// arena row. Small enough that `committed_row` stays O(d), large enough
/// that a node active every round doesn't re-fold per commit.
const COMPACT_AFTER: usize = 4;

/// The round's participation coin: node `node` is active in `round` iff
/// the first `f64` of its `(seed, round, node, PARTICIPATE)` stream lands
/// below `participation`. A pure function of its key — every backend in
/// every process derives the same active set. `participation >= 1.0`
/// short-circuits (the dense full-participation regime draws nothing).
pub fn is_active(seed: u64, round: usize, node: usize, participation: f64) -> bool {
    participation >= 1.0
        || Rng::stream(seed, round as u64, node as u64, stream_tag::PARTICIPATE).f64()
            < participation
}

/// Per-round footprint of the virtual backend (the sparse ledgers'
/// source): how many nodes were active, how many table rows were
/// materialized (active ∪ pulled), and the bytes actually resident in
/// the backend's stores.
#[derive(Clone, Copy, Debug, Default)]
pub struct SparseStats {
    /// honest nodes whose PARTICIPATE coin landed below the fraction
    pub active: u32,
    /// rows materialized this round: active nodes plus the inactive
    /// peers some active victim pulled
    pub materialized: u32,
    /// bytes resident in the backend after commit: seed substrate,
    /// arena rows + delta logs, stored momentum/shards/carried rows
    pub resident_bytes: u64,
}

/// Everything needed to materialize any node's state on demand, captured
/// by the world build at construction time (see
/// [`super::build_world_virtual`]): per-node RNG snapshots and compact
/// label bytes instead of sampled datasets and parameter buffers.
pub(crate) struct VirtualSeeds {
    /// global node id per honest index
    pub ids: Vec<usize>,
    /// the node's `0x5AD + id` fork, pre-`Shard::new` (whose reshuffle
    /// consumes from it)
    pub node_rngs: Vec<Rng>,
    /// the shared data stream's position just before this node's
    /// `sample_labels` draws
    pub data_rngs: Vec<Rng>,
    /// Dirichlet label multisets, flattened (class counts fit u8)
    pub labels_flat: Vec<u8>,
    /// prefix offsets into `labels_flat`, length h+1
    pub label_off: Vec<u32>,
    /// the frozen task instance (class means) all shards sample from
    pub task: TaskInstance,
}

impl VirtualSeeds {
    fn labels_of(&self, hi: usize) -> &[u8] {
        &self.labels_flat[self.label_off[hi] as usize..self.label_off[hi + 1] as usize]
    }
}

/// The sparse backend: one instance hosts ALL honest nodes (start 0,
/// length h) behind the ordinary [`ShardBackend`] protocol, so the
/// trainer drives it exactly like a remote shard — which is also what
/// keeps the round tables sparse (rows it does not touch stay empty).
pub(crate) struct VirtualShard {
    h: usize,
    d: usize,
    seed: u64,
    participation: f64,
    asyn: AsyncCfg,
    sampler: PullSampler,
    byz: Vec<bool>,
    node_of: Vec<usize>,
    seeds: VirtualSeeds,
    /// shared init row (f32 and bit views): every node's round-0 state
    init: Vec<f32>,
    init_bits: Vec<u32>,
    /// compacted arena row per node (None ⇒ still on the shared init row)
    base: Vec<Option<Box<[u32]>>>,
    /// XOR delta log per node, committed round order
    logs: Vec<Vec<Box<[u32]>>>,
    /// momentum parked between activations (None ⇒ never active: zeros)
    momentum: Vec<Option<Box<[f32]>>>,
    /// data shard parked between activations (None ⇒ sampled on first
    /// activation; MUST persist afterwards — cursor/RNG state advance)
    shards: Vec<Option<Shard>>,
    /// async engine: last fresh served row per node (the worker-side
    /// `carried` twin; only ever Some for nodes that were active+fresh)
    carried: Vec<Option<Vec<f32>>>,
    /// async engine: this round's staleness schedule + its round
    cur_stale: Vec<u32>,
    stale_round: Option<u64>,
    /// this round's materialized nodes, ascending honest index
    live: Vec<(usize, NodeState)>,
    /// aggregation outputs, parallel to `live`
    next: Vec<Vec<f32>>,
    /// sparse ledger sources for the round in flight
    round_active: u32,
    round_materialized: u32,
}

impl VirtualShard {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        seeds: VirtualSeeds,
        init: Vec<f32>,
        seed: u64,
        participation: f64,
        asyn: AsyncCfg,
        sampler: PullSampler,
        byz: Vec<bool>,
        node_of: Vec<usize>,
    ) -> VirtualShard {
        let h = seeds.ids.len();
        let d = init.len();
        let init_bits: Vec<u32> = init.iter().map(|x| x.to_bits()).collect();
        VirtualShard {
            h,
            d,
            seed,
            participation,
            asyn,
            sampler,
            byz,
            node_of,
            seeds,
            init,
            init_bits,
            base: (0..h).map(|_| None).collect(),
            logs: vec![Vec::new(); h],
            momentum: (0..h).map(|_| None).collect(),
            shards: (0..h).map(|_| None).collect(),
            carried: vec![None; h],
            cur_stale: Vec::new(),
            stale_round: None,
            live: Vec::new(),
            next: Vec::new(),
            round_active: 0,
            round_materialized: 0,
        }
    }

    /// Committed parameter bits of node `hi`: arena (or init) row
    /// XOR-folded with the delta log. Bit-exact by construction.
    fn committed_bits(&self, hi: usize) -> Vec<u32> {
        let mut bits: Vec<u32> = match &self.base[hi] {
            Some(row) => row.to_vec(),
            None => self.init_bits.clone(),
        };
        for delta in &self.logs[hi] {
            for (o, x) in bits.iter_mut().zip(delta.iter()) {
                *o ^= x;
            }
        }
        bits
    }

    /// Committed params of node `hi` as f32 — the row peers observe when
    /// they pull an inactive node, and what evaluation reads.
    pub fn committed_row(&self, hi: usize) -> Vec<f32> {
        self.committed_bits(hi)
            .into_iter()
            .map(f32::from_bits)
            .collect()
    }

    /// Record a commit: append `new` XOR committed to the delta log (an
    /// all-zero delta is dropped), compacting the log into the arena row
    /// once it grows past [`COMPACT_AFTER`].
    fn absorb(&mut self, hi: usize, new: &[f32]) {
        let old = self.committed_bits(hi);
        let mut any = false;
        let delta: Vec<u32> = new
            .iter()
            .zip(old.iter())
            .map(|(n, o)| {
                let x = n.to_bits() ^ o;
                any |= x != 0;
                x
            })
            .collect();
        if !any {
            return;
        }
        self.logs[hi].push(delta.into_boxed_slice());
        if self.logs[hi].len() > COMPACT_AFTER {
            let folded = self.committed_bits(hi);
            self.base[hi] = Some(folded.into_boxed_slice());
            self.logs[hi].clear();
        }
    }

    /// Materialize node `hi` for this round: committed params, parked or
    /// zero momentum, parked or first-touch-sampled data shard. The
    /// first-touch sample replays exactly the dense build's draws: the
    /// stored data-stream snapshot feeds `sample_labels`, then the
    /// stored node fork feeds `Shard::new`'s epoch shuffle.
    fn materialize(&mut self, hi: usize) -> NodeState {
        let params = self.committed_row(hi);
        let momentum = match self.momentum[hi].take() {
            Some(m) => m.into_vec(),
            None => vec![0.0f32; self.d],
        };
        let shard = match self.shards[hi].take() {
            Some(s) => s,
            None => {
                let labels: Vec<i32> =
                    self.seeds.labels_of(hi).iter().map(|&c| c as i32).collect();
                let mut drng = self.seeds.data_rngs[hi].clone();
                let data = self.seeds.task.sample_labels(&labels, &mut drng);
                Shard::new(data, self.seeds.node_rngs[hi].clone())
            }
        };
        NodeState {
            id: self.seeds.ids[hi],
            params,
            momentum,
            shard,
        }
    }

    /// This round's materialized nodes (ascending honest index) — the
    /// trainer's digest fold reads committed prev-params from here.
    pub(crate) fn live(&self) -> &[(usize, NodeState)] {
        &self.live
    }

    /// Export the full committed state for a round-boundary checkpoint:
    /// per-node committed params (materialized from the recipe), parked
    /// momentum (zeros for never-active nodes — bit-identical to what
    /// `materialize` would hand out), and the async carried rows. The
    /// data shards' cursor/RNG state is deliberately NOT exported: it is
    /// a pure function of `(seeds, active-round history)` and
    /// [`VirtualShard::install_resume`] replays it.
    #[allow(clippy::type_complexity)]
    pub(crate) fn export_state(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Option<Vec<f32>>>) {
        let params: Vec<Vec<f32>> = (0..self.h).map(|hi| self.committed_row(hi)).collect();
        let momentum: Vec<Vec<f32>> = (0..self.h)
            .map(|hi| match &self.momentum[hi] {
                Some(m) => m.to_vec(),
                None => vec![0.0f32; self.d],
            })
            .collect();
        (params, momentum, self.carried.to_vec())
    }

    /// Restore a checkpointed boundary: install committed params (as the
    /// node's arena row when the bits moved off the shared init row),
    /// parked momentum (collapsed back to "never active" when all bits
    /// are +0.0 — `materialize` hands out the same zeros either way),
    /// and carried rows; then replay each node's data-shard history —
    /// first-touch sample plus one `next_batches` draw per active round
    /// in `0..rounds` — so batch cursors land exactly where the
    /// straight-through run left them. Never-active nodes stay pure
    /// recipe: no arena row, no shard, zero resident cost.
    pub(crate) fn install_resume(
        &mut self,
        params: &[Vec<f32>],
        momentum: &[Vec<f32>],
        carried: &[Option<Vec<f32>>],
        rounds: u64,
        local_steps: usize,
        batch: usize,
    ) {
        debug_assert_eq!(params.len(), self.h);
        debug_assert_eq!(momentum.len(), self.h);
        debug_assert_eq!(carried.len(), self.h);
        for hi in 0..self.h {
            let bits: Vec<u32> = params[hi].iter().map(|x| x.to_bits()).collect();
            self.logs[hi].clear();
            self.base[hi] = if bits == self.init_bits {
                None
            } else {
                Some(bits.into_boxed_slice())
            };
            self.momentum[hi] = if momentum[hi].iter().all(|x| x.to_bits() == 0) {
                None
            } else {
                Some(momentum[hi].clone().into_boxed_slice())
            };
            self.carried[hi] = carried[hi].clone();
            let active_rounds: Vec<u64> = (0..rounds)
                .filter(|&t| {
                    is_active(self.seed, t as usize, self.seeds.ids[hi], self.participation)
                })
                .collect();
            self.shards[hi] = if active_rounds.is_empty() {
                None
            } else {
                let labels: Vec<i32> =
                    self.seeds.labels_of(hi).iter().map(|&c| c as i32).collect();
                let mut drng = self.seeds.data_rngs[hi].clone();
                let data = self.seeds.task.sample_labels(&labels, &mut drng);
                let mut shard = Shard::new(data, self.seeds.node_rngs[hi].clone());
                for _ in &active_rounds {
                    let _ = shard.next_batches(local_steps, batch);
                }
                Some(shard)
            };
        }
    }

    /// Resident-byte accounting plus the round's active/materialized
    /// counts. Honest about every store the backend holds onto; the
    /// trainer adds the round-table rows it owns itself.
    pub fn stats(&self) -> SparseStats {
        let d = self.d as u64;
        let h = self.h as u64;
        // the always-resident seed substrate: two 32-byte RNG snapshots,
        // the id, a label offset, the Option discriminants of the four
        // per-node stores, and the label bytes
        let mut bytes = h * (32 + 32 + 8 + 4 + 8 * 3 + 24 + 4)
            + self.seeds.labels_flat.len() as u64
            + 2 * d * 4; // shared init row, f32 + bit views
        for (hi, log) in self.logs.iter().enumerate() {
            bytes += log.len() as u64 * d * 4;
            if self.base[hi].is_some() {
                bytes += d * 4;
            }
            if self.momentum[hi].is_some() {
                bytes += d * 4;
            }
            if let Some(s) = &self.shards[hi] {
                // dataset rows + labels + the shuffle order
                bytes += s.len() as u64 * (s.dim() as u64 * 4 + 4 + 8);
            }
            if self.carried[hi].is_some() {
                bytes += d * 4;
            }
        }
        SparseStats {
            active: self.round_active,
            materialized: self.round_materialized,
            resident_bytes: bytes,
        }
    }
}

impl ShardBackend for VirtualShard {
    fn start(&self) -> usize {
        0
    }

    fn len(&self) -> usize {
        self.h
    }

    fn begin_round_async(&mut self, round: usize, stale: &[u32]) -> Result<()> {
        self.cur_stale = stale.to_vec();
        self.stale_round = Some(round as u64);
        Ok(())
    }

    fn half_step_begin(&mut self, _round: usize) -> Result<()> {
        Ok(())
    }

    fn half_step_end(
        &mut self,
        round: usize,
        ctx: &StepCtx<'_>,
        pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()> {
        debug_assert_eq!(halves_out.len(), self.h);
        // rows not rebuilt this round must not leak a previous round's
        // contents: the table starts each round all-empty, all-zero-loss
        for row in halves_out.iter_mut() {
            *row = Vec::new();
        }
        for loss in losses_out.iter_mut() {
            *loss = 0.0;
        }

        // 1. the active set, ascending — materialize exactly those nodes
        self.live.clear();
        for hi in 0..self.h {
            if is_active(self.seed, round, self.seeds.ids[hi], self.participation) {
                let node = self.materialize(hi);
                self.live.push((hi, node));
            }
        }
        self.round_active = self.live.len() as u32;

        // 2. stage the active half-step jobs through the shared dispatch
        // (split-cursor over the table slices: live is ascending)
        {
            let mut rest_h: &mut [Vec<f32>] = halves_out;
            let mut rest_l: &mut [f64] = losses_out;
            let mut offset = 0usize;
            let mut jobs: Vec<HalfStepJob<'_>> = Vec::with_capacity(self.live.len());
            for (hi, node) in self.live.iter_mut() {
                let (_, h2) = std::mem::take(&mut rest_h).split_at_mut(*hi - offset);
                let (_, l2) = std::mem::take(&mut rest_l).split_at_mut(*hi - offset);
                let (half, h3) = h2.split_first_mut().expect("hi < h");
                let (loss, l3) = l2.split_first_mut().expect("hi < h");
                rest_h = h3;
                rest_l = l3;
                offset = *hi + 1;
                *half = vec![0.0f32; self.d];
                jobs.push(HalfStepJob { node, half, loss });
            }
            run_half_step_jobs(&mut jobs, ctx, pool)?;
        }

        // 3. async engine: owner-side served-row transform on active rows
        // only — inactivity trumps staleness (an inactive node's row IS
        // its committed params, untransformed, and its carried snapshot
        // does not move)
        if self.stale_round == Some(round as u64) {
            for (hi, node) in self.live.iter() {
                serve_row(
                    &self.asyn,
                    self.cur_stale[*hi],
                    &mut halves_out[*hi],
                    &mut self.carried[*hi],
                    &node.params,
                );
            }
        }

        // 4. populate the rows active victims will pull from inactive
        // honest peers (pull sets are pure functions of the round key,
        // so the touched-row set is known now). Every other row stays
        // empty — that emptiness is the memory diet.
        let mut populated = 0u32;
        for (_, node) in self.live.iter() {
            for p in self.sampler.sample_at(self.seed, round, node.id) {
                if self.byz[p] {
                    continue; // crafted per victim, never a table row
                }
                let phi = self.node_of[p];
                if halves_out[phi].is_empty() {
                    halves_out[phi] = self.committed_row(phi);
                    populated += 1;
                }
            }
        }
        self.round_materialized = self.round_active + populated;
        Ok(())
    }

    fn aggregate_begin(&mut self, _round: usize, _ctx: &AggCtx<'_>) -> Result<()> {
        Ok(())
    }

    fn aggregate_end(
        &mut self,
        round: usize,
        ctx: &AggCtx<'_>,
        pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()> {
        debug_assert_eq!(byz_seen_out.len(), self.h);
        // inactive entries must read zero, same as the dense engine's
        // inactive-victim jobs write
        for x in byz_seen_out.iter_mut() {
            *x = 0;
        }
        for x in received_out.iter_mut() {
            *x = 0;
        }
        self.next.resize_with(self.live.len(), Vec::new);
        for row in self.next.iter_mut() {
            if row.len() != self.d {
                *row = vec![0.0f32; self.d];
            }
        }
        {
            let mut rest_b: &mut [usize] = byz_seen_out;
            let mut rest_r: &mut [usize] = received_out;
            let mut offset = 0usize;
            let mut jobs: Vec<AggJob<'_>> = Vec::with_capacity(self.live.len());
            for ((hi, node), out) in self.live.iter().zip(self.next.iter_mut()) {
                let (_, b2) = std::mem::take(&mut rest_b).split_at_mut(*hi - offset);
                let (_, r2) = std::mem::take(&mut rest_r).split_at_mut(*hi - offset);
                let (byz_seen, b3) = b2.split_first_mut().expect("hi < h");
                let (received, r3) = r2.split_first_mut().expect("hi < h");
                rest_b = b3;
                rest_r = r3;
                offset = *hi + 1;
                jobs.push(AggJob {
                    node,
                    gi: *hi,
                    out,
                    byz_seen,
                    received,
                });
            }
            run_agg_jobs(&mut jobs, round, ctx, pool)?;
        }
        // async engine: a non-fresh active node does not commit — its
        // round-t work "never arrived" (the worker-side discard twin)
        if self.stale_round == Some(round as u64) {
            for ((hi, node), next) in self.live.iter().zip(self.next.iter_mut()) {
                if self.cur_stale[*hi] != 0 {
                    next.copy_from_slice(&node.params);
                    byz_seen_out[*hi] = 0;
                    received_out[*hi] = 0;
                }
            }
        }
        Ok(())
    }

    fn commit(&mut self, params_out: &mut [Vec<f32>]) -> Result<()> {
        debug_assert_eq!(params_out.len(), self.h);
        // the mirror rows stay empty on purpose: committed params are a
        // recipe here — `Trainer::committed_params` materializes on read
        let live = std::mem::take(&mut self.live);
        for (k, (hi, node)) in live.into_iter().enumerate() {
            // take the row out so absorb can borrow self mutably; the
            // buffer goes back for next round's reuse
            let next = std::mem::take(&mut self.next[k]);
            self.absorb(hi, &next);
            self.next[k] = next;
            self.momentum[hi] = Some(node.momentum.into_boxed_slice());
            self.shards[hi] = Some(node.shard);
        }
        Ok(())
    }

    fn as_virtual(&self) -> Option<&VirtualShard> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskKind;

    #[test]
    fn is_active_is_pure_monotone_and_short_circuits() {
        // full participation never draws; the same key always lands the
        // same side; raising the fraction can only add nodes
        for node in 0..200 {
            assert!(is_active(7, 3, node, 1.0));
            let lo = is_active(7, 3, node, 0.2);
            let hi = is_active(7, 3, node, 0.8);
            assert_eq!(lo, is_active(7, 3, node, 0.2));
            if lo {
                assert!(hi, "monotone in the fraction");
            }
        }
        // the coin matches a by-hand read of the public stream
        let coin = Rng::stream(7, 3, 11, stream_tag::PARTICIPATE).f64();
        assert_eq!(is_active(7, 3, 11, 0.5), coin < 0.5);
    }

    #[test]
    fn active_fraction_tracks_participation() {
        let mut active = 0usize;
        let n = 20_000;
        for node in 0..n {
            if is_active(42, 5, node, 0.3) {
                active += 1;
            }
        }
        let frac = active as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.02, "frac={frac}");
    }

    fn tiny_shard(h: usize, d: usize) -> VirtualShard {
        let task = TaskKind::Tiny.spec().instantiate(0);
        let spn = 3usize;
        let seeds = VirtualSeeds {
            ids: (0..h).collect(),
            node_rngs: (0..h).map(|i| Rng::new(100 + i as u64)).collect(),
            data_rngs: (0..h).map(|i| Rng::new(200 + i as u64)).collect(),
            labels_flat: vec![0u8; h * spn],
            label_off: (0..=h).map(|i| (i * spn) as u32).collect(),
            task,
        };
        VirtualShard::new(
            seeds,
            vec![0.5f32; d],
            9,
            1.0,
            AsyncCfg::default(),
            PullSampler::new(h.max(2), 1),
            vec![false; h.max(2)],
            (0..h.max(2)).collect(),
        )
    }

    #[test]
    fn delta_log_roundtrips_bits_and_compacts() {
        let d = 8;
        let mut vs = tiny_shard(2, d);
        assert_eq!(vs.committed_row(0), vec![0.5f32; d]);
        // a run of commits: committed_row must always return exactly the
        // last absorbed bits, across the log→arena compaction boundary
        let mut expect = vec![0.5f32; d];
        for step in 1..=(COMPACT_AFTER * 3) {
            let row: Vec<f32> = (0..d).map(|j| (step * 31 + j) as f32 * 0.125 - 3.0).collect();
            vs.absorb(0, &row);
            expect.copy_from_slice(&row);
            let got = vs.committed_row(0);
            let got_bits: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let want_bits: Vec<u32> = expect.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "step {step}");
            assert!(vs.logs[0].len() <= COMPACT_AFTER, "log stays bounded");
        }
        assert!(vs.base[0].is_some(), "compaction produced an arena row");
        // the untouched node is still on the shared init row, log empty
        assert!(vs.base[1].is_none() && vs.logs[1].is_empty());
        assert_eq!(vs.committed_row(1), vec![0.5f32; d]);
    }

    #[test]
    fn zero_delta_commits_are_not_stored() {
        let d = 4;
        let mut vs = tiny_shard(1, d);
        let row = vs.committed_row(0);
        vs.absorb(0, &row); // identical bits: a skipped/stale round
        assert!(vs.logs[0].is_empty() && vs.base[0].is_none());
        // negative zero differs in bits from positive zero — the XOR log
        // must preserve exactly that distinction
        let signed: Vec<f32> = vec![-0.0f32; d];
        vs.absorb(0, &signed);
        assert_eq!(vs.logs[0].len(), 1);
        let got = vs.committed_row(0);
        assert!(got.iter().all(|x| x.to_bits() == (-0.0f32).to_bits()));
    }

    #[test]
    fn first_touch_materialization_is_reproducible_and_persistent() {
        let d = 6;
        let mut vs = tiny_shard(2, d);
        let a = vs.materialize(0);
        // park it back, as commit would
        vs.momentum[0] = Some(a.momentum.clone().into_boxed_slice());
        vs.shards[0] = Some(a.shard);
        // a twin backend materializing the same node gets the same bits
        let b = tiny_shard(2, d).materialize(0);
        assert_eq!(a.params, b.params);
        assert_eq!(a.momentum, b.momentum);
        // the parked shard is returned by reference on reactivation (its
        // batch cursor must persist), not resampled
        let mut re = vs.materialize(0);
        let batch1 = re.shard.next_batch(2);
        vs.shards[0] = Some(re.shard);
        let mut fresh = tiny_shard(2, d).materialize(0);
        let fresh1 = fresh.shard.next_batch(2);
        assert_eq!(batch1.x, fresh1.x, "first activation replays the dense build");
        let batch2 = vs.materialize(0).shard.next_batch(2);
        assert_ne!(batch1.x, batch2.x, "cursor advanced across activations");
    }

    #[test]
    fn stats_count_only_touched_state() {
        let d = 8;
        let mut vs = tiny_shard(4, d);
        let base = vs.stats().resident_bytes;
        let node = vs.materialize(0);
        vs.momentum[0] = Some(node.momentum.into_boxed_slice());
        vs.shards[0] = Some(node.shard);
        let row: Vec<f32> = (0..d).map(|j| j as f32).collect();
        vs.absorb(0, &row);
        let grown = vs.stats().resident_bytes;
        assert!(grown > base, "touching one node grows residency");
        // one delta row + one momentum row + the 3-sample shard — far
        // below a dense world's 2 rows per node
        assert!(grown - base < 4 * (d as u64) * 4 + 4 * 1024);
    }
}
