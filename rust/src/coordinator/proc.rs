//! Multi-process shard engine: the coordinator-side [`ProcessShard`]
//! backend and the worker-side `rpel shard-worker` loop, over either
//! wire transport.
//!
//! Each worker process rebuilds the **identical world** from the config
//! the coordinator ships in the `Init` handshake (all construction
//! randomness is derived from the experiment seed, so adversary
//! placement, data shards, graph topology and parameter init are
//! bit-identical across processes), keeps only its contiguous honest
//! range as a [`NodeShard`], and then speaks the round protocol of
//! [`crate::wire::proto`] over a [`Transport`].
//!
//! # Pipe transport (`--transport pipe`, the default)
//!
//! The worker converses on stdin/stdout; the coordinator broadcasts the
//! full half-step table each round:
//!
//! ```text
//! coordinator                         worker
//! -----------                         ------
//! spawn(shard-worker) ──────────────▶ (stdin/stdout pipes)
//! Init{config,worker,procs,resume} ─▶ build world, keep own range,
//! ◀──────────────────────── InitOk{start,len,d}   install resume state
//! per round t:
//!   HalfStep{t} ────────────────────▶ phase 1 on owned nodes
//!   ◀───────────────── Snapshot{t, losses, halves}
//!   Aggregate{t, digest, halves[h]} ▶ pull/craft/aggregate/commit
//!   ◀──────── RoundDone{t, byz, recv, 0, 0, params}
//!   GetState{t} ────────────────────▶ (supervised runs only)
//!   ◀──────── State{t, params, momentum, carried}
//! Shutdown (or EOF) ────────────────▶ exit 0
//! ```
//!
//! # Socket transport (`--transport socket|tcp`)
//!
//! The worker dials the coordinator's listener for the control channel
//! and binds its **own** listener to serve pulls; the coordinator ships
//! only the digest plus the per-round routing table, and workers fetch
//! the honest rows they lack from the owning peer (see
//! [`super::peer`]):
//!
//! ```text
//! coordinator                         worker w
//! -----------                         --------
//! bind coordinator.sock
//! spawn(shard-worker --transport socket
//!       --connect … --worker w --incarnation k)
//! ◀──────── connect + PeerHello{w, k, listen}    (worker binds its own
//! Init{config,w,procs,resume} ──────▶             pull listener first)
//! ◀──────────────────────── InitOk{start,len,d}
//! Peers{(start,len,addr)*} ─────────▶ start RowServer, build PeerClient
//! per round t:
//!   HalfStep{t} ────────────────────▶ phase 1; publish rows to RowServer
//!   ◀───────────────── Snapshot{t, losses, halves}
//!   AggregateRouted{t, digest,        fetch referenced off-shard rows
//!     routes} ──────────────────────▶   from peers (PullRequest/Reply),
//!                                       craft vs digest, aggregate
//!   ◀── RoundDone{t, byz, recv, peer_bytes, retries, params}
//!   GetState{t} ────────────────────▶ (supervised runs only)
//!   ◀──────── State{t, params, momentum, carried}
//! Shutdown (or EOF) ────────────────▶ exit 0
//! ```
//!
//! The coordinator still folds every snapshot into the [`HonestDigest`]
//! in ascending honest order, and the routing table dictates each
//! victim's receive order, so **both** transports are bit-identical with
//! the in-process engine (`rust/tests/determinism.rs` pins the whole
//! transport × procs × shards × threads grid). What changes is the
//! coordinator's downstream traffic — O(s·d + routing table) per worker
//! instead of O(h·d) — which the per-round bytes ledger in
//! [`crate::metrics::History`] measures.
//!
//! # Asynchronous rounds
//!
//! When the asynchronous engine is enabled (`[async]` config section),
//! the coordinator prepends an `AsyncRound{t, stale}` frame — the
//! virtual-clock staleness schedule for this worker's owned range —
//! before each `HalfStep`. The worker applies the served-row staleness
//! policy to its own rows *before* publishing them to the `RowServer`
//! and before encoding the `Snapshot` (so both transports serve the same
//! bytes), and discards the committed update of every non-fresh row
//! (params restored, DoS/receive counters zeroed) before `RoundDone`.
//! See [`super`] module docs for the full round-close sequence.
//!
//! # Crash recovery (supervised restart)
//!
//! With `recovery.max_worker_restarts > 0` (the default), a worker that
//! dies or hangs mid-round no longer aborts the run. The trainer keeps a
//! **boundary mirror** of every remote shard's state — committed params,
//! momentum, async carry — refreshed by a `GetState`/`State` exchange at
//! the end of each round and promoted atomically only when the whole
//! round succeeded, so on any mid-round failure the mirror still holds
//! the start-of-round boundary. [`Supervisor::try_recover`] then:
//!
//! 1. **detects** — `is_down` probes the control stream (`io_failed` on
//!    any transport/decode error; a semantic `Failed` reply is *not* a
//!    crash) and the child's exit status; on the socket transport a
//!    per-reply read timeout of `recovery.handshake_timeout_secs` turns
//!    hangs into io errors (pipes detect death via EOF only);
//! 2. **drains** — survivors get `GetState{t}` and are read until the
//!    `State` reply, discarding whatever an aborted phase left queued
//!    (request/reply ordering makes `State` the last frame in flight);
//! 3. **respawns** — each dead worker restarts with a bumped
//!    incarnation; its `PeerHello` must echo it, so stale connections
//!    are rejected, and its `Init` carries the mirror as a resume state;
//! 4. **re-drives** — the address book is re-broadcast (a respawned
//!    TCP listener moves), recovery traffic is absorbed from the wire
//!    ledgers, the trainer rolls its own tables back to the mirror
//!    boundary, and the failed round re-runs from its phase boundary.
//!
//! Survivors make the re-driven round idempotent by caching the encoded
//! `Snapshot` and `RoundDone` frames per round and re-serving the exact
//! bytes on a duplicate request — nothing recomputes, the data-RNG
//! cursor never double-advances, and the trajectory stays bit-identical
//! to an unfaulted run. A worker whose *peer pull* fails (its peer died)
//! reports `Failed` but stays alive: no state mutates before the fetch
//! phase completes, so the drain barrier can re-align it. Once a
//! worker's respawn budget is exhausted, recovery declines and the
//! original named error surfaces.
//!
//! Without supervision (`max_worker_restarts = 0`), a worker that dies
//! mid-round surfaces as an actionable error on the coordinator (EOF /
//! connection reset with the worker's exit status), and a peer that dies
//! mid-pull surfaces on the *pulling* worker (which forwards it as
//! `Failed`) — never a hang: every read is a blocking read on a stream
//! whose write end dies with the peer, and [`ProcessShard`]'s `Drop`
//! half-closes then drains so a worker blocked mid-write can always
//! finish and observe EOF.

use super::peer::{PeerClient, RowServer};
use super::shard::{self, AggCtx, NodeShard, NodeState, ShardBackend, StepCtx};
use super::{build_world, AggBackend};
use crate::attacks::{Attack, AttackKind};
use crate::config::{file as config_file, ExperimentConfig, RecoveryCfg, TransportKind};
use crate::coordinator::{ComputeEngine, PullSampler};
use crate::testkit::chaos::{ChaosPlan, ChaosTransport};
use crate::util::pool::WorkerPool;
use crate::util::vclock::serve_row;
use crate::wire::codec::{self, Compression, EncodedRows, RowCodec};
use crate::wire::proto::{self, FromWorker, PeerEntry, PeerMsg, ToWorker};
use crate::wire::transport::{
    Listener, PipeTransport, RetryPolicy, SockAddr, SocketTransport, Transport,
};
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant}; // lint: wall-clock-exempt (worker-spawn deadline only)

/// Process-wide worker-binary override for tests. A `OnceLock` instead of
/// `std::env::set_var`: mutating the environment races with concurrent
/// `Command::spawn` reading `environ` from other test threads.
static WORKER_BIN_OVERRIDE: OnceLock<PathBuf> = OnceLock::new(); // lint: global-state-exempt (test-only spawn override, first-set-wins)

/// Test hook: pin the `rpel` binary used to spawn shard workers
/// (first caller wins; later calls with the same path are no-ops).
#[doc(hidden)]
pub fn set_worker_bin(path: &str) {
    let _ = WORKER_BIN_OVERRIDE.set(PathBuf::from(path));
}

/// Locate the `rpel` binary to spawn shard workers from: the test
/// override or `RPEL_WORKER_BIN` first, then the current executable when
/// it *is* `rpel`, then siblings of the current executable
/// (`target/<profile>/deps/…` test binaries find `target/<profile>/rpel`
/// one level up).
#[allow(clippy::disallowed_methods)] // env reads are exempt-marked spawn config
fn worker_binary() -> Result<PathBuf> {
    if let Some(path) = WORKER_BIN_OVERRIDE.get() {
        return Ok(path.clone());
    }
    if let Ok(path) = std::env::var("RPEL_WORKER_BIN") { // lint: ambient-rng-exempt (spawn config only; results never depend on it)
        return Ok(PathBuf::from(path));
    }
    let exe = std::env::current_exe().context("resolving current executable")?; // lint: ambient-rng-exempt (spawn config only)
    if exe.file_stem() == Some(std::ffi::OsStr::new("rpel")) {
        return Ok(exe);
    }
    let mut candidates = Vec::new();
    if let Some(dir) = exe.parent() {
        candidates.push(dir.join("rpel"));
        if let Some(up) = dir.parent() {
            candidates.push(up.join("rpel"));
        }
    }
    for cand in &candidates {
        if cand.is_file() {
            return Ok(cand.clone());
        }
    }
    bail!(
        "cannot locate the `rpel` binary to spawn shard workers \
         (searched next to {}); set RPEL_WORKER_BIN",
        exe.display()
    )
}

fn reply_name(msg: &FromWorker) -> &'static str {
    match msg {
        FromWorker::InitOk { .. } => "InitOk",
        FromWorker::Snapshot { .. } => "Snapshot",
        FromWorker::RoundDone { .. } => "RoundDone",
        FromWorker::State { .. } => "State",
        FromWorker::Failed { .. } => "Failed",
    }
}

fn request_name(msg: &ToWorker) -> &'static str {
    match msg {
        ToWorker::Init { .. } => "Init",
        ToWorker::HalfStep { .. } => "HalfStep",
        ToWorker::Aggregate { .. } => "Aggregate",
        ToWorker::Peers { .. } => "Peers",
        ToWorker::AggregateRouted { .. } => "AggregateRouted",
        ToWorker::AsyncRound { .. } => "AsyncRound",
        ToWorker::GetState { .. } => "GetState",
        ToWorker::Shutdown => "Shutdown",
    }
}

/// Removes the per-run socket directory once the last shard drops it.
struct SockDirGuard(PathBuf);

impl Drop for SockDirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Coordinator-side handle to one `rpel shard-worker` process owning the
/// honest range `[start, start + len)`, over either transport.
pub(crate) struct ProcessShard {
    index: usize,
    start: usize,
    len: usize,
    d: usize,
    child: Child,
    conn: Option<Box<dyn Transport>>,
    /// true on the socket transport: `serve_pulls` ships the routing
    /// table and `aggregate_begin` is a no-op (and vice versa for pipes)
    routed: bool,
    /// the worker's own pull-listener address (socket transport; what
    /// the `Peers` address book redistributes)
    listen_addr: String,
    /// keeps the per-run socket directory alive until every shard drops
    _sock_dir: Option<Arc<SockDirGuard>>,
    /// committed params parked between `aggregate_end` and `commit`
    pending_params: Vec<Vec<f32>>,
    /// wire-ledger marks: transport counter values already attributed
    counted_out: u64,
    counted_in: u64,
    /// peer-served bytes reported by the last `RoundDone`
    peer_bytes: u64,
    /// row-block compression level this run's frames travel at
    comp: Compression,
    /// codec delta reference for the current round (installed by the
    /// trainer via `set_wire_ref`; decodes the worker's `Snapshot`)
    wire_ref: Vec<f32>,
    /// codec ledgers since the last `take_codec_bytes`: raw vs encoded
    /// row-payload bytes of this shard's compressed blocks
    codec_raw: u64,
    codec_enc: u64,
    /// true after any transport or frame-decode error on the control
    /// stream: the channel is unusable and only a respawn re-syncs it
    /// (a semantic `Failed` reply does NOT set this)
    io_failed: bool,
    /// how many times this worker slot has been (re)spawned; the
    /// respawn handshake rejects hellos that don't echo it
    incarnation: u32,
    /// peer-pull retries reported by `RoundDone` since the last
    /// `take_retries` (the `peer_retries_per_round` ledger source)
    retries: u32,
}

impl ProcessShard {
    /// Spawn every worker process and run all handshakes: each `Init` is
    /// sent before any `InitOk` is awaited, so the workers build their
    /// worlds **concurrently** instead of serializing behind one blocking
    /// handshake per process. `resume` is either empty (fresh start) or
    /// one boundary-state slice per shard (checkpoint resume). Returns
    /// the shards plus the [`Supervisor`] holding everything a mid-run
    /// respawn needs.
    pub fn spawn_all(
        cfg_toml: &str,
        ranges: &[(usize, usize)],
        procs: usize,
        d: usize,
        transport: TransportKind,
        socket_dir: &str,
        comp: Compression,
        recovery: &RecoveryCfg,
        resume: &[proto::WireResume],
    ) -> Result<(Vec<ProcessShard>, Supervisor)> {
        ensure!(
            resume.is_empty() || resume.len() == ranges.len(),
            "internal: {} resume slices for {} shard workers",
            resume.len(),
            ranges.len()
        );
        let timeout = Duration::from_secs(recovery.handshake_timeout_secs.max(1));
        let (mut shards, listener, coord_addr) = match transport {
            TransportKind::Pipe => (Self::spawn_all_pipe(ranges, d)?, None, String::new()),
            TransportKind::Socket | TransportKind::Tcp => {
                let tcp = transport == TransportKind::Tcp || !cfg!(unix);
                Self::spawn_all_socket(ranges, d, socket_dir, tcp, timeout, recovery.supervised())?
            }
        };
        for shard in shards.iter_mut() {
            shard.comp = comp;
        }
        let fresh = proto::WireResume::default();
        for (index, shard) in shards.iter_mut().enumerate() {
            let res = resume.get(index).unwrap_or(&fresh);
            shard.send(&proto::encode_init(cfg_toml, index as u32, procs as u32, res))?;
        }
        for shard in shards.iter_mut() {
            shard.finish_handshake()?;
        }
        if transport.is_socket() {
            // the address book completes the socket handshake: every
            // worker learns which peer serves which honest range
            let frame = proto::encode_peers(&peer_book(&shards));
            for shard in shards.iter_mut() {
                shard.send(&frame)?;
            }
        }
        // handshake traffic is construction cost, not part of the
        // per-round bytes ledger
        for shard in shards.iter_mut() {
            shard.reset_wire_marks();
        }
        let supervisor = Supervisor {
            cfg_toml: cfg_toml.to_string(),
            procs,
            transport,
            timeout,
            max_restarts: recovery.max_worker_restarts,
            listener,
            coord_addr,
            restarts: vec![0usize; ranges.len()],
        };
        Ok((shards, supervisor))
    }

    /// Pipe path: one child per range with piped stdin/stdout.
    fn spawn_all_pipe(ranges: &[(usize, usize)], d: usize) -> Result<Vec<ProcessShard>> {
        let bin = worker_binary()?;
        let mut shards = Vec::with_capacity(ranges.len());
        for (index, &(start, len)) in ranges.iter().enumerate() {
            let mut child = Command::new(&bin)
                .arg("shard-worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .spawn()
                .with_context(|| {
                    format!("spawning shard worker {index} from {}", bin.display())
                })?;
            let stdin = BufWriter::new(
                child
                    .stdin
                    .take()
                    .with_context(|| format!("shard worker {index}: stdin not piped"))?,
            );
            let stdout = BufReader::new(
                child
                    .stdout
                    .take()
                    .with_context(|| format!("shard worker {index}: stdout not piped"))?,
            );
            shards.push(ProcessShard {
                index,
                start,
                len,
                d,
                child,
                conn: Some(Box::new(PipeTransport::new(stdout, stdin))),
                routed: false,
                _sock_dir: None,
                listen_addr: String::new(),
                pending_params: Vec::new(),
                counted_out: 0,
                counted_in: 0,
                peer_bytes: 0,
                comp: Compression::None, // spawn_all overwrites
                wire_ref: Vec::new(),
                codec_raw: 0,
                codec_enc: 0,
                io_failed: false,
                incarnation: 0,
                retries: 0,
            });
        }
        Ok(shards)
    }

    /// Socket path: bind the coordinator listener, spawn the children
    /// with `--connect`, and accept + identify every control connection
    /// under the configured handshake deadline — a worker that dies
    /// before dialing in surfaces as an error naming it, never a hang.
    /// The listener stays open (returned for the supervisor) so crashed
    /// workers can dial back in mid-run.
    #[allow(clippy::disallowed_methods)] // temp_dir/pid/Instant are exempt-marked spawn plumbing
    fn spawn_all_socket(
        ranges: &[(usize, usize)],
        d: usize,
        socket_dir: &str,
        tcp: bool,
        timeout: Duration,
        supervised: bool,
    ) -> Result<(Vec<ProcessShard>, Option<Listener>, String)> {
        static DIR_SEQ: AtomicU64 = AtomicU64::new(0); // lint: global-state-exempt (socket-dir uniquifier; never observable in results)
        let (listener, guard) = if tcp {
            (Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into()))?, None)
        } else {
            let base = if socket_dir.is_empty() {
                std::env::temp_dir() // lint: ambient-rng-exempt (socket scratch location only)
            } else {
                PathBuf::from(socket_dir)
            };
            let dir = base.join(format!(
                "rpel-{}-{}",
                std::process::id(), // lint: ambient-rng-exempt (socket-path uniquifier only)
                DIR_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating socket dir {}", dir.display()))?;
            let listener = Listener::bind(&SockAddr::Unix(dir.join("coordinator.sock")))?;
            (listener, Some(Arc::new(SockDirGuard(dir))))
        };
        let coord_addr = listener.local_addr()?.to_string();

        let bin = worker_binary()?;
        let mut children: Vec<Option<Child>> = Vec::with_capacity(ranges.len());
        for index in 0..ranges.len() {
            let child = Command::new(&bin)
                .arg("shard-worker")
                .arg("--transport")
                .arg("socket")
                .arg("--connect")
                .arg(&coord_addr)
                .arg("--worker")
                .arg(index.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .with_context(|| {
                    format!("spawning shard worker {index} from {}", bin.display())
                })?;
            children.push(Some(child));
        }

        // accept + identify: PeerHello carries the worker index and the
        // address of the worker's own pull listener
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + timeout; // lint: wall-clock-exempt
        let mut conns: Vec<Option<SocketTransport>> = (0..ranges.len()).map(|_| None).collect();
        let mut listens: Vec<String> = vec![String::new(); ranges.len()];
        let accept_result = (|| -> Result<()> {
            let mut accepted = 0usize;
            while accepted < ranges.len() {
                match listener.accept() {
                    Ok(stream) => {
                        stream.set_nonblocking(false)?;
                        let mut t = SocketTransport::from_stream(stream)?;
                        // a worker that connects but never speaks must not
                        // bypass the deadline: bound the PeerHello read by
                        // the time remaining, then restore blocking reads
                        let remaining = deadline
                            .saturating_duration_since(Instant::now()) // lint: wall-clock-exempt
                            .max(Duration::from_millis(10));
                        t.set_read_timeout(Some(remaining))?;
                        let frame = t
                            .recv()
                            .context("reading PeerHello from a connecting shard worker")?;
                        // supervised runs keep a per-reply read timeout on
                        // the control stream: a hung worker turns into an
                        // io error the recovery pass can act on
                        t.set_read_timeout(if supervised { Some(timeout) } else { None })?;
                        match proto::decode_peer(&frame).context("decoding PeerHello")? {
                            PeerMsg::Hello {
                                worker,
                                incarnation,
                                listen,
                            } => {
                                let w = worker as usize;
                                ensure!(w < ranges.len(), "shard worker index {w} out of range");
                                ensure!(conns[w].is_none(), "shard worker {w} connected twice");
                                ensure!(
                                    incarnation == 0,
                                    "shard worker {w} connected with stale incarnation \
                                     {incarnation} (expected 0 at spawn)"
                                );
                                listens[w] = listen;
                                conns[w] = Some(t);
                                accepted += 1;
                            }
                            other => bail!(
                                "expected PeerHello on the coordinator socket, got {other:?}"
                            ),
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        for (i, slot) in children.iter_mut().enumerate() {
                            if let Some(child) = slot {
                                if let Some(status) = child.try_wait()? {
                                    bail!(
                                        "shard worker {i} exited before connecting: {status}"
                                    );
                                }
                            }
                        }
                        ensure!(
                            Instant::now() < deadline, // lint: wall-clock-exempt
                            "timed out waiting for {} shard workers to connect at \
                             {coord_addr} (recovery.handshake_timeout_secs = {})",
                            ranges.len() - accepted,
                            timeout.as_secs()
                        );
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        return Err(e).context("accepting shard worker control connections")
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = accept_result {
            // don't leak half-spawned workers as zombies: kill and reap
            // whatever came up before the handshake failed
            for slot in children.iter_mut() {
                if let Some(child) = slot.as_mut() {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
            return Err(e);
        }

        let mut shards = Vec::with_capacity(ranges.len());
        for (index, &(start, len)) in ranges.iter().enumerate() {
            shards.push(ProcessShard {
                index,
                start,
                len,
                d,
                child: children[index]
                    .take()
                    .with_context(|| format!("internal: shard worker {index} has no child handle"))?,
                conn: Some(Box::new(conns[index].take().with_context(|| {
                    format!("internal: shard worker {index} never connected")
                })?)),
                routed: true,
                _sock_dir: guard.clone(),
                listen_addr: std::mem::take(&mut listens[index]),
                pending_params: Vec::new(),
                counted_out: 0,
                counted_in: 0,
                peer_bytes: 0,
                comp: Compression::None, // spawn_all overwrites
                wire_ref: Vec::new(),
                codec_raw: 0,
                codec_enc: 0,
                io_failed: false,
                incarnation: 0,
                retries: 0,
            });
        }
        Ok((shards, Some(listener), coord_addr))
    }

    /// Await `InitOk` and verify the worker independently derived the
    /// same shard range.
    fn finish_handshake(&mut self) -> Result<()> {
        let (index, start, len, d) = (self.index, self.start, self.len, self.d);
        match self.recv()? {
            FromWorker::InitOk {
                start: ws,
                len: wl,
                d: wd,
            } => {
                ensure!(
                    ws == start as u64 && wl == len as u64 && wd == d as u64,
                    "shard worker {index}: partition mismatch — worker derived \
                     (start {ws}, len {wl}, d {wd}), coordinator expected \
                     (start {start}, len {len}, d {d})"
                );
                Ok(())
            }
            other => bail!(
                "shard worker {index}: expected InitOk, got {}",
                reply_name(&other)
            ),
        }
    }

    /// One line of who/what/why for errors: which worker, which honest
    /// range, and whether the process is still alive (with exit status).
    fn describe(&mut self, action: &str) -> String {
        let status = match self.child.try_wait() {
            Ok(Some(st)) => format!("worker process exited: {st}"),
            Ok(None) => "worker process still running".to_string(),
            Err(e) => format!("worker status unknown: {e}"),
        };
        format!(
            "shard worker {} (honest nodes {}..{}): {action} failed — {status}",
            self.index,
            self.start,
            self.start + self.len
        )
    }

    fn send(&mut self, payload: &[u8]) -> Result<()> {
        let result = match self.conn.as_mut() {
            Some(conn) => conn.send(payload),
            None => Err(anyhow::anyhow!("worker connection already closed")),
        };
        match result {
            Ok(()) => Ok(()),
            Err(e) => {
                self.io_failed = true;
                let what = self.describe("sending request");
                Err(e.context(what))
            }
        }
    }

    /// Receive and decode one reply, marking the stream failed on any
    /// transport or framing error (a respawn is then the only re-sync).
    /// A semantic `Failed` reply passes through — the worker is alive.
    fn recv_raw(&mut self) -> Result<FromWorker> {
        let frame = match self.conn.as_mut() {
            Some(conn) => conn.recv(),
            None => Err(anyhow::anyhow!("worker connection already closed")),
        };
        let frame = match frame {
            Ok(f) => f,
            Err(e) => {
                self.io_failed = true;
                let what = self.describe("awaiting reply");
                return Err(e.context(what));
            }
        };
        // decode through the run's row codec: Snapshot blocks arrive
        // compressed; every other reply (RoundDone rows stay raw f32)
        // is unaffected, and a `none` codec is the legacy decode
        match proto::decode_from_worker_c(&frame, &RowCodec::new(self.comp, &self.wire_ref)) {
            Ok(m) => Ok(m),
            Err(e) => {
                self.io_failed = true;
                let what = self.describe("decoding reply");
                Err(e.context(what))
            }
        }
    }

    fn recv(&mut self) -> Result<FromWorker> {
        let msg = self.recv_raw()?;
        if let FromWorker::Failed { message } = &msg {
            bail!(
                "shard worker {} (honest nodes {}..{}) reported: {message}",
                self.index,
                self.start,
                self.start + self.len
            );
        }
        Ok(msg)
    }

    /// Forget all traffic so far (handshakes and recovery sync are not
    /// ledger traffic). Also zeroes the codec/peer/retry counters an
    /// aborted round attempt may have accrued without a draining
    /// `commit`, so a re-driven round's ledgers match an unfaulted one.
    pub(crate) fn reset_wire_marks(&mut self) {
        if let Some(conn) = &self.conn {
            self.counted_out = conn.bytes_out();
            self.counted_in = conn.bytes_in();
        }
        self.peer_bytes = 0;
        self.codec_raw = 0;
        self.codec_enc = 0;
        self.retries = 0;
    }

    /// Liveness probe for the recovery pass: true when the control
    /// stream has failed or the worker process has exited.
    pub(crate) fn is_down(&mut self) -> bool {
        if self.io_failed || self.conn.is_none() {
            return true;
        }
        matches!(self.child.try_wait(), Ok(Some(_)))
    }

    /// End-of-round state sync and drain barrier: request the worker's
    /// boundary state and read until the matching `State` reply,
    /// discarding anything an aborted phase left queued ahead of it
    /// (request/reply ordering makes `State` the last frame in flight —
    /// including a parked semantic `Failed`, which is exactly why a
    /// worker stays alive after a peer-pull failure). Sync traffic is
    /// recovery bookkeeping: callers absorb it via `reset_wire_marks`.
    #[allow(clippy::type_complexity)]
    pub(crate) fn sync_state(
        &mut self,
        round: u64,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Option<Vec<f32>>>)> {
        self.send(&proto::encode_get_state(round))?;
        loop {
            match self.recv_raw()? {
                FromWorker::State {
                    round: got,
                    params,
                    momentum,
                    carried,
                } => {
                    ensure!(
                        got == round,
                        "shard worker {}: State for round {got} (expected {round})",
                        self.index
                    );
                    ensure!(
                        params.len() == self.len
                            && momentum.len() == self.len
                            && carried.len() == self.len
                            && params.iter().chain(&momentum).all(|r| r.len() == self.d)
                            && carried.iter().flatten().all(|r| r.len() == self.d),
                        "shard worker {}: malformed State ({} params, {} momentum, {} \
                         carried; expected {} of width {})",
                        self.index,
                        params.len(),
                        momentum.len(),
                        carried.len(),
                        self.len,
                        self.d
                    );
                    return Ok((params, momentum, carried));
                }
                // stale reply from an aborted phase: drain and keep reading
                _stale => continue,
            }
        }
    }

    /// Bring a crashed or hung worker back: kill and reap whatever is
    /// left, spawn a fresh process under the **next incarnation**, replay
    /// the `Init` handshake with the supervisor's boundary-state resume,
    /// and absorb the respawn traffic from the wire ledgers.
    pub(crate) fn respawn(
        &mut self,
        sup: &mut Supervisor,
        resume: &proto::WireResume,
    ) -> Result<()> {
        self.conn = None;
        let _ = self.child.kill();
        let _ = self.child.wait();
        self.io_failed = false;
        self.pending_params.clear();
        self.peer_bytes = 0;
        self.retries = 0;
        self.incarnation += 1;
        sup.restarts[self.index] += 1;
        let bin = worker_binary()?;
        match sup.transport {
            TransportKind::Pipe => {
                let mut child = Command::new(&bin)
                    .arg("shard-worker")
                    .stdin(Stdio::piped())
                    .stdout(Stdio::piped())
                    .spawn()
                    .with_context(|| {
                        format!("respawning shard worker {} from {}", self.index, bin.display())
                    })?;
                let stdin = BufWriter::new(child.stdin.take().with_context(|| {
                    format!("respawned shard worker {}: stdin not piped", self.index)
                })?);
                let stdout = BufReader::new(child.stdout.take().with_context(|| {
                    format!("respawned shard worker {}: stdout not piped", self.index)
                })?);
                self.child = child;
                self.conn = Some(Box::new(PipeTransport::new(stdout, stdin)));
            }
            TransportKind::Socket | TransportKind::Tcp => {
                self.respawn_socket(sup, &bin)?;
            }
        }
        self.send(&proto::encode_init(
            &sup.cfg_toml,
            self.index as u32,
            sup.procs as u32,
            resume,
        ))?;
        self.finish_handshake()?;
        self.reset_wire_marks();
        Ok(())
    }

    /// Socket half of [`Self::respawn`]: spawn with `--incarnation`,
    /// accept on the supervisor's (still open) listener under the
    /// handshake deadline, and reject hellos that don't echo the new
    /// incarnation — stale traffic from the previous life can never be
    /// mistaken for the respawned worker.
    #[allow(clippy::disallowed_methods)] // Instant is exempt-marked spawn plumbing
    fn respawn_socket(&mut self, sup: &mut Supervisor, bin: &PathBuf) -> Result<()> {
        let listener = sup
            .listener
            .as_ref()
            .context("internal: socket supervisor without a control listener")?;
        let child = Command::new(bin)
            .arg("shard-worker")
            .arg("--transport")
            .arg("socket")
            .arg("--connect")
            .arg(&sup.coord_addr)
            .arg("--worker")
            .arg(self.index.to_string())
            .arg("--incarnation")
            .arg(self.incarnation.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .with_context(|| {
                format!("respawning shard worker {} from {}", self.index, bin.display())
            })?;
        self.child = child;
        let deadline = Instant::now() + sup.timeout; // lint: wall-clock-exempt
        let conn = loop {
            match listener.accept() {
                Ok(stream) => {
                    stream.set_nonblocking(false)?;
                    let mut t = SocketTransport::from_stream(stream)?;
                    let remaining = deadline
                        .saturating_duration_since(Instant::now()) // lint: wall-clock-exempt
                        .max(Duration::from_millis(10));
                    t.set_read_timeout(Some(remaining))?;
                    let frame = t
                        .recv()
                        .context("reading PeerHello from a respawned shard worker")?;
                    match proto::decode_peer(&frame).context("decoding PeerHello")? {
                        PeerMsg::Hello {
                            worker,
                            incarnation,
                            listen,
                        } if worker as usize == self.index
                            && incarnation == self.incarnation =>
                        {
                            t.set_read_timeout(Some(sup.timeout))?;
                            self.listen_addr = listen;
                            break t;
                        }
                        PeerMsg::Hello {
                            worker,
                            incarnation,
                            ..
                        } => {
                            // stale connection from a previous incarnation
                            // (or a sibling's corpse): drop it, keep waiting
                            log::warn!(
                                "respawn of shard worker {}: rejecting hello from \
                                 worker {worker} incarnation {incarnation}",
                                self.index
                            );
                        }
                        other => {
                            bail!("expected PeerHello on the coordinator socket, got {other:?}")
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Some(status) = self.child.try_wait()? {
                        bail!(
                            "respawned shard worker {} exited before connecting: {status}",
                            self.index
                        );
                    }
                    ensure!(
                        Instant::now() < deadline, // lint: wall-clock-exempt
                        "timed out waiting for respawned shard worker {} to connect at \
                         {} (recovery.handshake_timeout_secs = {})",
                        self.index,
                        sup.coord_addr,
                        sup.timeout.as_secs()
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accepting a respawned shard worker"),
            }
        };
        self.conn = Some(Box::new(conn));
        Ok(())
    }
}

/// The socket address book: which worker serves which honest range.
fn peer_book(shards: &[ProcessShard]) -> Vec<PeerEntry> {
    shards
        .iter()
        .map(|s| PeerEntry {
            start: s.start as u64,
            len: s.len as u64,
            addr: s.listen_addr.clone(),
        })
        .collect()
}

/// Everything a mid-run worker respawn needs, created by
/// [`ProcessShard::spawn_all`] and held by the trainer for the lifetime
/// of the run. `max_restarts == 0` disables supervision: the first
/// worker failure surfaces as an error, exactly as before.
pub(crate) struct Supervisor {
    cfg_toml: String,
    procs: usize,
    transport: TransportKind,
    /// handshake deadline and (supervised socket runs) per-reply read
    /// timeout: `recovery.handshake_timeout_secs`
    timeout: Duration,
    max_restarts: usize,
    /// socket transport: the coordinator's control listener, kept open
    /// so respawned workers can dial back in
    listener: Option<Listener>,
    coord_addr: String,
    /// per-worker respawn counts (== each worker's current incarnation)
    restarts: Vec<usize>,
}

impl Supervisor {
    pub(crate) fn supervised(&self) -> bool {
        self.max_restarts > 0
    }

    /// Total respawns so far — the `worker_restarts_per_round` ledger
    /// reads the per-round delta of this.
    pub(crate) fn total_restarts(&self) -> usize {
        self.restarts.iter().sum()
    }

    /// The recovery pass, run after a round fails. Probes every remote
    /// shard; when at least one is down and every down worker has
    /// restart budget left: drains the survivors to the `boundary`
    /// round, respawns the dead with `resume_of(start, len)` boundary
    /// state, re-broadcasts the peer address book (a respawned TCP
    /// listener moves), and absorbs all recovery traffic from the wire
    /// ledgers. Returns false — leaving the caller's original error to
    /// surface — when nothing is down (a semantic failure, not a crash)
    /// or a down worker is out of budget.
    pub(crate) fn try_recover(
        &mut self,
        backends: &mut [Box<dyn ShardBackend>],
        boundary: u64,
        resume_of: &mut dyn FnMut(usize, usize) -> proto::WireResume,
    ) -> Result<bool> {
        if !self.supervised() {
            return Ok(false);
        }
        let mut down = vec![false; backends.len()];
        for (i, backend) in backends.iter_mut().enumerate() {
            if let Some(shard) = backend.as_process() {
                down[i] = shard.is_down();
            }
        }
        if !down.iter().any(|&x| x) {
            return Ok(false);
        }
        // drain survivors first: a worker that reported a failed peer
        // pull is idle in its loop with stale frames queued; the
        // GetState/State barrier re-aligns its stream to the boundary.
        // A survivor that io-fails during the drain joins the down set.
        for (i, backend) in backends.iter_mut().enumerate() {
            if down[i] {
                continue;
            }
            let Some(shard) = backend.as_process() else {
                continue;
            };
            if shard.sync_state(boundary).is_err() {
                if !shard.is_down() {
                    return Ok(false); // semantic sync failure: surface the original error
                }
                down[i] = true;
            }
        }
        // budget check covers every down worker before any respawn, so a
        // declined recovery leaves nothing half-restarted
        for (i, backend) in backends.iter_mut().enumerate() {
            if !down[i] {
                continue;
            }
            if let Some(shard) = backend.as_process() {
                if self.restarts[shard.index] >= self.max_restarts {
                    return Ok(false);
                }
            }
        }
        for (i, backend) in backends.iter_mut().enumerate() {
            if !down[i] {
                continue;
            }
            if let Some(shard) = backend.as_process() {
                let resume = resume_of(shard.start, shard.len);
                shard.respawn(self, &resume)?;
            }
        }
        if self.transport.is_socket() {
            // the respawned workers' listener addresses replaced the dead
            // ones': every worker rebuilds its fetch client from the new
            // book (the respawned worker is also waiting on this frame to
            // finish its handshake)
            let mut entries = Vec::with_capacity(backends.len());
            for backend in backends.iter_mut() {
                if let Some(shard) = backend.as_process() {
                    entries.push(PeerEntry {
                        start: shard.start as u64,
                        len: shard.len as u64,
                        addr: shard.listen_addr.clone(),
                    });
                }
            }
            let frame = proto::encode_peers(&entries);
            for backend in backends.iter_mut() {
                if let Some(shard) = backend.as_process() {
                    shard.send(&frame)?;
                }
            }
        }
        for backend in backends.iter_mut() {
            if let Some(shard) = backend.as_process() {
                shard.reset_wire_marks();
            }
        }
        Ok(true)
    }
}

impl ShardBackend for ProcessShard {
    fn start(&self) -> usize {
        self.start
    }

    fn len(&self) -> usize {
        self.len
    }

    fn begin_round_async(&mut self, round: usize, stale: &[u32]) -> Result<()> {
        // ships the schedule ahead of HalfStep; the frame's bytes land in
        // the per-round wire ledger like any other control traffic
        self.send(&proto::encode_async_round(round as u64, stale))
    }

    fn half_step_begin(&mut self, round: usize) -> Result<()> {
        self.send(&proto::encode_half_step(round as u64))
    }

    fn half_step_end(
        &mut self,
        round: usize,
        _ctx: &StepCtx<'_>,
        _pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()> {
        match self.recv()? {
            FromWorker::Snapshot {
                round: got,
                losses,
                halves,
            } => {
                ensure!(
                    got == round as u64,
                    "shard worker {}: stale Snapshot for round {got} (expected \
                     {round}) — an earlier round aborted mid-collection",
                    self.index
                );
                ensure!(
                    losses.len() == self.len
                        && halves.len() == self.len
                        && halves.iter().all(|r| r.len() == self.d),
                    "shard worker {}: malformed Snapshot ({} losses, {} rows; \
                     expected {} of width {})",
                    self.index,
                    losses.len(),
                    halves.len(),
                    self.len,
                    self.d
                );
                losses_out.copy_from_slice(&losses);
                for (out, row) in halves_out.iter_mut().zip(halves) {
                    *out = row;
                }
                // codec ledger: this Snapshot carried len rows of width d
                self.codec_raw += codec::block_bytes(Compression::None, self.len, self.d);
                self.codec_enc += codec::block_bytes(self.comp, self.len, self.d);
                Ok(())
            }
            other => bail!(
                "shard worker {}: expected Snapshot, got {}",
                self.index,
                reply_name(&other)
            ),
        }
    }

    fn serve_pulls(&mut self, round: usize, ctx: &AggCtx<'_>) -> Result<()> {
        if !self.routed {
            return Ok(());
        }
        let (first, rows) = ctx
            .routes
            .context("internal: socket transport without a routing table")?;
        let lo = self.start.checked_sub(first).with_context(|| {
            format!(
                "internal: routing table starts at victim {first}, past shard start {}",
                self.start
            )
        })?;
        ensure!(
            rows.len() >= lo + self.len,
            "internal: routing table has {} victims, shard {} needs {}..{}",
            rows.len(),
            self.index,
            lo,
            lo + self.len
        );
        let slice = &rows[lo..lo + self.len];
        // codec ledger: the distinct off-shard honest rows this worker
        // will fetch as PullReply payloads. The worker dedups per owning
        // peer; owners partition the honest range, so one global dedup
        // counts the identical row set (byte-exact twin of the fetch loop
        // in `WorkerShard::aggregate_commit_routed`)
        let mut pulled: Vec<usize> = Vec::new();
        for per in slice {
            for &p in per {
                if ctx.byz[p] {
                    continue; // crafted worker-side, never travels
                }
                let hi = ctx.node_of[p];
                if hi < self.start || hi >= self.start + self.len {
                    pulled.push(hi);
                }
            }
        }
        pulled.sort_unstable();
        pulled.dedup();
        self.codec_raw += codec::block_bytes(Compression::None, pulled.len(), self.d);
        self.codec_enc += codec::block_bytes(self.comp, pulled.len(), self.d);
        let as_u32: Vec<Vec<u32>> = slice
            .iter()
            .map(|per| per.iter().map(|&p| p as u32).collect())
            .collect();
        self.send(&proto::encode_aggregate_routed(
            round as u64,
            ctx.digest,
            &as_u32,
        ))
    }

    fn aggregate_begin(&mut self, round: usize, ctx: &AggCtx<'_>) -> Result<()> {
        if self.routed {
            return Ok(()); // serve_pulls already shipped the routed frame
        }
        // the payload is worker-independent: encode the O(h·d) frame once
        // per round and write the same bytes to every worker's pipe
        let frame = ctx
            .wire_frame
            .get_or_init(|| proto::encode_aggregate(round as u64, ctx.digest, ctx.halves));
        self.send(frame)
    }

    fn aggregate_end(
        &mut self,
        round: usize,
        _ctx: &AggCtx<'_>,
        _pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()> {
        match self.recv()? {
            FromWorker::RoundDone {
                round: got,
                byz_seen,
                received,
                peer_bytes,
                retries,
                params,
            } => {
                ensure!(
                    got == round as u64,
                    "shard worker {}: stale RoundDone for round {got} (expected \
                     {round}) — an earlier round aborted mid-collection",
                    self.index
                );
                ensure!(
                    byz_seen.len() == self.len
                        && received.len() == self.len
                        && params.len() == self.len
                        && params.iter().all(|r| r.len() == self.d),
                    "shard worker {}: malformed RoundDone ({} byz, {} recv, {} \
                     params; expected {} of width {})",
                    self.index,
                    byz_seen.len(),
                    received.len(),
                    params.len(),
                    self.len,
                    self.d
                );
                for (out, v) in byz_seen_out.iter_mut().zip(&byz_seen) {
                    *out = *v as usize;
                }
                for (out, v) in received_out.iter_mut().zip(&received) {
                    *out = *v as usize;
                }
                self.peer_bytes += peer_bytes;
                self.retries += retries;
                self.pending_params = params;
                Ok(())
            }
            other => bail!(
                "shard worker {}: expected RoundDone, got {}",
                self.index,
                reply_name(&other)
            ),
        }
    }

    fn commit(&mut self, params_out: &mut [Vec<f32>]) -> Result<()> {
        ensure!(
            self.pending_params.len() == params_out.len(),
            "shard worker {}: commit without a completed round",
            self.index
        );
        for (out, row) in params_out.iter_mut().zip(self.pending_params.drain(..)) {
            *out = row;
        }
        Ok(())
    }

    fn take_wire_bytes(&mut self) -> (u64, u64, u64) {
        let (out, inn) = match &self.conn {
            Some(conn) => (conn.bytes_out(), conn.bytes_in()),
            None => (self.counted_out, self.counted_in),
        };
        let delta = (out - self.counted_out, inn - self.counted_in, self.peer_bytes);
        self.counted_out = out;
        self.counted_in = inn;
        self.peer_bytes = 0;
        delta
    }

    fn set_wire_ref(&mut self, ref32: &[f32]) {
        self.wire_ref.clear();
        self.wire_ref.extend_from_slice(ref32);
    }

    fn take_codec_bytes(&mut self) -> (u64, u64) {
        let delta = (self.codec_raw, self.codec_enc);
        self.codec_raw = 0;
        self.codec_enc = 0;
        delta
    }

    fn take_retries(&mut self) -> u32 {
        std::mem::take(&mut self.retries)
    }

    fn as_process(&mut self) -> Option<&mut ProcessShard> {
        Some(self)
    }

    fn kill_for_test(&mut self) -> bool {
        // drop the connection outright (no drain — the peer is about to
        // die) so nothing blocks on a corpse
        self.conn = None;
        self.child.kill().is_ok()
    }

    fn inject_chaos(&mut self, plan: ChaosPlan) -> bool {
        match self.conn.take() {
            Some(inner) => {
                self.conn = Some(Box::new(ChaosTransport::new(inner, plan)));
                true
            }
            None => false,
        }
    }
}

impl Drop for ProcessShard {
    fn drop(&mut self) {
        if let Some(mut conn) = self.conn.take() {
            // Best effort: ask for an orderly exit, then half-close the
            // write direction and drain the read side. After an aborted
            // round (e.g. a sibling worker died) a surviving worker can
            // be blocked writing a reply nobody will read — with a reply
            // larger than the kernel buffer, wait() alone would deadlock.
            // Draining unblocks that write; the worker then observes the
            // close (pipe EOF / socket half-close) and exits.
            let _ = conn.send(&proto::encode_shutdown());
            conn.shutdown();
        }
        let _ = self.child.wait();
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// One honest shard hosted in a worker process: the same world the
/// coordinator builds, narrowed to the owned contiguous range.
struct WorkerShard {
    cfg: ExperimentConfig,
    engine: Box<dyn ComputeEngine>,
    agg: AggBackend,
    attack: Option<Box<dyn Attack>>,
    byz: Vec<bool>,
    node_of: Vec<usize>,
    sampler: Option<PullSampler>,
    push_s: Option<usize>,
    gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    pool: WorkerPool,
    shard: NodeShard,
    d: usize,
    /// honest population size (row count of the full round table)
    h: usize,
    /// the shard's slice of the round tables
    halves: Vec<Vec<f32>>,
    losses: Vec<f64>,
    byz_seen: Vec<usize>,
    received: Vec<usize>,
    params_scratch: Vec<Vec<f32>>,
    /// async engine: the half-step each owned node last served while
    /// fresh (the coordinator's `carried` twin for this range)
    carried: Vec<Option<Vec<f32>>>,
    /// async engine: this round's staleness schedule for the owned range
    /// (0 = fresh), shipped by `AsyncRound` ahead of each `HalfStep`
    cur_stale: Vec<u32>,
    /// round the `cur_stale` schedule belongs to
    stale_round: Option<u64>,
    /// round-scoped honest↔honest distance memo for this worker's
    /// victims (the per-shard twin of the coordinator's cache; cleared
    /// at the top of every aggregate phase). Bit-invisible by the
    /// [`crate::aggregation::DistCache`] contract, so per-worker caches
    /// cannot split results across the procs grid.
    dist_cache: crate::aggregation::DistCache,
    /// codec delta reference this worker encodes against: the digest
    /// mean of the last committed round as f32 (zeros before the first),
    /// kept in lockstep with the coordinator's copy via the digest in
    /// every aggregate frame
    wire_ref: Vec<f32>,
    /// the encoded block the half-step transform produced, parked until
    /// the `HalfStep` reply publishes and ships it (rows are encoded
    /// exactly once — q8 is not FP-idempotent)
    pending_block: Option<EncodedRows>,
}

impl WorkerShard {
    fn build(cfg: &ExperimentConfig, index: usize, procs: usize) -> Result<WorkerShard> {
        let world = build_world(cfg)?;
        let h = world.nodes.len();
        let parts = procs.clamp(1, h.max(1));
        ensure!(
            index < parts,
            "worker index {index} out of range for {parts} shard processes"
        );
        let ranges = shard::partition_ranges(h, parts);
        let (start, len) = ranges[index];
        let d = world.d;
        let owned: Vec<NodeState> = world.nodes.into_iter().skip(start).take(len).collect();
        debug_assert_eq!(owned.len(), len);
        // threads=0 ("all cores") would oversubscribe the machine
        // `parts`-fold with every worker running its own all-cores pool:
        // split the cores across the worker processes instead (results
        // are thread-count-invariant by design, so this is free)
        let threads = if world.cfg.threads == 0 {
            (crate::util::pool::resolve_threads(0) / parts).max(1)
        } else {
            world.cfg.threads
        };
        Ok(WorkerShard {
            engine: world.engine,
            agg: world.agg,
            attack: world.attack,
            byz: world.byz,
            node_of: world.node_of,
            sampler: world.sampler,
            push_s: world.push_s,
            gossip_rows: world.gossip_rows,
            pool: WorkerPool::new(threads),
            shard: NodeShard::new(start, owned, d),
            d,
            h,
            halves: vec![vec![0.0f32; d]; len],
            losses: vec![0.0f64; len],
            byz_seen: vec![0usize; len],
            received: vec![0usize; len],
            params_scratch: vec![vec![0.0f32; d]; len],
            carried: vec![None; len],
            cur_stale: vec![0u32; len],
            stale_round: None,
            dist_cache: crate::aggregation::DistCache::new(),
            wire_ref: vec![0.0f32; d],
            pending_block: None,
            cfg: world.cfg,
        })
    }

    /// Resume-at-boundary install (supervised respawn or checkpoint
    /// resume): overwrite the owned nodes' committed state, restore the
    /// async carry and the codec delta reference, and replay the
    /// data-RNG cursor through the first `resume.round` rounds so the
    /// next batch draw is bit-identical to a straight-through run.
    fn install_resume(&mut self, resume: &proto::WireResume) -> Result<()> {
        if resume.is_fresh() {
            return Ok(());
        }
        let len = self.shard.shard_len();
        ensure!(
            resume.params.len() == len
                && resume.momentum.len() == len
                && resume.carried.len() == len,
            "resume state has {} params / {} momentum / {} carried rows, expected {len}",
            resume.params.len(),
            resume.momentum.len(),
            resume.carried.len()
        );
        ensure!(
            resume
                .params
                .iter()
                .chain(&resume.momentum)
                .chain(resume.carried.iter().flatten())
                .all(|r| r.len() == self.d)
                && (resume.wire_ref.is_empty() || resume.wire_ref.len() == self.d),
            "resume state row width mismatch (d = {})",
            self.d
        );
        self.shard.install_resume(
            &resume.params,
            &resume.momentum,
            resume.round,
            self.cfg.seed,
            self.cfg.participation,
            self.engine.local_steps(),
            self.engine.batch(),
        );
        self.carried = resume.carried.clone();
        if !resume.wire_ref.is_empty() {
            self.wire_ref.copy_from_slice(&resume.wire_ref);
        }
        Ok(())
    }

    fn half_step(&mut self, round: usize) -> Result<()> {
        let ctx = StepCtx {
            engine: self.engine.as_ref(),
            lr: self.cfg.lr_at(round),
            beta: self.cfg.momentum,
            wd: self.cfg.weight_decay,
            local_steps: self.engine.local_steps(),
            batch: self.engine.batch(),
            seed: self.cfg.seed,
            round,
            participation: self.cfg.participation,
        };
        self.shard
            .half_step(&ctx, &self.pool, &mut self.halves, &mut self.losses)?;
        if self.cfg.asyn.is_enabled() {
            // the schedule must have arrived ahead of this HalfStep — a
            // missing or mismatched AsyncRound means the coordinator and
            // worker disagree about the round structure
            ensure!(
                self.stale_round == Some(round as u64),
                "HalfStep for round {round} without a matching AsyncRound \
                 schedule (have {:?})",
                self.stale_round
            );
            // owner-side served-row transform, BEFORE RowServer publish
            // and Snapshot encode: both transports serve the same bytes.
            // Inactivity trumps staleness: an inactive node's row is its
            // committed params as the dispatch wrote it, untransformed,
            // and its carried snapshot stays frozen (exactly what the
            // coordinator's in-process path skips)
            for (i, &st) in self.cur_stale.iter().enumerate() {
                if !super::vnode::is_active(
                    self.cfg.seed,
                    round,
                    self.shard.nodes[i].id,
                    self.cfg.participation,
                ) {
                    continue;
                }
                serve_row(
                    &self.cfg.asyn,
                    st,
                    &mut self.halves[i],
                    &mut self.carried[i],
                    &self.shard.nodes[i].params,
                );
            }
        }
        if !self.cfg.compression.is_none() {
            // publish-point transform, AFTER the served-row policy so
            // carried rows transform at serve time like the in-process
            // path: encode every row once against the round's reference,
            // keep the block for the Snapshot/RowServer, and overwrite
            // the rows with the decoded bits everyone aggregates
            let rc = RowCodec::new(self.cfg.compression, &self.wire_ref);
            self.pending_block = Some(codec::transform_rows(&rc, &mut self.halves)?);
        }
        Ok(())
    }

    /// Async engine: discard the committed update of every non-fresh
    /// node — the virtual clock says its round-`t` work never arrived,
    /// so params stay at the pre-round value and the node's DoS/receive
    /// counters read zero (exactly what the coordinator's in-process
    /// path does after its own commit).
    fn async_discard_stale(&mut self) {
        if !self.cfg.asyn.is_enabled() {
            return;
        }
        for (i, &st) in self.cur_stale.iter().enumerate() {
            if st != 0 {
                let params = &self.shard.nodes[i].params;
                self.shard.next[i].copy_from_slice(params);
                self.byz_seen[i] = 0;
                self.received[i] = 0;
            }
        }
    }

    /// Phases 3–5 against the full broadcast table (pipe transport).
    fn aggregate_commit(
        &mut self,
        round: usize,
        digest: proto::WireDigest,
        all_halves: &[Vec<f32>],
    ) -> Result<()> {
        ensure!(
            all_halves.len() == self.h && all_halves.iter().all(|r| r.len() == self.d),
            "Aggregate table has {} rows, expected {} of width {}",
            all_halves.len(),
            self.h,
            self.d
        );
        let digest = digest.into_digest();
        let push_recv: Option<Vec<Vec<usize>>> = self.push_s.map(|s| {
            shard::push_routes(
                self.cfg.seed,
                round,
                self.cfg.n,
                s,
                &self.byz,
                &self.node_of,
                self.h,
            )
        });
        self.dist_cache.clear();
        let ctx = AggCtx {
            agg: &self.agg,
            attack: self.attack.as_deref(),
            digest: &digest,
            halves: all_halves,
            push_recv: push_recv.as_deref(),
            routes: None,
            byz: &self.byz,
            node_of: &self.node_of,
            sampler: self.sampler,
            gossip_rows: self.gossip_rows.as_deref(),
            seed: self.cfg.seed,
            n: self.cfg.n,
            b: self.cfg.b,
            push: self.push_s.is_some(),
            dos: self.cfg.attack == AttackKind::Dos,
            dist_cache: Some(&self.dist_cache),
            wire_frame: std::sync::OnceLock::new(),
            participation: self.cfg.participation,
        };
        self.shard.aggregate(
            round,
            &ctx,
            &self.pool,
            &mut self.byz_seen,
            &mut self.received,
        )?;
        self.async_discard_stale();
        self.shard.commit_into(&mut self.params_scratch);
        if !self.cfg.compression.is_none() {
            // next round's delta reference: the digest the coordinator
            // just shipped (its round-t fold) — the f32 twin of the
            // coordinator's own update in its commit phase
            self.wire_ref = codec::reference_from_mean(&digest.mean);
        }
        Ok(())
    }

    /// Phases 3–5 against the shipped routing table (socket transport):
    /// fetch the referenced off-shard honest rows from the owning peers,
    /// then aggregate exactly as the pipe path would. Returns the bytes
    /// exchanged with peers (for the coordinator's ledger).
    fn aggregate_commit_routed(
        &mut self,
        round: usize,
        digest: proto::WireDigest,
        routes_wire: &[Vec<u32>],
        client: &mut PeerClient,
    ) -> Result<u64> {
        let start = self.shard.start;
        let len = self.shard.shard_len();
        ensure!(
            routes_wire.len() == len,
            "AggregateRouted has {} victims, expected {len}",
            routes_wire.len()
        );
        let mut routes: Vec<Vec<usize>> = Vec::with_capacity(len);
        for per in routes_wire {
            let mut row = Vec::with_capacity(per.len());
            for &p in per {
                let p = p as usize;
                ensure!(
                    p < self.cfg.n,
                    "routing table references node {p} (n = {})",
                    self.cfg.n
                );
                row.push(p);
            }
            routes.push(row);
        }
        // sparse round table: own rows now, referenced peer rows below —
        // no row travels that the routing table doesn't require
        let mut table: Vec<Vec<f32>> = vec![Vec::new(); self.h];
        for (i, row) in self.halves.iter().enumerate() {
            table[start + i] = row.clone();
        }
        let mut need: Vec<Vec<u32>> = vec![Vec::new(); client.peer_count()];
        for per in &routes {
            for &p in per {
                if self.byz[p] {
                    continue; // crafted locally against the digest
                }
                let hi = self.node_of[p];
                if hi >= start && hi < start + len {
                    continue; // own row
                }
                let owner = client.owner_of(hi).with_context(|| {
                    format!("routing table references honest row {hi} that no peer owns")
                })?;
                need[owner].push(hi as u32);
            }
        }
        let mut peer_bytes = 0u64;
        for (owner, mut rows) in need.into_iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            rows.sort_unstable();
            rows.dedup();
            // the reply rows decode through the same codec the owner
            // encoded with — both sides track the identical reference
            let rc = RowCodec::new(self.cfg.compression, &self.wire_ref);
            let (fetched, bytes) = client.fetch(round as u64, owner, &rows, self.d, &rc)?;
            peer_bytes += bytes;
            for (hi, row) in rows.iter().zip(fetched) {
                table[*hi as usize] = row;
            }
        }
        let digest = digest.into_digest();
        self.dist_cache.clear();
        let ctx = AggCtx {
            agg: &self.agg,
            attack: self.attack.as_deref(),
            digest: &digest,
            halves: &table,
            push_recv: None,
            routes: Some((start, &routes)),
            byz: &self.byz,
            node_of: &self.node_of,
            sampler: self.sampler,
            gossip_rows: self.gossip_rows.as_deref(),
            seed: self.cfg.seed,
            n: self.cfg.n,
            b: self.cfg.b,
            push: self.push_s.is_some(),
            dos: self.cfg.attack == AttackKind::Dos,
            dist_cache: Some(&self.dist_cache),
            wire_frame: std::sync::OnceLock::new(),
            participation: self.cfg.participation,
        };
        self.shard.aggregate(
            round,
            &ctx,
            &self.pool,
            &mut self.byz_seen,
            &mut self.received,
        )?;
        self.async_discard_stale();
        self.shard.commit_into(&mut self.params_scratch);
        if !self.cfg.compression.is_none() {
            self.wire_ref = codec::reference_from_mean(&digest.mean);
        }
        Ok(peer_bytes)
    }
}

/// The `rpel shard-worker` entry for the pipe transport: strict
/// request/reply over stdin/stdout. Returns cleanly on `Shutdown` or EOF
/// at a frame boundary; processing errors are shipped as
/// `Failed{message}` (best effort) before propagating, so the
/// coordinator sees the root cause.
pub fn run_worker<R: Read + Send, W: Write + Send>(input: R, output: W) -> Result<()> {
    let mut conn = PipeTransport::new(BufReader::new(input), BufWriter::new(output));
    run_worker_loop(&mut conn, None, 0)
}

/// The `rpel shard-worker` entry for the socket transport: bind our own
/// pull listener, dial the coordinator, identify with `PeerHello`
/// (echoing the `--incarnation` the supervisor spawned us under — a
/// respawned worker's hello is rejected unless it matches), then speak
/// the same request/reply protocol on the control connection while the
/// listener serves peers' `PullRequest`s. A respawned worker re-binds
/// the same `worker-{w}.sock` name ([`Listener::bind`] removes the dead
/// incarnation's stale file first).
pub fn run_worker_socket(connect: &str, worker: usize, incarnation: u32) -> Result<()> {
    let coord = SockAddr::parse(connect)
        .with_context(|| format!("shard worker {worker}: bad --connect address"))?;
    let listen_at = match &coord {
        SockAddr::Unix(path) => {
            let dir = path
                .parent()
                .context("coordinator socket path has no parent directory")?;
            SockAddr::Unix(dir.join(format!("worker-{worker}.sock")))
        }
        SockAddr::Tcp(_) => SockAddr::Tcp("127.0.0.1:0".into()),
    };
    let listener = Listener::bind(&listen_at)
        .with_context(|| format!("shard worker {worker}: binding pull listener"))?;
    let listen = listener.local_addr()?;
    let mut conn = SocketTransport::connect(&coord)
        .with_context(|| format!("shard worker {worker}: connecting to coordinator at {coord}"))?;
    conn.send(&proto::encode_peer_hello(
        worker as u32,
        incarnation,
        &listen.to_string(),
    ))?;
    run_worker_loop(&mut conn, Some(listener), incarnation)
}

/// The shared worker loop. `peer_listener` is `Some` on the socket
/// transport, where the `Peers` address book is expected right after the
/// `Init`/`InitOk` handshake and pull serving starts. `incarnation` is
/// nonzero when this process is a supervised respawn; its `Init` then
/// carries the boundary state to resume from, and its first fetch
/// hellos are absorbed from the byte ledgers (reconnects are recovery
/// traffic, not round traffic).
fn run_worker_loop<T: Transport>(
    conn: &mut T,
    peer_listener: Option<Listener>,
    incarnation: u32,
) -> Result<()> {
    let Some(first) = conn.recv_opt().context("shard worker: reading handshake")? else {
        return Ok(()); // closed before Init: nothing to do
    };
    let (cfg, index, procs, resume) =
        match proto::decode_to_worker(&first).context("shard worker: decoding handshake")? {
            ToWorker::Init {
                config_toml,
                worker,
                procs,
                resume,
            } => match config_file::from_toml_str(&config_toml) {
                Ok(cfg) => (cfg, worker as usize, procs as usize, resume),
                Err(e) => {
                    let _ = conn.send(&proto::encode_failed(&format!("bad config: {e}")));
                    bail!("shard worker: bad config: {e}");
                }
            },
            other => bail!("shard worker: expected Init, got {}", request_name(&other)),
        };
    let mut state = match WorkerShard::build(&cfg, index, procs) {
        Ok(state) => state,
        Err(e) => {
            let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
            return Err(e);
        }
    };
    if let Err(e) = state.install_resume(&resume) {
        let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
        return Err(e);
    }
    conn.send(&proto::encode_init_ok(
        state.shard.start as u64,
        state.shard.shard_len() as u64,
        state.d as u64,
    ))?;
    log::info!(
        "shard worker {index}/{procs}: honest nodes {}..{} (d={})",
        state.shard.start,
        state.shard.start + state.shard.shard_len(),
        state.d
    );

    // socket transport: the address book arrives before the first round
    let mut peer_net: Option<(RowServer, PeerClient)> = None;
    if let Some(listener) = peer_listener {
        let Some(frame) = conn.recv_opt()? else {
            return Ok(()); // torn down before the first round
        };
        match proto::decode_to_worker(&frame)? {
            ToWorker::Peers { peers } => {
                match build_peer_net(&state, index, incarnation, &peers, listener) {
                    Ok(net) => peer_net = Some(net),
                    Err(e) => {
                        let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
                        return Err(e);
                    }
                }
            }
            other => bail!(
                "shard worker: expected Peers after InitOk, got {}",
                request_name(&other)
            ),
        }
    }

    // Idempotent re-serve caches: a supervised re-drive of round t must
    // not recompute — the data-RNG draw in `half_step` is the only
    // hidden stream advance, and q8 encoding is not FP-idempotent — so a
    // duplicate request is answered with the exact cached reply bytes
    // (and the cached block republished to the RowServer for peers).
    let mut served_half: Option<(u64, Vec<u8>)> = None;
    let mut served_block: Option<EncodedRows> = None;
    let mut served_done: Option<(u64, Vec<u8>)> = None;

    loop {
        let Some(frame) = conn.recv_opt()? else {
            return Ok(()); // coordinator closed the stream: orderly shutdown
        };
        match proto::decode_to_worker(&frame)? {
            ToWorker::Shutdown => return Ok(()),
            ToWorker::Init { .. } => bail!("shard worker: duplicate Init"),
            ToWorker::Peers { peers } => match &mut peer_net {
                Some((_, client)) => {
                    // recovery re-broadcast after a peer respawn: rebuild
                    // the fetch client against the new address book (its
                    // reconnect hellos are absorbed — recovery traffic),
                    // keep the existing RowServer serving
                    match make_peer_client(&state, index, incarnation, true, &peers) {
                        Ok(new_client) => *client = new_client,
                        Err(e) => {
                            let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
                            return Err(e);
                        }
                    }
                }
                None => bail!("shard worker: Peers on the pipe transport (no pull listener)"),
            },
            ToWorker::GetState { round } => {
                // boundary-state sync / drain barrier: ship the committed
                // state; the reply is also the last frame in flight, so
                // the coordinator can re-align an aborted round behind it
                let params: Vec<&[f32]> = state
                    .shard
                    .nodes
                    .iter()
                    .map(|n| n.params.as_slice())
                    .collect();
                let momentum: Vec<&[f32]> = state
                    .shard
                    .nodes
                    .iter()
                    .map(|n| n.momentum.as_slice())
                    .collect();
                conn.send(&proto::encode_state(
                    round,
                    &params,
                    &momentum,
                    &state.carried,
                ))?;
            }
            ToWorker::AsyncRound { round, stale } => {
                // fire-and-forget schedule ahead of HalfStep — no reply
                if stale.len() != state.shard.shard_len() {
                    let msg = format!(
                        "AsyncRound schedule has {} entries, expected {}",
                        stale.len(),
                        state.shard.shard_len()
                    );
                    let _ = conn.send(&proto::encode_failed(&msg));
                    bail!("shard worker: {msg}");
                }
                state.cur_stale = stale;
                state.stale_round = Some(round);
            }
            ToWorker::HalfStep { round } => {
                if let Some((r, frame)) = &served_half {
                    if *r == round {
                        // re-drive of a round this incarnation already
                        // computed: republish and replay the cached bytes
                        if let Some((server, _)) = &peer_net {
                            server.publish(round, &state.halves, served_block.clone());
                        }
                        let frame = frame.clone();
                        conn.send(&frame)?;
                        continue;
                    }
                }
                match state.half_step(round as usize) {
                    Ok(()) => {
                        // the half-step transform encoded the rows once;
                        // the same cached block backs the Snapshot and
                        // every PullReply served this round (None at
                        // `none`)
                        let block = state.pending_block.take();
                        let frame = match &block {
                            Some(b) => proto::encode_snapshot_block(round, &state.losses, b),
                            None => proto::encode_snapshot(round, &state.losses, &state.halves),
                        };
                        if let Some((server, _)) = &peer_net {
                            // publish BEFORE the snapshot: the coordinator
                            // only routes peers here after seeing it
                            server.publish(round, &state.halves, block.clone());
                        }
                        conn.send(&frame)?;
                        served_half = Some((round, frame));
                        served_block = block;
                    }
                    Err(e) => {
                        let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
                        return Err(e);
                    }
                }
            }
            ToWorker::Aggregate {
                round,
                digest,
                halves,
            } => {
                if let Some((r, frame)) = &served_done {
                    if *r == round {
                        let frame = frame.clone();
                        conn.send(&frame)?;
                        continue;
                    }
                }
                match state.aggregate_commit(round as usize, digest, &halves) {
                    Ok(()) => {
                        let byz: Vec<u32> = state.byz_seen.iter().map(|&x| x as u32).collect();
                        let recv: Vec<u32> = state.received.iter().map(|&x| x as u32).collect();
                        let frame = proto::encode_round_done(
                            round,
                            &byz,
                            &recv,
                            0,
                            0,
                            &state.params_scratch,
                        );
                        conn.send(&frame)?;
                        served_done = Some((round, frame));
                    }
                    Err(e) => {
                        let _ = conn.send(&proto::encode_failed(&format!("{e:#}")));
                        return Err(e);
                    }
                }
            }
            ToWorker::AggregateRouted {
                round,
                digest,
                routes,
            } => {
                if let Some((r, frame)) = &served_done {
                    if *r == round {
                        let frame = frame.clone();
                        conn.send(&frame)?;
                        continue;
                    }
                }
                let result = match &mut peer_net {
                    Some((_, client)) => {
                        state.aggregate_commit_routed(round as usize, digest, &routes, client)
                    }
                    None => Err(anyhow::anyhow!(
                        "AggregateRouted on the pipe transport (no peer network)"
                    )),
                };
                match result {
                    Ok(peer_bytes) => {
                        let retries = match &mut peer_net {
                            Some((_, client)) => client.take_retries(),
                            None => 0,
                        };
                        let byz: Vec<u32> = state.byz_seen.iter().map(|&x| x as u32).collect();
                        let recv: Vec<u32> =
                            state.received.iter().map(|&x| x as u32).collect();
                        let frame = proto::encode_round_done(
                            round,
                            &byz,
                            &recv,
                            peer_bytes,
                            retries,
                            &state.params_scratch,
                        );
                        conn.send(&frame)?;
                        served_done = Some((round, frame));
                    }
                    Err(e) => {
                        // A failed peer pull (the peer died) is
                        // recoverable: nothing mutated before the fetch
                        // phase completed, so report Failed and stay
                        // alive — the supervisor's drain barrier
                        // re-aligns this stream before the re-drive.
                        // Without supervision the coordinator surfaces
                        // the report and tears us down via Shutdown/EOF.
                        conn.send(&proto::encode_failed(&format!("{e:#}")))?;
                    }
                }
            }
        }
    }
}

/// Build the peer fetch client from the coordinator's address book,
/// validating it against the locally derived partition. `absorb` marks
/// every lazy connect's hello as non-ledger traffic — set on respawned
/// incarnations and recovery rebuilds, whose reconnects have no
/// unfaulted-run counterpart.
fn make_peer_client(
    state: &WorkerShard,
    index: usize,
    incarnation: u32,
    absorb: bool,
    book: &[PeerEntry],
) -> Result<PeerClient> {
    let retry = RetryPolicy {
        attempts: state.cfg.recovery.retry_attempts,
        backoff_ms: state.cfg.recovery.retry_backoff_ms,
    };
    let mut client = PeerClient::new(index, incarnation, retry, book)?;
    if absorb {
        client.set_absorb_hellos(true);
    }
    ensure!(
        index < client.peer_count(),
        "peer book has {} entries, but this is worker {index}",
        client.peer_count()
    );
    let (bs, bl) = client.range_of(index);
    ensure!(
        bs == state.shard.start && bl == state.shard.shard_len(),
        "peer book range mismatch for worker {index}: book says {bs}+{bl}, \
         derived {}+{}",
        state.shard.start,
        state.shard.shard_len()
    );
    Ok(client)
}

/// Validate the coordinator's address book against the locally derived
/// partition, then start serving.
fn build_peer_net(
    state: &WorkerShard,
    index: usize,
    incarnation: u32,
    book: &[PeerEntry],
    listener: Listener,
) -> Result<(RowServer, PeerClient)> {
    let client = make_peer_client(state, index, incarnation, incarnation > 0, book)?;
    let server = RowServer::spawn(
        listener,
        index,
        state.shard.start,
        state.shard.shard_len(),
    )?;
    Ok((server, client))
}
