//! Multi-process shard engine: the coordinator-side [`ProcessShard`]
//! backend and the worker-side `rpel shard-worker` loop.
//!
//! Each worker process rebuilds the **identical world** from the config
//! the coordinator ships in the `Init` handshake (all construction
//! randomness is derived from the experiment seed, so adversary
//! placement, data shards, graph topology and parameter init are
//! bit-identical across processes), keeps only its contiguous honest
//! range as a [`NodeShard`], and then speaks the round protocol of
//! [`crate::wire::proto`] over stdin/stdout pipes:
//!
//! * `HalfStep` → run phase 1 on the owned nodes, reply with the shard's
//!   `Snapshot` — the shipped round digest (half-step rows + losses);
//! * `Aggregate` → receive the folded [`HonestDigest`] and the full
//!   half-step table, serve the owned victims' pulls from it, craft and
//!   robustly aggregate, commit, and reply `RoundDone` (byz-seen and
//!   delivered counts + committed params for the coordinator's mirror);
//! * `Shutdown` or EOF → exit cleanly.
//!
//! Both sides run the *same* [`NodeShard`] phase code — the only
//! difference between the engines is whether the round tables travel by
//! borrow or by wire, and the codec ships IEEE bit patterns, so results
//! are bit-identical (`rust/tests/determinism.rs` pins it).
//!
//! A worker that dies mid-round surfaces as an actionable error on the
//! coordinator (broken pipe / EOF with the worker's exit status), never
//! a hang: every read is a blocking read on a pipe whose write end dies
//! with the worker. Worker-side failures are shipped as `Failed{message}`
//! before exiting, so the coordinator reports the root cause.

use super::shard::{self, AggCtx, NodeShard, NodeState, ShardBackend, StepCtx};
use super::{build_world, AggBackend};
use crate::attacks::{Attack, AttackKind};
use crate::config::{file as config_file, ExperimentConfig};
use crate::coordinator::{ComputeEngine, PullSampler};
use crate::util::pool::WorkerPool;
use crate::wire;
use crate::wire::proto::{self, FromWorker, ToWorker};
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::OnceLock;

/// Process-wide worker-binary override for tests. A `OnceLock` instead of
/// `std::env::set_var`: mutating the environment races with concurrent
/// `Command::spawn` reading `environ` from other test threads.
static WORKER_BIN_OVERRIDE: OnceLock<PathBuf> = OnceLock::new();

/// Test hook: pin the `rpel` binary used to spawn shard workers
/// (first caller wins; later calls with the same path are no-ops).
#[doc(hidden)]
pub fn set_worker_bin(path: &str) {
    let _ = WORKER_BIN_OVERRIDE.set(PathBuf::from(path));
}

/// Locate the `rpel` binary to spawn shard workers from: the test
/// override or `RPEL_WORKER_BIN` first, then the current executable when
/// it *is* `rpel`, then siblings of the current executable
/// (`target/<profile>/deps/…` test binaries find `target/<profile>/rpel`
/// one level up).
fn worker_binary() -> Result<PathBuf> {
    if let Some(path) = WORKER_BIN_OVERRIDE.get() {
        return Ok(path.clone());
    }
    if let Ok(path) = std::env::var("RPEL_WORKER_BIN") {
        return Ok(PathBuf::from(path));
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    if exe.file_stem() == Some(std::ffi::OsStr::new("rpel")) {
        return Ok(exe);
    }
    let mut candidates = Vec::new();
    if let Some(dir) = exe.parent() {
        candidates.push(dir.join("rpel"));
        if let Some(up) = dir.parent() {
            candidates.push(up.join("rpel"));
        }
    }
    for cand in &candidates {
        if cand.is_file() {
            return Ok(cand.clone());
        }
    }
    bail!(
        "cannot locate the `rpel` binary to spawn shard workers \
         (searched next to {}); set RPEL_WORKER_BIN",
        exe.display()
    )
}

fn reply_name(msg: &FromWorker) -> &'static str {
    match msg {
        FromWorker::InitOk { .. } => "InitOk",
        FromWorker::Snapshot { .. } => "Snapshot",
        FromWorker::RoundDone { .. } => "RoundDone",
        FromWorker::Failed { .. } => "Failed",
    }
}

fn request_name(msg: &ToWorker) -> &'static str {
    match msg {
        ToWorker::Init { .. } => "Init",
        ToWorker::HalfStep { .. } => "HalfStep",
        ToWorker::Aggregate { .. } => "Aggregate",
        ToWorker::Shutdown => "Shutdown",
    }
}

/// Coordinator-side handle to one `rpel shard-worker` process owning the
/// honest range `[start, start + len)`.
pub(crate) struct ProcessShard {
    index: usize,
    start: usize,
    len: usize,
    d: usize,
    child: Child,
    stdin: Option<BufWriter<ChildStdin>>,
    stdout: BufReader<ChildStdout>,
    /// committed params parked between `aggregate_end` and `commit`
    pending_params: Vec<Vec<f32>>,
}

impl ProcessShard {
    /// Spawn every worker process and run all handshakes: each `Init` is
    /// sent before any `InitOk` is awaited, so the workers build their
    /// worlds **concurrently** instead of serializing behind one blocking
    /// handshake per process.
    pub fn spawn_all(
        cfg_toml: &str,
        ranges: &[(usize, usize)],
        procs: usize,
        d: usize,
    ) -> Result<Vec<ProcessShard>> {
        let mut shards = Vec::with_capacity(ranges.len());
        for (index, &(start, len)) in ranges.iter().enumerate() {
            let mut shard = ProcessShard::launch(index, start, len, d)?;
            shard.send(&proto::encode_init(cfg_toml, index as u32, procs as u32))?;
            shards.push(shard);
        }
        for shard in shards.iter_mut() {
            shard.finish_handshake()?;
        }
        Ok(shards)
    }

    /// Start the worker process with piped stdin/stdout (no handshake).
    fn launch(index: usize, start: usize, len: usize, d: usize) -> Result<ProcessShard> {
        let bin = worker_binary()?;
        let mut child = Command::new(&bin)
            .arg("shard-worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning shard worker {index} from {}", bin.display()))?;
        let stdin = BufWriter::new(child.stdin.take().expect("piped stdin"));
        let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
        Ok(ProcessShard {
            index,
            start,
            len,
            d,
            child,
            stdin: Some(stdin),
            stdout,
            pending_params: Vec::new(),
        })
    }

    /// Await `InitOk` and verify the worker independently derived the
    /// same shard range.
    fn finish_handshake(&mut self) -> Result<()> {
        let (index, start, len, d) = (self.index, self.start, self.len, self.d);
        match self.recv()? {
            FromWorker::InitOk {
                start: ws,
                len: wl,
                d: wd,
            } => {
                ensure!(
                    ws == start as u64 && wl == len as u64 && wd == d as u64,
                    "shard worker {index}: partition mismatch — worker derived \
                     (start {ws}, len {wl}, d {wd}), coordinator expected \
                     (start {start}, len {len}, d {d})"
                );
                Ok(())
            }
            other => bail!(
                "shard worker {index}: expected InitOk, got {}",
                reply_name(&other)
            ),
        }
    }

    /// One line of who/what/why for errors: which worker, which honest
    /// range, and whether the process is still alive (with exit status).
    fn describe(&mut self, action: &str) -> String {
        let status = match self.child.try_wait() {
            Ok(Some(st)) => format!("worker process exited: {st}"),
            Ok(None) => "worker process still running".to_string(),
            Err(e) => format!("worker status unknown: {e}"),
        };
        format!(
            "shard worker {} (honest nodes {}..{}): {action} failed — {status}",
            self.index,
            self.start,
            self.start + self.len
        )
    }

    fn send(&mut self, payload: &[u8]) -> Result<()> {
        let result = (|| -> Result<()> {
            let stdin = self
                .stdin
                .as_mut()
                .context("worker stdin already closed")?;
            wire::write_frame(stdin, payload)?;
            stdin.flush()?;
            Ok(())
        })();
        match result {
            Ok(()) => Ok(()),
            Err(e) => {
                let what = self.describe("sending request");
                Err(e.context(what))
            }
        }
    }

    fn recv(&mut self) -> Result<FromWorker> {
        let frame = match wire::read_frame(&mut self.stdout) {
            Ok(f) => f,
            Err(e) => {
                let what = self.describe("awaiting reply");
                return Err(e.context(what));
            }
        };
        let msg = match proto::decode_from_worker(&frame) {
            Ok(m) => m,
            Err(e) => {
                let what = self.describe("decoding reply");
                return Err(e.context(what));
            }
        };
        if let FromWorker::Failed { message } = &msg {
            bail!(
                "shard worker {} (honest nodes {}..{}) reported: {message}",
                self.index,
                self.start,
                self.start + self.len
            );
        }
        Ok(msg)
    }
}

impl ShardBackend for ProcessShard {
    fn start(&self) -> usize {
        self.start
    }

    fn len(&self) -> usize {
        self.len
    }

    fn half_step_begin(&mut self, round: usize) -> Result<()> {
        self.send(&proto::encode_half_step(round as u64))
    }

    fn half_step_end(
        &mut self,
        round: usize,
        _ctx: &StepCtx<'_>,
        _pool: &WorkerPool,
        halves_out: &mut [Vec<f32>],
        losses_out: &mut [f64],
    ) -> Result<()> {
        match self.recv()? {
            FromWorker::Snapshot {
                round: got,
                losses,
                halves,
            } => {
                ensure!(
                    got == round as u64,
                    "shard worker {}: stale Snapshot for round {got} (expected \
                     {round}) — an earlier round aborted mid-collection",
                    self.index
                );
                ensure!(
                    losses.len() == self.len
                        && halves.len() == self.len
                        && halves.iter().all(|r| r.len() == self.d),
                    "shard worker {}: malformed Snapshot ({} losses, {} rows; \
                     expected {} of width {})",
                    self.index,
                    losses.len(),
                    halves.len(),
                    self.len,
                    self.d
                );
                losses_out.copy_from_slice(&losses);
                for (out, row) in halves_out.iter_mut().zip(halves) {
                    *out = row;
                }
                Ok(())
            }
            other => bail!(
                "shard worker {}: expected Snapshot, got {}",
                self.index,
                reply_name(&other)
            ),
        }
    }

    fn aggregate_begin(&mut self, round: usize, ctx: &AggCtx<'_>) -> Result<()> {
        // the payload is worker-independent: encode the O(h·d) frame once
        // per round and write the same bytes to every worker's pipe
        let frame = ctx
            .wire_frame
            .get_or_init(|| proto::encode_aggregate(round as u64, ctx.digest, ctx.halves));
        self.send(frame)
    }

    fn aggregate_end(
        &mut self,
        round: usize,
        _ctx: &AggCtx<'_>,
        _pool: &WorkerPool,
        byz_seen_out: &mut [usize],
        received_out: &mut [usize],
    ) -> Result<()> {
        match self.recv()? {
            FromWorker::RoundDone {
                round: got,
                byz_seen,
                received,
                params,
            } => {
                ensure!(
                    got == round as u64,
                    "shard worker {}: stale RoundDone for round {got} (expected \
                     {round}) — an earlier round aborted mid-collection",
                    self.index
                );
                ensure!(
                    byz_seen.len() == self.len
                        && received.len() == self.len
                        && params.len() == self.len
                        && params.iter().all(|r| r.len() == self.d),
                    "shard worker {}: malformed RoundDone ({} byz, {} recv, {} \
                     params; expected {} of width {})",
                    self.index,
                    byz_seen.len(),
                    received.len(),
                    params.len(),
                    self.len,
                    self.d
                );
                for (out, v) in byz_seen_out.iter_mut().zip(&byz_seen) {
                    *out = *v as usize;
                }
                for (out, v) in received_out.iter_mut().zip(&received) {
                    *out = *v as usize;
                }
                self.pending_params = params;
                Ok(())
            }
            other => bail!(
                "shard worker {}: expected RoundDone, got {}",
                self.index,
                reply_name(&other)
            ),
        }
    }

    fn commit(&mut self, params_out: &mut [Vec<f32>]) -> Result<()> {
        ensure!(
            self.pending_params.len() == params_out.len(),
            "shard worker {}: commit without a completed round",
            self.index
        );
        for (out, row) in params_out.iter_mut().zip(self.pending_params.drain(..)) {
            *out = row;
        }
        Ok(())
    }

    fn kill_for_test(&mut self) -> bool {
        self.stdin = None; // close the pipe so nothing blocks on a corpse
        self.child.kill().is_ok()
    }
}

impl Drop for ProcessShard {
    fn drop(&mut self) {
        if let Some(mut stdin) = self.stdin.take() {
            let _ = wire::write_frame(&mut stdin, &proto::encode_shutdown());
            let _ = stdin.flush();
            // dropping the write end closes the pipe: EOF doubles as
            // Shutdown for workers that missed the frame
        }
        // Drain the worker's stdout before reaping: after an aborted
        // round (e.g. a sibling worker died) a surviving worker can be
        // blocked writing a reply nobody will read — with a reply larger
        // than the pipe buffer, wait() alone would deadlock. Draining
        // unblocks that write; the worker then reads EOF and exits.
        let _ = std::io::copy(&mut self.stdout, &mut std::io::sink());
        let _ = self.child.wait();
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// One honest shard hosted in a worker process: the same world the
/// coordinator builds, narrowed to the owned contiguous range.
struct WorkerShard {
    cfg: ExperimentConfig,
    engine: Box<dyn ComputeEngine>,
    agg: AggBackend,
    attack: Option<Box<dyn Attack>>,
    byz: Vec<bool>,
    node_of: Vec<usize>,
    sampler: Option<PullSampler>,
    push_s: Option<usize>,
    gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    pool: WorkerPool,
    shard: NodeShard,
    d: usize,
    /// honest population size (row count of the broadcast table)
    h: usize,
    /// the shard's slice of the round tables
    halves: Vec<Vec<f32>>,
    losses: Vec<f64>,
    byz_seen: Vec<usize>,
    received: Vec<usize>,
    params_scratch: Vec<Vec<f32>>,
}

impl WorkerShard {
    fn build(cfg: &ExperimentConfig, index: usize, procs: usize) -> Result<WorkerShard> {
        let world = build_world(cfg)?;
        let h = world.nodes.len();
        let parts = procs.clamp(1, h.max(1));
        ensure!(
            index < parts,
            "worker index {index} out of range for {parts} shard processes"
        );
        let ranges = shard::partition_ranges(h, parts);
        let (start, len) = ranges[index];
        let d = world.d;
        let owned: Vec<NodeState> = world.nodes.into_iter().skip(start).take(len).collect();
        debug_assert_eq!(owned.len(), len);
        // threads=0 ("all cores") would oversubscribe the machine
        // `parts`-fold with every worker running its own all-cores pool:
        // split the cores across the worker processes instead (results
        // are thread-count-invariant by design, so this is free)
        let threads = if world.cfg.threads == 0 {
            (crate::util::pool::resolve_threads(0) / parts).max(1)
        } else {
            world.cfg.threads
        };
        Ok(WorkerShard {
            engine: world.engine,
            agg: world.agg,
            attack: world.attack,
            byz: world.byz,
            node_of: world.node_of,
            sampler: world.sampler,
            push_s: world.push_s,
            gossip_rows: world.gossip_rows,
            pool: WorkerPool::new(threads),
            shard: NodeShard::new(start, owned, d),
            d,
            h,
            halves: vec![vec![0.0f32; d]; len],
            losses: vec![0.0f64; len],
            byz_seen: vec![0usize; len],
            received: vec![0usize; len],
            params_scratch: vec![vec![0.0f32; d]; len],
            cfg: world.cfg,
        })
    }

    fn half_step(&mut self, round: usize) -> Result<()> {
        let ctx = StepCtx {
            engine: self.engine.as_ref(),
            lr: self.cfg.lr_at(round),
            beta: self.cfg.momentum,
            wd: self.cfg.weight_decay,
            local_steps: self.engine.local_steps(),
            batch: self.engine.batch(),
        };
        self.shard
            .half_step(&ctx, &self.pool, &mut self.halves, &mut self.losses)
    }

    fn aggregate_commit(
        &mut self,
        round: usize,
        digest: proto::WireDigest,
        all_halves: &[Vec<f32>],
    ) -> Result<()> {
        ensure!(
            all_halves.len() == self.h && all_halves.iter().all(|r| r.len() == self.d),
            "Aggregate table has {} rows, expected {} of width {}",
            all_halves.len(),
            self.h,
            self.d
        );
        let digest = digest.into_digest();
        let push_recv: Option<Vec<Vec<usize>>> = self.push_s.map(|s| {
            shard::push_routes(
                self.cfg.seed,
                round,
                self.cfg.n,
                s,
                &self.byz,
                &self.node_of,
                self.h,
            )
        });
        let ctx = AggCtx {
            agg: &self.agg,
            attack: self.attack.as_deref(),
            digest: &digest,
            halves: all_halves,
            push_recv: push_recv.as_deref(),
            byz: &self.byz,
            node_of: &self.node_of,
            sampler: self.sampler,
            gossip_rows: self.gossip_rows.as_deref(),
            seed: self.cfg.seed,
            n: self.cfg.n,
            b: self.cfg.b,
            dos: self.cfg.attack == AttackKind::Dos,
            wire_frame: std::sync::OnceLock::new(),
        };
        self.shard.aggregate(
            round,
            &ctx,
            &self.pool,
            &mut self.byz_seen,
            &mut self.received,
        )?;
        self.shard.commit_into(&mut self.params_scratch);
        Ok(())
    }
}

fn send_reply(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    wire::write_frame(w, payload)?;
    w.flush()?;
    Ok(())
}

/// The `rpel shard-worker` main loop: strict request/reply over the given
/// streams. Returns cleanly on `Shutdown` or EOF at a frame boundary;
/// processing errors are shipped as `Failed{message}` (best effort)
/// before propagating, so the coordinator sees the root cause.
pub fn run_worker<R: Read, W: Write>(mut input: R, mut output: W) -> Result<()> {
    let Some(first) = wire::read_frame_opt(&mut input).context("shard worker: reading handshake")?
    else {
        return Ok(()); // closed before Init: nothing to do
    };
    let (cfg, index, procs) =
        match proto::decode_to_worker(&first).context("shard worker: decoding handshake")? {
            ToWorker::Init {
                config_toml,
                worker,
                procs,
            } => match config_file::from_toml_str(&config_toml) {
                Ok(cfg) => (cfg, worker as usize, procs as usize),
                Err(e) => {
                    let _ = send_reply(
                        &mut output,
                        &proto::encode_failed(&format!("bad config: {e}")),
                    );
                    bail!("shard worker: bad config: {e}");
                }
            },
            other => bail!(
                "shard worker: expected Init, got {}",
                request_name(&other)
            ),
        };
    let mut state = match WorkerShard::build(&cfg, index, procs) {
        Ok(state) => state,
        Err(e) => {
            let _ = send_reply(&mut output, &proto::encode_failed(&format!("{e:#}")));
            return Err(e);
        }
    };
    send_reply(
        &mut output,
        &proto::encode_init_ok(
            state.shard.start as u64,
            state.shard.shard_len() as u64,
            state.d as u64,
        ),
    )?;
    log::info!(
        "shard worker {index}/{procs}: honest nodes {}..{} (d={})",
        state.shard.start,
        state.shard.start + state.shard.shard_len(),
        state.d
    );

    loop {
        let Some(frame) = wire::read_frame_opt(&mut input)? else {
            return Ok(()); // coordinator closed the pipe: orderly shutdown
        };
        match proto::decode_to_worker(&frame)? {
            ToWorker::Shutdown => return Ok(()),
            ToWorker::Init { .. } => bail!("shard worker: duplicate Init"),
            ToWorker::HalfStep { round } => match state.half_step(round as usize) {
                Ok(()) => send_reply(
                    &mut output,
                    &proto::encode_snapshot(round, &state.losses, &state.halves),
                )?,
                Err(e) => {
                    let _ =
                        send_reply(&mut output, &proto::encode_failed(&format!("{e:#}")));
                    return Err(e);
                }
            },
            ToWorker::Aggregate {
                round,
                digest,
                halves,
            } => match state.aggregate_commit(round as usize, digest, &halves) {
                Ok(()) => {
                    let byz: Vec<u32> = state.byz_seen.iter().map(|&x| x as u32).collect();
                    let recv: Vec<u32> = state.received.iter().map(|&x| x as u32).collect();
                    send_reply(
                        &mut output,
                        &proto::encode_round_done(round, &byz, &recv, &state.params_scratch),
                    )?;
                }
                Err(e) => {
                    let _ =
                        send_reply(&mut output, &proto::encode_failed(&format!("{e:#}")));
                    return Err(e);
                }
            },
        }
    }
}
