//! The RPEL coordinator: Algorithm 1 as a synchronous round engine.
//!
//! Per round t, for every honest node i (paper Algorithm 1):
//!
//! 1. local stochastic gradient + Polyak momentum + half-step
//!    `x_i^{t+1/2} = x_i^t − η m_i^t` (delegated to the compute engine —
//!    the AOT HLO graph or its native twin);
//! 2. pull sampling: `S_i^t` = s uniform peers (epidemic topology) or the
//!    fixed graph neighborhood (baseline topology);
//! 3. the omniscient adversary crafts per-victim malicious models for the
//!    Byzantine members of `S_i^t`;
//! 4. robust aggregation `x_i^{t+1} = R(x_i^{t+1/2}; received)` — the
//!    Pallas NNM∘CWTM executable on the HLO path, or a native rule.
//!
//! All honest updates within a round are computed against the same
//! snapshot (synchronous model, §3.3) — nodes never see intra-round
//! updates of their peers.
//!
//! # Three shard backends, one round protocol
//!
//! Honest-node state is partitioned into contiguous shards, each hosted
//! by a [`shard::ShardBackend`]:
//!
//! * [`shard::NodeShard`] — **in-process**: the shard's nodes live in the
//!   coordinator's address space and every phase runs data-parallel on
//!   the persistent [`crate::util::pool::WorkerPool`];
//! * [`proc::ProcessShard`] — **multi-process** (`--procs N`): the shard
//!   lives in a spawned `rpel shard-worker` process that rebuilds the
//!   identical world from the shipped config and speaks the
//!   length-prefixed round protocol of [`crate::wire`] over pipes
//!   (`--transport pipe`, broadcast table) or stream sockets
//!   (`--transport socket|tcp`, worker-served pulls via the per-round
//!   routing table — see [`peer`]);
//! * [`vnode::VirtualShard`] — **virtual nodes** (`--virtual-nodes`): one
//!   backend hosts ALL honest nodes as `(seed, XOR-delta log)` recipes
//!   and materializes full state lazily, only for the nodes a round
//!   touches — the million-node engine (see below).
//!
//! [`Trainer`] is an orchestrator over `Vec<Box<dyn ShardBackend>>` and
//! owns the **round tables** — half-step rows, the committed-params
//! mirror, and the per-node loss / byz-seen / delivered counters, all in
//! ascending honest order. Every round:
//!
//! 1. **half-step** — `half_step_begin` to every backend (remote shards
//!    start computing), then `half_step_end` collects each shard's rows
//!    into the half-step table (remote shards ship their [`RoundDigest
//!    payload`](crate::wire::proto::FromWorker::Snapshot) — the same rows
//!    an in-process shard writes by reference);
//! 2. **digest** — the coordinator folds the table rows, in ascending
//!    honest-node order, into one [`HonestDigest`] (count, f64
//!    coordinate-wise mean/std, prev-mean). This is the only all-nodes
//!    reduction in the round and the only thing the omniscient adversary
//!    conditions on: crafting is O(d) per victim;
//! 3. **push routes** (push-mode ablation only) — sender → recipient
//!    scatter, reproducible from counter-keyed streams;
//! 4. **pull + craft + aggregate** — `serve_pulls` ships the digest +
//!    per-round routing table to socket-transport workers (which fetch
//!    the referenced rows from each other), then `aggregate_begin`
//!    broadcasts the digest + half-step table to the remaining backends
//!    (a borrow in-process, the full-table wire payload on pipes); each
//!    victim pulls exactly its sampled rows, the adversary crafts
//!    against the digest, and the rule aggregates into the shard's next
//!    buffers; `aggregate_end` collects per-node byz-seen and
//!    **delivered-message** counts;
//! 5. **commit** — the synchronous swap; every backend refreshes its
//!    slice of the committed-params mirror, which is what keeps
//!    evaluation and [`Trainer::params_of`] local and O(1) for both
//!    engines.
//!
//! # Message accounting
//!
//! [`crate::config::ExperimentConfig::messages_per_round`] is the
//! protocol's *nominal* budget (the paper's communication axis). What
//! actually arrives differs exactly in the adversarial regimes the paper
//! characterizes: DoS withholds every Byzantine response, and push mode
//! wastes pushes addressed to Byzantine recipients while Byzantine
//! senders flood. The engine therefore counts, per victim per round, the
//! model rows actually received (phase 4) and records the sum in
//! [`History::delivered_per_round`] alongside the nominal budget.
//!
//! # Determinism
//!
//! Results are **bit-identical for every (procs × shards × threads)
//! combination**: all round-path randomness comes from counter-based
//! streams keyed `(seed, round, node, purpose)`
//! ([`crate::util::rng::Rng::stream`]) so no draw depends on scheduling,
//! partitioning, or process placement; the digest is folded serially in
//! ascending honest-node order regardless of shard boundaries; scalar
//! reductions collect per-node values and fold them serially in index
//! order; and the wire codec ships IEEE-754 bit patterns, never text.
//! `rust/tests/determinism.rs` enforces the grid, including `--procs 2`
//! against the in-process engine. The phase-4 fast path additionally
//! memoizes honest↔honest pairwise distances in a round-scoped
//! [`crate::aggregation::DistCache`] (one per address space); the memo
//! is bit-invisible — a hit returns exactly the bits a miss would
//! compute — so the grid guarantee (and a cache-on vs cache-off
//! comparison) holds byte-for-byte. These invariants are machine-checked:
//! `rpel lint` ([`crate::analysis`]) statically scans the source tree for
//! wall-clock reads, iteration-order-sensitive containers, ambient
//! nondeterminism, and f32 fold-order hazards on the round path, and CI
//! fails on any finding.
//!
//! # Asynchronous rounds (the `[async]` config section)
//!
//! With [`crate::util::vclock::AsyncCfg::is_enabled`], each round is
//! prefixed by a **virtual-clock** phase. Nothing is measured: every
//! latency and churn coin is a pure function of
//! `(seed, round, node, LATENCY|CHURN)`, so the async engine keeps the
//! full grid guarantee above. The round-close sequence:
//!
//! ```text
//!  coordinator (virtual clock)          backends / workers
//!  ---------------------------          ------------------
//!  draw churn coins, latencies
//!  close = max(q-th arrival, cap
//!          by deadline if set)
//!  stale[i] = rounds since node i       AsyncRound{round, stale-slice}
//!    last made a close (0 = fresh) ───────────▶ (remote shards only)
//!  HalfStep ─────────────────────────────────▶ every node computes its
//!                                              half-step (stale ones
//!                                              too: RNG/momentum state
//!                                              must stay on-schedule)
//!                                       serve transform, by row OWNER:
//!                                        st = 0   → fresh row, record
//!                                                   as carried snapshot
//!                                        1…bound  → carried row, aged
//!                                                   per stale_policy
//!                                        beyond   → committed params
//!                                                   (frozen model)
//!  ◀───────────────────────────────────── Snapshot{losses, served rows}
//!  digest fold, routes, pull/craft/
//!  aggregate, commit — unchanged
//!  non-fresh nodes do NOT commit:       restore pre-round params, zero
//!  train-loss fold is fresh-only  ◀───── byz-seen/delivered ledgers
//! ```
//!
//! **Staleness policy spec.** A node's *served row* is what its peers
//! aggregate. `stale == 0`: the fresh half-step, recorded as the carried
//! snapshot. `1 ≤ stale ≤ max_staleness` with a carried snapshot:
//! `Carry` serves it verbatim; `Decay` serves
//! `params + λ^stale · (carried − params)` with `λ^stale` formed by
//! repeated f64 multiplication. Beyond the bound (or before any snapshot
//! arrived): the node's committed params. Peers always receive *some*
//! row, so receive sets, push routes, routing tables and the digest fold
//! are byte-identical code paths with or without asynchrony — staleness
//! is a modeled transform of row contents, never a membership change.
//! Non-fresh nodes also skip the commit (params and ledgers stay at the
//! pre-round state) while their momentum/RNG streams advance normally,
//! so `quorum = h` + `max_staleness = 0` + no churn reproduces the
//! synchronous engine bit-for-bit — `rust/tests/async_rounds.rs` pins
//! both properties across the transport × procs × shards × threads grid.
//!
//! # Sparse activation: partial participation + virtual nodes
//!
//! `participation = p < 1` (epidemic topology only) activates each
//! honest node per round with probability p, decided by the public
//! `(seed, round, node, PARTICIPATE)` coin ([`vnode::is_active`]) —
//! keyed by **global** node id, so every backend on every grid point
//! derives the same active set independently. An inactive node is a
//! frozen model, not an absent one: it skips the half-step (its data-RNG
//! and momentum do not advance), publishes its committed params as its
//! row (peers that pull it aggregate those), is excluded from the
//! digest, loss and ledger folds, skips the async serve transform
//! (inactivity trumps staleness — its carried snapshot does not move),
//! and does not commit. Byzantine nodes are always "available": the
//! adversary does not get quieter because honest nodes rest.
//!
//! `--virtual-nodes` swaps the storage model underneath the exact same
//! semantics. Committed per-node state follows the lifecycle
//!
//! ```text
//!   seed ─▶ shared init row ─▶ per-round XOR delta log ─▶ compacted
//!   arena row (log folded once it passes a threshold) ─▶ lazily
//!   materialized params/momentum/shard for this round's active set
//!   ─▶ commit appends the next XOR delta
//! ```
//!
//! and each round runs: compute active set → materialize exactly those
//! nodes → half-step through the shared job dispatch → serve transform
//! (async) → populate the table rows active victims will pull from
//! inactive peers with committed params → aggregate → commit deltas and
//! park momentum/shard state. Everything else is an **empty table row**
//! — the trainer's half-step/params tables hold rows only for the
//! touched set, which is what makes n = 10⁶ rounds fit in memory
//! (`rust/tests/large_n.rs`). Module docs in [`vnode`] cover the
//! lifecycle in detail; `History`'s `active/materialized/resident_bytes`
//! ledgers expose it per round. Dense and virtual engines are pinned
//! bit-identical at every participation level by
//! `rust/tests/determinism.rs` and `rust/tests/sparse_engine.rs`.
//!
//! # Crash recovery (the `[recovery]` config section)
//!
//! Three layers, all deterministic — a recovered run is bit-identical
//! to an unfaulted one because every recovery decision is modeled
//! (attempt budgets, the counter-keyed round randomness, boundary-state
//! mirrors), never measured:
//!
//! * **Durable round checkpoints** ([`checkpoint`]) — with
//!   `checkpoint_dir` set, every `checkpoint_every`-th round boundary is
//!   snapshotted (committed params, momentum, carried rows, codec
//!   reference, virtual clock, history) and written atomically
//!   (tmp-file + rename, FNV-checksummed — the format spec lives in the
//!   [`checkpoint`] module docs). `rpel train --resume DIR` rebuilds the
//!   world from the embedded config, installs the boundary state into
//!   whichever backend hosts it, fast-forwards data cursors by the
//!   completed-round count, and re-enters the round loop — the
//!   continuation is bit-for-bit the straight-through run.
//! * **Supervised worker restart** ([`proc::Supervisor`]) — with
//!   `max_worker_restarts > 0`, a multi-process run survives worker
//!   crashes. The recovery state machine, driven from
//!   `round_with_recovery`:
//!
//!   ```text
//!   round(t) ──Ok──▶ promote mirror (boundary t+1) ──▶ next round
//!      │Err
//!      ▼
//!   probe workers ──none down / budget spent──▶ surface the error
//!      │ ≥1 down, all within budget
//!      ▼
//!   drain survivors to the boundary (GetState barrier) ──▶ respawn
//!   dead workers (fresh incarnation, Init carries the mirror's
//!   boundary slice) ──▶ re-broadcast peer book ──▶ absorb recovery
//!   bytes ──▶ roll tables back to the mirror ──▶ re-drive round(t)
//!   ```
//!
//!   The re-driven round is bit-identical to an unfaulted one: round
//!   randomness is keyed `(seed, round, node, tag)`, the mirror IS the
//!   boundary state, and recovery traffic never lands in the ledgers.
//! * **Retry/timeout/backoff on the peer-pull path** ([`peer`],
//!   [`crate::wire::transport::RetryPolicy`]) — socket-transport pulls
//!   retry within a deterministic attempt budget; exhaustion surfaces
//!   as an error naming the peer, round and attempt count, never a
//!   hang.

pub mod checkpoint;
pub mod engine;
pub mod peer;
pub mod proc;
pub mod sampler;
pub(crate) mod shard;
pub mod vnode;

pub use engine::{build_engine, ComputeEngine, HloEngine, NativeEngine};
pub use sampler::PullSampler;

use crate::aggregation::gossip::GossipAggregator;
use crate::aggregation::{Aggregator, DistCache};
use crate::attacks::{Attack, HonestDigest};
use crate::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use crate::data::partition_dirichlet;
use crate::graph::Graph;
use crate::metrics::{EvalPoint, History};
use crate::runtime::{AggregateExec, Runtime};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;
use crate::util::vclock::{serve_row, RoundSchedule, VClock};
use crate::wire::codec as wire_codec;
use crate::wire::proto;
use anyhow::{anyhow, bail, ensure, Context, Result};
use shard::{AggCtx, NodeShard, NodeState, ShardBackend, StepCtx};
use std::path::Path;
use std::time::Instant; // lint: wall-clock-exempt (reporting-only wall_secs)

/// Which aggregation backend executes step 4.
pub(crate) enum AggBackend {
    /// Native Definition-5.1 rule over the pulled set.
    Native(Box<dyn Aggregator>),
    /// The AOT Pallas NNM∘CWTM executable (production path).
    Hlo(AggregateExec),
    /// Fixed-graph gossip rule over the node's neighborhood.
    Gossip(Box<dyn GossipAggregator>),
}

impl AggBackend {
    fn name(&self) -> &'static str {
        match self {
            AggBackend::Native(r) => r.name(),
            AggBackend::Hlo(_) => "nnm_cwtm[pallas]",
            AggBackend::Gossip(r) => r.name(),
        }
    }
}

/// Everything one address space needs to host (part of) a run: the
/// compute engine, the resolved adversary, per-node state for **all**
/// honest nodes, and the topology. Both the coordinator and every
/// `rpel shard-worker` process build this from the same config — all
/// construction randomness forks off the experiment seed, so two worlds
/// built from equal configs are bit-identical.
pub(crate) struct World {
    pub cfg: ExperimentConfig,
    pub engine: Box<dyn ComputeEngine>,
    pub agg: AggBackend,
    pub attack: Option<Box<dyn Attack>>,
    pub bhat: usize,
    pub byz: Vec<bool>,
    pub node_of: Vec<usize>,
    pub nodes: Vec<NodeState>,
    pub sampler: Option<PullSampler>,
    pub push_s: Option<usize>,
    pub gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    pub d: usize,
    /// Virtual build only: the lazy-materialization substrate (per-node
    /// RNG snapshots + label bytes) the [`vnode::VirtualShard`] owns.
    pub vseeds: Option<vnode::VirtualSeeds>,
}

/// How much per-node state the one world-construction path materializes.
/// All three modes run the **same** build — engine, b̂, adversary
/// placement, data partition, the per-node fork loop, topology — and
/// differ only in what the node-loop arm keeps, so the RNG fork/draw
/// sequence (hence everything downstream) is bit-identical by
/// construction rather than by three hand-synchronized copies.
#[derive(Clone, Copy, PartialEq)]
pub(crate) enum Materialize {
    /// Full node states (params, momentum, sampled shard): the
    /// in-process dense engine.
    Full,
    /// Nothing per node (each skipped node still consumes its
    /// `0x5AD + id` fork and its data-stream draws stay un-taken — the
    /// test set is drawn before the loop, so nothing downstream shifts):
    /// the multi-process coordinator, whose workers rebuild their own.
    Lite,
    /// Recipes only: per-node RNG snapshots + label bytes for
    /// [`vnode::VirtualShard`]'s lazy materialization, with the shared
    /// data stream advanced by exactly the draws a full build would
    /// consume.
    Virtual,
}

/// Build the full world from a config: engine, adversary placement, b̂
/// resolution (Algorithm 2 when unset), node states, topology.
pub(crate) fn build_world(cfg: &ExperimentConfig) -> Result<World> {
    build_world_impl(cfg, Materialize::Full)
}

/// [`build_world`] without materializing per-node state (`nodes` comes
/// back empty): what a multi-process coordinator needs — every worker
/// rebuilds its own nodes anyway, and sampling h nodes' data and params
/// here would only be dropped.
pub(crate) fn build_world_lite(cfg: &ExperimentConfig) -> Result<World> {
    build_world_impl(cfg, Materialize::Lite)
}

/// [`build_world`] capturing materialization *recipes* instead of node
/// state: what the sparse engine boots from. A node first activated in
/// round t samples its shard from the stored RNG snapshots and gets
/// bit-for-bit the dataset the dense build would have given it.
pub(crate) fn build_world_virtual(cfg: &ExperimentConfig) -> Result<World> {
    build_world_impl(cfg, Materialize::Virtual)
}

fn build_world_impl(cfg: &ExperimentConfig, materialize: Materialize) -> Result<World> {
    cfg.validate().map_err(|e| anyhow!("invalid config: {e}"))?;
    let mut cfg = cfg.clone();
    let mut rng = Rng::new(cfg.seed);

    // --- compute engine -------------------------------------------------
    let mut runtime = match cfg.engine {
        EngineKind::Hlo => Some(
            Runtime::open(&cfg.artifacts_dir)
                .context("HLO engine requires built artifacts")?,
        ),
        EngineKind::Native => None,
    };
    let engine = build_engine(&cfg, runtime.as_mut())?;
    if engine.batch() != cfg.batch {
        log::info!(
            "batch {} overridden to {} (baked into HLO artifact)",
            cfg.batch,
            engine.batch()
        );
        cfg.batch = engine.batch();
    }
    let d = engine.d();

    // --- resolve b̂ (Algorithm 2 / §6.1) --------------------------------
    // b̂ resolution uses Appendix B Remark 2's "more precise" method:
    // the exact 90%-quantile of max_{i,t} b_i^t from the closed-form
    // hypergeometric CDF (deterministic; Algorithm 2's simulation is
    // available via `rpel select` / sampling::select_params).
    const BHAT_CONFIDENCE: f64 = 0.9;
    let bhat = match (cfg.bhat, &cfg.topology) {
        (Some(bh), _) => bh,
        (None, _) if cfg.b == 0 => 0,
        // push mode deliberately reuses the pull-mode b̂ (Appendix D:
        // flooding voids the hypergeometric bound — that mismatch IS
        // the ablation)
        (None, Topology::Epidemic { s }) | (None, Topology::EpidemicPush { s }) => {
            crate::sampling::selector::select_bhat_exact(
                cfg.n as u64,
                cfg.b as u64,
                cfg.rounds as u64,
                *s as u64,
                BHAT_CONFIDENCE,
            ) as usize
        }
        (None, Topology::FixedGraph { .. }) => {
            // Remark C.2: under random placement use the same b̂ an
            // epidemic run of equal budget would use
            let s_equiv = (2 * cfg.messages_per_round() / cfg.n).clamp(1, cfg.n - 1);
            crate::sampling::selector::select_bhat_exact(
                cfg.n as u64,
                cfg.b as u64,
                cfg.rounds as u64,
                s_equiv as u64,
                BHAT_CONFIDENCE,
            ) as usize
        }
    };
    if let Topology::Epidemic { s } = cfg.topology {
        if cfg.b > 0 && 2 * bhat >= s + 1 {
            bail!(
                "effective adversarial fraction {bhat}/{} ≥ 1/2 — robust \
                 aggregation breaks down (paper §6.2); increase s or reduce b",
                s + 1
            );
        }
    }

    // --- aggregation backend -------------------------------------------
    let agg = match (&cfg.topology, cfg.rule) {
        (Topology::Epidemic { s }, RuleChoice::Epidemic(kind)) => {
            // DoS shrinks receive sets; the fixed-shape Pallas
            // executable cannot apply, so fall back to the native rule
            let want_hlo = cfg.engine == EngineKind::Hlo
                && kind == crate::aggregation::RuleKind::NnmCwtm
                && cfg.attack != crate::attacks::AttackKind::Dos;
            if want_hlo {
                let rt = runtime.as_mut().unwrap();
                match rt.aggregate_exec(&cfg.arch, s + 1, bhat) {
                    Ok(exec) => AggBackend::Hlo(exec),
                    Err(e) => {
                        log::warn!(
                            "no Pallas aggregate artifact (m={}, b̂={bhat}): {e}; \
                             falling back to native rule",
                            s + 1
                        );
                        AggBackend::Native(kind.build(bhat))
                    }
                }
            } else {
                AggBackend::Native(kind.build(bhat))
            }
        }
        (Topology::EpidemicPush { .. }, RuleChoice::Epidemic(kind)) => {
            AggBackend::Native(kind.build(bhat))
        }
        (Topology::FixedGraph { .. }, RuleChoice::Gossip(kind)) => {
            AggBackend::Gossip(kind.build(bhat))
        }
        _ => bail!("rule/topology mismatch (config validation bug)"),
    };

    // --- adversary placement (uniform random, Remark C.1) ---------------
    let mut byz = vec![false; cfg.n];
    for id in rng.fork(0xB12).sample_distinct(cfg.n, cfg.b) {
        byz[id] = true;
    }
    let attack = if cfg.b > 0 { cfg.attack.build() } else { None };

    // --- data ------------------------------------------------------------
    let task = cfg.task.spec().instantiate(cfg.seed);
    let mut data_rng = rng.fork(0xDA7A);
    let shard_labels = partition_dirichlet(
        cfg.n,
        task.spec.classes,
        cfg.samples_per_node,
        cfg.alpha,
        &mut data_rng,
    );
    let test_n = if engine.eval_n() > 0 {
        if engine.eval_n() != cfg.test_samples {
            log::info!(
                "test_samples {} overridden to {} (baked into HLO eval artifact)",
                cfg.test_samples,
                engine.eval_n()
            );
        }
        engine.eval_n()
    } else {
        cfg.test_samples
    };
    let test = task.sample_uniform(test_n, &mut data_rng);

    // --- honest node states ----------------------------------------------
    let full = materialize == Materialize::Full;
    let mut nodes = Vec::with_capacity(if full { cfg.honest() } else { 0 });
    let mut vseeds = (materialize == Materialize::Virtual).then(|| vnode::VirtualSeeds {
        ids: Vec::with_capacity(cfg.honest()),
        node_rngs: Vec::with_capacity(cfg.honest()),
        data_rngs: Vec::with_capacity(cfg.honest()),
        labels_flat: Vec::new(),
        label_off: vec![0u32],
        task: task.clone(),
    });
    let mut node_of = vec![usize::MAX; cfg.n];
    let mut honest_seen = 0usize;
    for id in 0..cfg.n {
        if byz[id] {
            continue;
        }
        node_of[id] = honest_seen;
        honest_seen += 1;
        // the fork must be consumed even when the node is skipped, so
        // the parent stream (and the topology fork below) stays in sync
        // with a full build
        let node_rng = rng.fork(0x5AD + id as u64);
        let labels = &shard_labels[id];
        match materialize {
            Materialize::Lite => {}
            Materialize::Full => {
                let data = task.sample_labels(labels, &mut data_rng);
                let data_shard = crate::data::Shard::new(data, node_rng);
                let params = engine.init_params(cfg.seed as i32)?;
                nodes.push(NodeState {
                    id,
                    params,
                    momentum: vec![0.0f32; d],
                    shard: data_shard,
                });
            }
            Materialize::Virtual => {
                // snapshot the recipe, then advance the shared data
                // stream by exactly the draws `sample_labels` would
                // consume (one gaussian per feature — gaussian32's draw
                // count is independent of mean/std), so every later
                // node's snapshot matches the full build bit-for-bit
                let vs = vseeds.as_mut().unwrap();
                vs.ids.push(id);
                vs.node_rngs.push(node_rng);
                vs.data_rngs.push(data_rng.clone());
                vs.labels_flat.extend(labels.iter().map(|&c| c as u8));
                vs.label_off.push(vs.labels_flat.len() as u32);
                for _ in 0..labels.len() * task.spec.dim {
                    data_rng.gaussian();
                }
            }
        }
    }

    // --- topology ----------------------------------------------------------
    let (sampler, push_s, gossip_rows) = match cfg.topology {
        Topology::Epidemic { s } => (Some(PullSampler::new(cfg.n, s)), None, None),
        Topology::EpidemicPush { s } => (None, Some(s), None),
        Topology::FixedGraph { edges } => {
            let g = Graph::random_connected(cfg.n, edges, &mut rng.fork(0x6AF));
            (None, None, Some(g.metropolis_weights()))
        }
    };

    Ok(World {
        engine,
        agg,
        attack,
        bhat,
        byz,
        node_of,
        nodes,
        sampler,
        push_s,
        gossip_rows,
        test_x: test.x,
        test_y: test.y,
        d,
        vseeds,
        cfg,
    })
}

/// A fully constructed training run.
pub struct Trainer {
    cfg: ExperimentConfig,
    engine: Box<dyn ComputeEngine>,
    agg: AggBackend,
    attack: Option<Box<dyn Attack>>,
    /// resolved effective adversaries b̂ (Algorithm 2 output when the
    /// config left it unset)
    pub bhat: usize,
    /// per-id Byzantine flag and id → honest-index map
    byz: Vec<bool>,
    node_of: Vec<usize>,
    /// honest index → global node id (the PARTICIPATE coin's key)
    honest_ids: Vec<usize>,
    /// shard backends, ascending contiguous honest ranges — in-process
    /// [`NodeShard`]s, or one [`proc::ProcessShard`] per worker process
    backends: Vec<Box<dyn ShardBackend>>,
    /// whether any backend is in-process (false ⇒ every shard is remote
    /// and per-round context the workers derive themselves can be
    /// skipped here)
    local_backends: bool,
    /// honest count |H| (sum of backend lengths)
    h: usize,
    sampler: Option<PullSampler>,
    /// push mode (pull-vs-push ablation): fan-out per honest sender
    push_s: Option<usize>,
    /// fixed-graph topology: metropolis rows per node id
    gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
    /// persistent worker pool for the in-process per-node phases
    pool: WorkerPool,
    /// §4.2 telemetry: max Byzantine rows any honest node received in the
    /// last round (the *observed* b̂)
    last_round_byz_max: usize,
    /// delivered-message ledger: model rows honest nodes actually
    /// received in the last round
    last_round_delivered: usize,
    /// bytes-on-the-wire ledger for the last round:
    /// (coordinator→workers, workers→coordinator, peer-served) — all
    /// zero for in-process backends
    last_round_wire: (u64, u64, u64),
    /// row-codec byte ledger for the last round: (raw, encoded) row
    /// payload bytes that crossed the wire compressed — zero for
    /// in-process backends, equal at `compression = none`
    last_round_codec: (u64, u64),
    /// row-codec delta reference for the coming round: the previous
    /// round's digest mean as f32 (zeros before the first fold). Workers
    /// track their own twin from the digest in the aggregate frames.
    wire_ref: Vec<f32>,
    /// per-round digest of the honest population (phase 2 output)
    digest: HonestDigest,
    /// round-scoped honest↔honest distance memo for the in-process
    /// aggregation fast path (cleared at the top of every phase 4;
    /// worker processes keep their own). Bit-invisible: hits return the
    /// bits a miss would compute.
    dist_cache: DistCache,
    /// test hook: `false` disables the memo (cache-on vs cache-off runs
    /// are pinned byte-identical by `rust/tests/agg_kernels.rs`)
    dist_cache_on: bool,
    /// round table: half-step rows x^{t+1/2}, ascending honest order
    tbl_halves: Vec<Vec<f32>>,
    /// round table: committed params mirror x^t (refreshed in phase 5;
    /// backs `params_of`, evaluation, and the digest's prev-mean fold)
    tbl_params: Vec<Vec<f32>>,
    /// round table: per-node train loss of the last half-step phase
    tbl_losses: Vec<f64>,
    /// round table: per-node Byzantine rows received in the last round
    tbl_byz_seen: Vec<usize>,
    /// round table: per-node model rows received in the last round
    tbl_recv: Vec<usize>,
    /// asynchronous round engine: the deterministic virtual clock
    /// (`None` ⇒ classic synchronous lockstep — see the module docs)
    vclock: Option<VClock>,
    /// per honest node: last fresh snapshot (the async serve state;
    /// used on the in-process path only — worker processes keep their
    /// own carried rows)
    carried: Vec<Option<Vec<f32>>>,
    /// async ledgers for the last round: fresh honest nodes, virtual
    /// close time, and the per-node staleness slice
    last_round_participation: u32,
    last_round_vclose: f64,
    last_round_stale: Vec<u32>,
    /// multi-process supervision (`recovery.max_worker_restarts > 0`):
    /// everything a mid-run respawn needs (None ⇒ crashes are fatal)
    supervisor: Option<proc::Supervisor>,
    /// supervised runs only: the last completed round boundary's full
    /// state — what a respawned worker resumes from and what the round
    /// tables roll back to before a failed round is re-driven
    mirror: Option<checkpoint::BoundaryState>,
    /// recovery ledgers for the last round: worker respawns consumed and
    /// peer-pull retry attempts spent
    last_round_restarts: u32,
    last_round_retries: u32,
    /// test hook: `(round, shard)` kills scheduled by
    /// [`Self::chaos_kill_at`], consumed just before the round is driven
    chaos_kills: Vec<(usize, usize)>,
}

impl Trainer {
    /// Build everything: engine, adversary placement, shard backends
    /// (spawning `rpel shard-worker` processes when `procs > 1`),
    /// topology, b̂ resolution (Algorithm 2 when unset).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Trainer> {
        Self::from_config_with_resume(cfg, None)
    }

    /// [`Self::from_config`] continuing from a checkpoint's boundary
    /// state: committed params / momentum / carried rows are installed
    /// into whichever backend hosts them (worker `Init` frames on the
    /// process path, [`NodeShard::install_resume`] /
    /// [`vnode::VirtualShard::install_resume`] in-process), data-shard
    /// cursors are fast-forwarded by the completed-round count, and the
    /// codec reference + virtual clock pick up mid-run. The caller
    /// re-enters the round loop at the boundary via [`Self::run_from`].
    pub(crate) fn from_config_with_resume(
        cfg: &ExperimentConfig,
        resume: Option<&checkpoint::BoundaryState>,
    ) -> Result<Trainer> {
        let virtual_nodes = cfg.virtual_nodes;
        let local_backends = cfg.procs <= 1 && !virtual_nodes;
        let World {
            cfg,
            engine,
            agg,
            attack,
            bhat,
            byz,
            node_of,
            nodes,
            sampler,
            push_s,
            gossip_rows,
            test_x,
            test_y,
            d,
            vseeds,
        } = if virtual_nodes {
            build_world_virtual(cfg)?
        } else if local_backends {
            build_world(cfg)?
        } else {
            // the workers rebuild their own node state; don't sample h
            // nodes' data and params here just to drop them
            build_world_lite(cfg)?
        };
        let h = cfg.honest();
        debug_assert!(!local_backends || nodes.len() == h);
        if let Some(rs) = resume {
            ensure!(
                rs.params.len() == h && rs.momentum.len() == h && rs.carried.len() == h,
                "resume state holds {} node(s) but this config has {h}",
                rs.params.len()
            );
            ensure!(
                rs.wire_ref.len() == d,
                "resume codec reference has width {} but the model dimension is {d}",
                rs.wire_ref.len()
            );
            ensure!(
                rs.params.iter().chain(rs.momentum.iter()).all(|r| r.len() == d)
                    && rs.carried.iter().flatten().all(|r| r.len() == d),
                "resume state rows do not match the model dimension {d}"
            );
            ensure!(
                rs.round as usize <= cfg.rounds,
                "resume boundary round {} exceeds the configured {} round(s)",
                rs.round,
                cfg.rounds
            );
        }
        // committed-params mirror starts at the init params (identical
        // for every node: init is a function of the experiment seed
        // only) — or at the checkpointed boundary rows on resume. The
        // virtual backend keeps the mirror EMPTY — committed params are
        // recipes there, materialized on read by `committed_params` —
        // which is most of the memory diet.
        let tbl_params: Vec<Vec<f32>> = if virtual_nodes {
            vec![Vec::new(); h]
        } else if let Some(rs) = resume {
            rs.params.clone()
        } else if local_backends {
            nodes.iter().map(|node| node.params.clone()).collect()
        } else {
            let row = engine.init_params(cfg.seed as i32)?;
            vec![row; h]
        };

        let mut supervisor = None;
        let backends: Vec<Box<dyn ShardBackend>> = if virtual_nodes {
            let seeds = vseeds.expect("virtual build returns seeds");
            let init = engine.init_params(cfg.seed as i32)?;
            let vsampler = sampler.expect("validated: virtual_nodes needs epidemic topology");
            let mut vs = vnode::VirtualShard::new(
                seeds,
                init,
                cfg.seed,
                cfg.participation,
                cfg.asyn.clone(),
                vsampler,
                byz.clone(),
                node_of.clone(),
            );
            if let Some(rs) = resume {
                vs.install_resume(
                    &rs.params,
                    &rs.momentum,
                    &rs.carried,
                    rs.round,
                    engine.local_steps(),
                    engine.batch(),
                );
            }
            vec![Box::new(vs) as Box<dyn ShardBackend>]
        } else if !local_backends {
            // multi-process engine: one worker process per contiguous
            // range; each rebuilds the identical world from the shipped
            // config (and, on resume, installs its slice of the
            // checkpointed boundary state from its `Init` frame)
            let parts = cfg.procs.clamp(1, h.max(1));
            if parts < cfg.procs {
                log::info!("procs {} clamped to honest count {parts}", cfg.procs);
            }
            drop(nodes);
            let toml = crate::config::file::to_toml_str(&cfg);
            let ranges = shard::partition_ranges(h, parts);
            let frames: Vec<proto::WireResume> = match resume {
                None => Vec::new(),
                Some(rs) => ranges
                    .iter()
                    .map(|&(start, len)| proto::WireResume {
                        round: rs.round,
                        wire_ref: rs.wire_ref.clone(),
                        params: rs.params[start..start + len].to_vec(),
                        momentum: rs.momentum[start..start + len].to_vec(),
                        carried: rs.carried[start..start + len].to_vec(),
                    })
                    .collect(),
            };
            let (workers, sup) = proc::ProcessShard::spawn_all(
                &toml,
                &ranges,
                parts,
                d,
                cfg.transport,
                &cfg.socket_dir,
                cfg.compression,
                &cfg.recovery,
                &frames,
            )
            .with_context(|| {
                format!(
                    "starting {parts} shard workers (transport {})",
                    cfg.transport.name()
                )
            })?;
            supervisor = sup.supervised().then_some(sup);
            workers
                .into_iter()
                .map(|worker| Box::new(worker) as Box<dyn ShardBackend>)
                .collect()
        } else {
            // in-process engine: contiguous NodeShards
            let parts = cfg.shards.clamp(1, h.max(1));
            let ranges = shard::partition_ranges(h, parts);
            let mut node_iter = nodes.into_iter();
            ranges
                .iter()
                .map(|&(start, len)| {
                    let shard_nodes: Vec<NodeState> = node_iter.by_ref().take(len).collect();
                    let mut ns = NodeShard::new(start, shard_nodes, d);
                    if let Some(rs) = resume {
                        ns.install_resume(
                            &rs.params[start..start + len],
                            &rs.momentum[start..start + len],
                            rs.round,
                            cfg.seed,
                            cfg.participation,
                            engine.local_steps(),
                            engine.batch(),
                        );
                    }
                    Box::new(ns) as Box<dyn ShardBackend>
                })
                .collect()
        };

        let pool = WorkerPool::new(cfg.threads);
        let honest_ids: Vec<usize> = (0..cfg.n).filter(|&id| !byz[id]).collect();
        let wire_ref = match resume {
            Some(rs) => rs.wire_ref.clone(),
            None => vec![0.0f32; d],
        };
        let carried: Vec<Option<Vec<f32>>> = match resume {
            Some(rs) => rs.carried.clone(),
            None => vec![None; h],
        };
        let mut vclock = cfg
            .asyn
            .is_enabled()
            .then(|| VClock::new(&cfg.asyn, cfg.seed, h));
        if let (Some(vc), Some(rs)) = (vclock.as_mut(), resume) {
            if let Some((down, fresh)) = rs.vclock.as_ref() {
                vc.restore(down.clone(), fresh.clone())
                    .map_err(|e| anyhow!("resume: {e}"))?;
            }
        }
        // supervised runs keep a boundary mirror from round 0 on: the
        // starting state IS the first boundary (init params or the
        // resumed checkpoint), so a crash in the very first driven
        // round already has somewhere to roll back to
        let mirror = supervisor.is_some().then(|| checkpoint::BoundaryState {
            round: resume.map_or(0, |rs| rs.round),
            wire_ref: wire_ref.clone(),
            params: tbl_params.clone(),
            momentum: match resume {
                Some(rs) => rs.momentum.clone(),
                None => vec![vec![0.0f32; d]; h],
            },
            carried: carried.clone(),
            vclock: vclock.as_ref().map(|v| v.state()),
        });
        log::info!(
            "trainer '{}': n={} b={} b̂={bhat} rule={} engine={} d={d} shards={} procs={} threads={}",
            cfg.name,
            cfg.n,
            cfg.b,
            agg.name(),
            engine.name(),
            backends.len(),
            cfg.procs,
            pool.threads()
        );
        Ok(Trainer {
            bhat,
            byz,
            node_of,
            honest_ids,
            sampler,
            push_s,
            gossip_rows,
            test_x,
            test_y,
            pool,
            last_round_byz_max: 0,
            last_round_delivered: 0,
            last_round_wire: (0, 0, 0),
            last_round_codec: (0, 0),
            wire_ref,
            digest: HonestDigest::new(d),
            dist_cache: DistCache::new(),
            dist_cache_on: true,
            backends,
            local_backends,
            h,
            // the virtual backend rebuilds (only) the touched rows each
            // round; pre-sizing h dense rows would defeat it
            tbl_halves: if virtual_nodes {
                vec![Vec::new(); h]
            } else {
                vec![vec![0.0f32; d]; h]
            },
            tbl_params,
            tbl_losses: vec![0.0f64; h],
            tbl_byz_seen: vec![0usize; h],
            tbl_recv: vec![0usize; h],
            vclock,
            carried,
            last_round_participation: 0,
            last_round_vclose: 0.0,
            last_round_stale: Vec::new(),
            supervisor,
            mirror,
            last_round_restarts: 0,
            last_round_retries: 0,
            chaos_kills: Vec::new(),
            engine,
            agg,
            attack,
            cfg,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Which aggregation backend actually runs (for logs/tests).
    pub fn aggregation_name(&self) -> &'static str {
        self.agg.name()
    }

    /// Number of honest nodes.
    pub fn honest_count(&self) -> usize {
        self.h
    }

    /// Resolved worker count for the per-node phases.
    pub fn thread_count(&self) -> usize {
        self.pool.threads()
    }

    /// Resolved shard-backend count (≥ 1, ≤ honest count): `shards`
    /// in-process shards, or `procs` worker processes.
    pub fn shard_count(&self) -> usize {
        self.backends.len()
    }

    /// Test hook: forcibly kill the idx-th shard's backing worker
    /// process. Returns false for in-process backends — used by the
    /// crash tests to prove a dead worker surfaces as an error, not a
    /// hang.
    #[doc(hidden)]
    pub fn kill_shard_worker(&mut self, idx: usize) -> bool {
        match self.backends.get_mut(idx) {
            Some(backend) => backend.kill_for_test(),
            None => false,
        }
    }

    /// Test hook: enable/disable the round-level distance cache for the
    /// in-process aggregation path (worker processes always cache).
    /// Results are byte-identical either way — `agg_kernels.rs` pins it;
    /// `bench_aggregation` uses the toggle to measure the speedup.
    #[doc(hidden)]
    pub fn set_dist_cache(&mut self, on: bool) {
        self.dist_cache_on = on;
    }

    /// Test hook: wrap the idx-th shard's transport in the deterministic
    /// chaos fault injector ([`crate::testkit::chaos`]). Returns false
    /// for in-process backends — used by the fault-injection suite to
    /// prove delayed/stale/cut replies surface as actionable errors
    /// naming the worker and round, never a hang.
    #[doc(hidden)]
    pub fn chaos_shard_transport(
        &mut self,
        idx: usize,
        plan: crate::testkit::chaos::ChaosPlan,
    ) -> bool {
        match self.backends.get_mut(idx) {
            Some(backend) => backend.inject_chaos(plan),
            None => false,
        }
    }

    /// Test hook: schedule the idx-th shard's backing worker process to
    /// be killed right before `round` is driven — the crash-recovery
    /// suite uses it to prove a supervised run re-drives the round to a
    /// bit-identical trajectory, and an unsupervised one fails with the
    /// named error.
    #[doc(hidden)]
    pub fn chaos_kill_at(&mut self, round: usize, shard: usize) {
        self.chaos_kills.push((round, shard));
    }

    /// Run the full training; returns the metric history.
    pub fn run(&mut self) -> Result<History> {
        let hist = History::new(&self.cfg.name, self.cfg.messages_per_round());
        self.run_from(hist, 0)
    }

    /// The round loop from `start` (0 for a fresh run; a checkpoint's
    /// boundary round on resume), appending to an existing history —
    /// the resume path re-enters here with the checkpointed `History`,
    /// so the finished ledgers are the straight-through run's entry for
    /// entry (`wall_secs` and `checkpoint_bytes_per_round` excepted:
    /// both are reporting-only and fault-profile-dependent).
    pub(crate) fn run_from(&mut self, mut hist: History, start: usize) -> Result<History> {
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now(); // lint: wall-clock-exempt (reporting only)
        let async_on = self.vclock.is_some();
        if async_on && hist.staleness_hist.is_empty() {
            // bucket k counts node-rounds served at staleness k; the last
            // bucket (max_staleness + 1) is the params-fallback regime
            hist.staleness_hist = vec![0u64; self.cfg.asyn.max_staleness + 2];
        }
        let sparse_on = self.cfg.virtual_nodes || self.cfg.participation < 1.0;
        for round in start..self.cfg.rounds {
            let loss = self.round_with_recovery(round)?;
            hist.train_loss.push(loss);
            hist.observed_byz_max.push(self.last_round_byz_max);
            hist.total_messages += self.cfg.messages_per_round();
            hist.delivered_per_round.push(self.last_round_delivered);
            hist.total_delivered += self.last_round_delivered;
            hist.wire_coord_out_per_round.push(self.last_round_wire.0 as usize);
            hist.wire_coord_in_per_round.push(self.last_round_wire.1 as usize);
            hist.wire_peer_per_round.push(self.last_round_wire.2 as usize);
            hist.wire_raw_bytes_per_round.push(self.last_round_codec.0);
            hist.wire_encoded_bytes_per_round.push(self.last_round_codec.1);
            if sparse_on {
                let (active, materialized, resident) = self.sparse_round_stats(round);
                hist.active_per_round.push(active);
                hist.materialized_per_round.push(materialized);
                hist.resident_bytes_per_round.push(resident);
            }
            if async_on {
                hist.participation_per_round.push(self.last_round_participation);
                hist.virtual_close_per_round.push(self.last_round_vclose);
                for &st in &self.last_round_stale {
                    hist.staleness_hist[st as usize] += 1;
                }
            }
            hist.worker_restarts_per_round.push(self.last_round_restarts);
            hist.peer_retries_per_round.push(self.last_round_retries);
            // filled below once the (optional) checkpoint write reports
            // its size — the file embeds the history with 0 here, so a
            // resumed ledger differs from the straight-through one only
            // in this reporting-only column
            hist.checkpoint_bytes_per_round.push(0);
            let last = round + 1 == self.cfg.rounds;
            if last || (round + 1) % self.cfg.eval_every == 0 {
                hist.evals.push(self.evaluate(round + 1)?);
            }
            self.promote_mirror(round)?;
            if let Some(bytes) = self.maybe_checkpoint(round, &hist)? {
                if let Some(slot) = hist.checkpoint_bytes_per_round.last_mut() {
                    *slot = bytes;
                }
            }
        }
        hist.wall_secs = t0.elapsed().as_secs_f64();
        Ok(hist)
    }

    /// [`Self::round`] wrapped in the supervised-recovery loop: when a
    /// round fails and the [`proc::Supervisor`] can respawn every dead
    /// worker within budget, the round tables roll back to the
    /// boundary mirror and the SAME round is re-driven — bit-identical
    /// to an unfaulted round, because all round randomness is
    /// counter-keyed and recovery traffic is absorbed from the byte
    /// ledgers. Unsupervised runs (in-process, virtual, or
    /// `max_worker_restarts = 0`) pass errors straight through. The
    /// loop is bounded: every iteration consumes restart budget, and an
    /// unrecoverable failure (nothing down, or budget spent) returns
    /// the original error.
    fn round_with_recovery(&mut self, round: usize) -> Result<f64> {
        for i in 0..self.chaos_kills.len() {
            if self.chaos_kills[i].0 == round {
                let shard = self.chaos_kills[i].1;
                self.kill_shard_worker(shard);
            }
        }
        self.chaos_kills.retain(|&(r, _)| r != round);
        let before = self.supervisor.as_ref().map_or(0, |s| s.total_restarts());
        let mut result = self.round(round);
        loop {
            match result {
                Ok(loss) => {
                    let after =
                        self.supervisor.as_ref().map_or(0, |s| s.total_restarts());
                    self.last_round_restarts = (after - before) as u32;
                    return Ok(loss);
                }
                Err(err) => {
                    if !self.try_recover_backends()? {
                        return Err(err);
                    }
                    self.rollback_to_mirror();
                    result = self.round(round);
                }
            }
        }
    }

    /// Probe-and-respawn pass after a failed round. `Ok(true)` ⇒ at
    /// least one dead worker was respawned at the mirror boundary and
    /// the round can be re-driven; `Ok(false)` ⇒ not recoverable here
    /// (no supervisor or mirror, nothing actually down, or restart
    /// budget spent) — the caller surfaces its original error.
    fn try_recover_backends(&mut self) -> Result<bool> {
        let Some(sup) = self.supervisor.as_mut() else {
            return Ok(false);
        };
        let Some(mirror) = self.mirror.as_ref() else {
            return Ok(false);
        };
        sup.try_recover(&mut self.backends, mirror.round, &mut |start, len| {
            proto::WireResume {
                round: mirror.round,
                wire_ref: mirror.wire_ref.clone(),
                params: mirror.params[start..start + len].to_vec(),
                momentum: mirror.momentum[start..start + len].to_vec(),
                carried: mirror.carried[start..start + len].to_vec(),
            }
        })
    }

    /// Reset the trainer-side round state to the boundary mirror before
    /// re-driving a failed round: the committed-params mirror rows, the
    /// codec delta reference, and the virtual clock. Everything else is
    /// either recomputed by the round from scratch (digest, half-step
    /// table, per-node ledgers, distance memo) or worker-owned state
    /// the drain/respawn already restored.
    fn rollback_to_mirror(&mut self) {
        let Some(mirror) = self.mirror.as_ref() else { return };
        for (row, src) in self.tbl_params.iter_mut().zip(mirror.params.iter()) {
            row.clone_from(src);
        }
        self.wire_ref.clone_from(&mirror.wire_ref);
        if let (Some(vc), Some((down, fresh))) =
            (self.vclock.as_mut(), mirror.vclock.as_ref())
        {
            // shapes came from this clock's own `state()`; a mismatch is
            // impossible, so the error arm is dead
            let _ = vc.restore(down.clone(), fresh.clone());
        }
    }

    /// Snapshot the boundary state after `round` completed: every
    /// backend's committed rows, momentum and carried rows, plus the
    /// codec reference and the virtual clock. Remote shards answer a
    /// `GetState` barrier (whose traffic is then absorbed from the byte
    /// ledgers); the virtual backend exports from its recipes; dense
    /// in-process shards clone node state directly.
    fn capture_state(&mut self, round: usize) -> Result<checkpoint::BoundaryState> {
        let boundary = round as u64 + 1;
        let mut params: Vec<Vec<f32>> = Vec::with_capacity(self.h);
        let mut momentum: Vec<Vec<f32>> = Vec::with_capacity(self.h);
        let mut carried: Vec<Option<Vec<f32>>> = Vec::with_capacity(self.h);
        for backend in self.backends.iter_mut() {
            let (start, len) = (backend.start(), backend.len());
            if let Some(shard) = backend.as_process() {
                let (p, m, c) = shard.sync_state(boundary)?;
                shard.reset_wire_marks();
                params.extend(p);
                momentum.extend(m);
                carried.extend(c);
            } else if let Some(v) = backend.as_virtual() {
                let (p, m, c) = v.export_state();
                params.extend(p);
                momentum.extend(m);
                carried.extend(c);
            } else {
                let shard = backend
                    .as_node_shard()
                    .expect("in-process backends are NodeShards");
                for node in &shard.nodes {
                    params.push(node.params.clone());
                    momentum.push(node.momentum.clone());
                }
                carried.extend(self.carried[start..start + len].iter().cloned());
            }
        }
        Ok(checkpoint::BoundaryState {
            round: boundary,
            wire_ref: self.wire_ref.clone(),
            params,
            momentum,
            carried,
            vclock: self.vclock.as_ref().map(|v| v.state()),
        })
    }

    /// Refresh the supervised-recovery mirror at a completed round
    /// boundary. Unsupervised runs keep no mirror: rollback can never
    /// be needed, and the per-round snapshot would be pure overhead.
    fn promote_mirror(&mut self, round: usize) -> Result<()> {
        if self.supervisor.is_none() {
            return Ok(());
        }
        self.mirror = Some(self.capture_state(round)?);
        Ok(())
    }

    /// Write the durable checkpoint at this round boundary when
    /// configured (`recovery.checkpoint_dir` set, boundary on the
    /// `checkpoint_every` cadence); returns the file size for the
    /// `checkpoint_bytes_per_round` ledger. The supervised path reuses
    /// the just-promoted mirror; otherwise the boundary state is
    /// captured transiently for the write.
    fn maybe_checkpoint(&mut self, round: usize, hist: &History) -> Result<Option<u64>> {
        if !self.cfg.recovery.checkpointing()
            || (round + 1) % self.cfg.recovery.checkpoint_every != 0
        {
            return Ok(None);
        }
        let boundary = round as u64 + 1;
        let transient = match self.mirror.as_ref() {
            Some(m) if m.round == boundary => None,
            _ => Some(self.capture_state(round)?),
        };
        let state = match transient.as_ref() {
            Some(s) => s,
            None => self
                .mirror
                .as_ref()
                .context("internal: mirror vanished between promote and checkpoint")?,
        };
        let toml = crate::config::file::to_toml_str(&self.cfg);
        let bytes = checkpoint::write_checkpoint(
            Path::new(&self.cfg.recovery.checkpoint_dir),
            &toml,
            state,
            hist,
        )
        .with_context(|| {
            format!(
                "writing round-{boundary} checkpoint to {}",
                self.cfg.recovery.checkpoint_dir
            )
        })?;
        Ok(Some(bytes))
    }

    /// Execute one synchronous round; returns the mean honest train loss.
    ///
    /// Every phase is bit-deterministic for any (procs × shards ×
    /// threads) grid point — see the module docs for the protocol.
    pub fn round(&mut self, round: usize) -> Result<f64> {
        // the round's active set (None ⇒ full participation): the same
        // per-node PARTICIPATE coin the job dispatches check, folded
        // once here for the digest/loss/serve phases
        let active = self.compute_active(round);
        // 0a. wire codec: install the round's delta reference (previous
        // digest mean) on every backend before any Snapshot is decoded
        if !self.cfg.compression.is_none() {
            for backend in self.backends.iter_mut() {
                backend.set_wire_ref(&self.wire_ref);
            }
        }
        // 0. async engine only: resolve the virtual-clock schedule and
        // ship each worker its staleness slice (None ⇒ synchronous)
        let sched = self.phase_async_begin(round)?;
        // 1. local half-steps (Algorithm 1 lines 3–6) — stale nodes
        // compute too (discarded): their RNG/momentum state must stay
        // on-schedule for the bit-identical neutral-config guarantee.
        // Inactive nodes do NOT compute: their streams freeze with them
        let mut loss = self.phase_half_steps(round, active.as_deref())?;
        // 1b. async: apply the served-row policy to the published table
        // and restrict the loss fold to fresh nodes
        if let Some(sched) = sched.as_ref() {
            loss = self.phase_async_serve(sched, active.as_deref());
        }
        // 1c. wire codec, in-process/virtual engines only: transform the
        // published table to its decoded-bits twin (remote tables are
        // already decoded — they came off the wire). Runs after the
        // served-row policy so carried rows transform at serve time,
        // mirroring the worker-side order
        self.phase_wire_transform()?;
        // 2. fold the published rows into the global honest digest the
        // omniscient adversary conditions on (active rows only: resting
        // nodes publish no new information)
        self.phase_attack_context(active.as_deref());
        // 3. push mode: honest senders scatter to s recipients; Byzantine
        // senders flood every honest node (the Appendix-D failure mode)
        let push_recv = self.phase_push_routes(round);
        // 4. pull, attack, aggregate — against the immutable round table
        // (synchronous model)
        self.phase_pull_craft_aggregate(round, push_recv.as_deref(), active.as_deref())?;
        // 5. synchronous swap, backend by backend; fold the telemetry.
        // Async: non-fresh nodes do not commit — their params and
        // ledgers return to the pre-round state (workers handle their
        // own slices; the in-process path saves/restores here)
        let saved = self.phase_async_pre_commit(sched.as_ref());
        self.phase_commit()?;
        self.phase_async_post_commit(saved);
        Ok(loss)
    }

    /// The round's honest active set under partial participation, or
    /// None at `participation = 1.0` (nothing is drawn — the dense
    /// engine's bits cannot shift). Honest-indexed; a pure function of
    /// `(seed, round)`, identical on every grid point.
    fn compute_active(&self, round: usize) -> Option<Vec<bool>> {
        if self.cfg.participation >= 1.0 {
            return None;
        }
        let p = self.cfg.participation;
        Some(
            self.honest_ids
                .iter()
                .map(|&id| vnode::is_active(self.cfg.seed, round, id, p))
                .collect(),
        )
    }

    /// The sparse ledgers' round entry: (active, materialized,
    /// resident-bytes). The virtual backend reports its own stores; the
    /// dense engines recount the public PARTICIPATE coins (byte-exact
    /// with what the job dispatches decided) and report full residency —
    /// h materialized rows plus every node's params + momentum. Public
    /// so memory-diet tests (`rust/tests/large_n.rs`) can read residency
    /// after driving [`Trainer::round`] directly, without a full `run()`.
    pub fn sparse_round_stats(&self, round: usize) -> (u32, u32, u64) {
        let tbl: u64 = self
            .tbl_halves
            .iter()
            .chain(self.tbl_params.iter())
            .map(|r| r.len() as u64 * 4)
            .sum();
        if let Some(v) = self.backends[0].as_virtual() {
            let s = v.stats();
            return (s.active, s.materialized, s.resident_bytes + tbl);
        }
        let active = match self.compute_active(round) {
            Some(mask) => mask.iter().filter(|&&a| a).count() as u32,
            None => self.h as u32,
        };
        let d = self.engine.d() as u64;
        (active, self.h as u32, tbl + self.h as u64 * 2 * d * 4)
    }

    /// Phase 0 (async engine only): advance the virtual clock, stash the
    /// round ledgers, and ship every remote backend its slice of the
    /// staleness schedule.
    fn phase_async_begin(&mut self, round: usize) -> Result<Option<RoundSchedule>> {
        let Some(vc) = self.vclock.as_mut() else {
            return Ok(None);
        };
        // virtual rounds are 1-based: "last fresh at 0" means never
        let sched = vc.advance(round as u64 + 1);
        self.last_round_participation = sched.participation();
        self.last_round_vclose = sched.close;
        self.last_round_stale = sched.stale.clone();
        for backend in self.backends.iter_mut() {
            let (start, len) = (backend.start(), backend.len());
            backend.begin_round_async(round, &sched.stale[start..start + len])?;
        }
        Ok(Some(sched))
    }

    /// Phase 1b (async): transform each published row per the staleness
    /// policy (in-process path — worker processes and the virtual
    /// backend transform their own rows before publishing, so the table
    /// already holds served rows) and fold the fresh-only loss.
    /// Inactivity trumps staleness: an inactive node's row IS its
    /// committed params, untransformed, and its carried snapshot stays
    /// frozen with the rest of its state.
    fn phase_async_serve(&mut self, sched: &RoundSchedule, active: Option<&[bool]>) -> f64 {
        if self.local_backends {
            for (i, &st) in sched.stale.iter().enumerate() {
                if !active.map_or(true, |m| m[i]) {
                    continue;
                }
                serve_row(
                    &self.cfg.asyn,
                    st,
                    &mut self.tbl_halves[i],
                    &mut self.carried[i],
                    &self.tbl_params[i],
                );
            }
        }
        // serial fresh∩active fold in ascending honest order; with every
        // node fresh and active this is exactly the synchronous sum/h
        let mut sum = 0.0f64;
        let mut fresh = 0usize;
        for (i, &st) in sched.stale.iter().enumerate() {
            if st == 0 && active.map_or(true, |m| m[i]) {
                sum += self.tbl_losses[i];
                fresh += 1;
            }
        }
        if fresh == 0 {
            0.0
        } else {
            sum / fresh as f64
        }
    }

    /// Async, in-process path: zero the non-fresh nodes' round ledgers
    /// and save their pre-round params so [`Self::phase_async_post_commit`]
    /// can undo the commit. Remote workers restore and zero their own
    /// slices before `RoundDone`, so nothing is saved for them here.
    fn phase_async_pre_commit(
        &mut self,
        sched: Option<&RoundSchedule>,
    ) -> Option<Vec<(usize, Vec<f32>)>> {
        let sched = sched?;
        if !self.local_backends {
            return None;
        }
        let mut saved = Vec::new();
        for (i, &st) in sched.stale.iter().enumerate() {
            if st != 0 {
                self.tbl_byz_seen[i] = 0;
                self.tbl_recv[i] = 0;
                saved.push((i, self.tbl_params[i].clone()));
            }
        }
        Some(saved)
    }

    /// Async, in-process path: a non-fresh node does not commit — its
    /// params return to the pre-round state, both in the mirror and in
    /// the owning shard's node state (momentum keeps advancing: the
    /// half-step ran, only its result is discarded).
    fn phase_async_post_commit(&mut self, saved: Option<Vec<(usize, Vec<f32>)>>) {
        let Some(saved) = saved else { return };
        for (i, row) in &saved {
            self.tbl_params[*i].copy_from_slice(row);
        }
        let mut it = saved.iter().peekable();
        for backend in self.backends.iter_mut() {
            let (start, len) = (backend.start(), backend.len());
            let shard = backend
                .as_node_shard()
                .expect("local backends are NodeShards");
            while let Some((i, row)) = it.peek() {
                if *i >= start + len {
                    break;
                }
                shard.nodes[*i - start].params.copy_from_slice(row);
                it.next();
            }
        }
    }

    /// Phase 1: every honest node's local train step. Remote backends are
    /// kicked off first so worker processes compute concurrently with the
    /// in-process shards.
    fn phase_half_steps(&mut self, round: usize, active: Option<&[bool]>) -> Result<f64> {
        let step_ctx = StepCtx {
            engine: self.engine.as_ref(),
            lr: self.cfg.lr_at(round),
            beta: self.cfg.momentum,
            wd: self.cfg.weight_decay,
            local_steps: self.engine.local_steps(),
            batch: self.engine.batch(),
            seed: self.cfg.seed,
            round,
            participation: self.cfg.participation,
        };
        for backend in self.backends.iter_mut() {
            backend.half_step_begin(round)?;
        }
        let pool = &self.pool;
        if self.local_backends {
            // flatten all in-process shards into one pool dispatch: no
            // per-shard barrier, one dispatch per phase (the PR-2 shape)
            let mut triples = Vec::with_capacity(self.backends.len());
            let mut halves_rest: &mut [Vec<f32>] = &mut self.tbl_halves;
            let mut losses_rest: &mut [f64] = &mut self.tbl_losses;
            for backend in self.backends.iter_mut() {
                let len = backend.len();
                let (hm, hr) = std::mem::take(&mut halves_rest).split_at_mut(len);
                let (lm, lr) = std::mem::take(&mut losses_rest).split_at_mut(len);
                halves_rest = hr;
                losses_rest = lr;
                let shard = backend
                    .as_node_shard()
                    .expect("local backends are NodeShards");
                triples.push((shard, hm, lm));
            }
            shard::half_step_shards(triples, &step_ctx, pool)?;
        } else {
            for backend in self.backends.iter_mut() {
                let (start, len) = (backend.start(), backend.len());
                backend.half_step_end(
                    round,
                    &step_ctx,
                    pool,
                    &mut self.tbl_halves[start..start + len],
                    &mut self.tbl_losses[start..start + len],
                )?;
            }
        }
        // serial fold in ascending honest order: identical for every
        // grid point. Inactive nodes hold exactly 0.0 (the dispatches
        // wrote it), so folding the full table adds only exact zeros;
        // the mean is over the nodes that actually trained
        let sum: f64 = self.tbl_losses.iter().sum();
        let denom = match active {
            Some(mask) => mask.iter().filter(|&&a| a).count(),
            None => self.h,
        };
        if denom == 0 {
            Ok(0.0)
        } else {
            Ok(sum / denom as f64)
        }
    }

    /// Phase 1c (in-process and virtual engines, `compression ≠ none`):
    /// transform every published row to its decoded-bits twin — the bits
    /// a remote consumer would decode off the wire — so a given
    /// compression level is bit-identical across the whole (transport ×
    /// procs × shards × threads × participation) grid. Remote backends
    /// skip this: their table rows already came through the codec.
    /// Virtual/participation sparse tables leave untouched rows empty;
    /// nothing reads them, so transforming only the non-empty rows
    /// matches the dense engines bit-for-bit.
    fn phase_wire_transform(&mut self) -> Result<()> {
        let comp = self.cfg.compression;
        if comp.is_none() || !(self.local_backends || self.cfg.virtual_nodes) {
            return Ok(());
        }
        let codec = wire_codec::RowCodec::new(comp, &self.wire_ref);
        let mut scratch = Vec::new();
        for row in self.tbl_halves.iter_mut() {
            if !row.is_empty() {
                wire_codec::transform_row_in_place(&codec, row, &mut scratch)?;
            }
        }
        Ok(())
    }

    /// Phase 2: fold the half-step table into the global honest digest,
    /// in ascending honest-node order (per-shard f64 partial sums would
    /// make the result depend on the shard grouping — see `shard.rs`).
    /// Skipped entirely when nothing will read it (no Byzantine nodes, or
    /// DoS where nothing is crafted); the O(h·d) variance pass runs only
    /// for ALIE, its sole consumer.
    /// The fold is restricted to the round's ACTIVE rows: a resting node
    /// publishes no new information, so the omniscient adversary (like
    /// everything else) conditions only on what the round produced. The
    /// virtual backend supplies its live set directly — its committed
    /// prev-params live in the materialized nodes, not the (empty)
    /// mirror rows — and the dense engines filter by the same mask, so
    /// the folds are row-for-row identical.
    fn phase_attack_context(&mut self, active: Option<&[bool]>) {
        use crate::attacks::AttackKind;
        // the row codec needs the digest mean as next round's delta
        // reference even when no attack reads it, so the skip applies
        // only at `compression = none`
        if (self.cfg.b == 0 || self.cfg.attack == AttackKind::Dos)
            && self.cfg.compression.is_none()
        {
            return;
        }
        let with_std = self.cfg.attack == AttackKind::Alie;
        if let Some(v) = self.backends[0].as_virtual() {
            let live = v.live();
            let halves: Vec<&[f32]> =
                live.iter().map(|&(hi, _)| self.tbl_halves[hi].as_slice()).collect();
            let prevs: Vec<&[f32]> =
                live.iter().map(|(_, node)| node.params.as_slice()).collect();
            self.digest.recompute(&halves, &prevs, with_std);
            return;
        }
        let keep = |i: usize| active.map_or(true, |m| m[i]);
        let halves: Vec<&[f32]> = self
            .tbl_halves
            .iter()
            .enumerate()
            .filter(|&(i, _)| keep(i))
            .map(|(_, r)| r.as_slice())
            .collect();
        let prevs: Vec<&[f32]> = self
            .tbl_params
            .iter()
            .enumerate()
            .filter(|&(i, _)| keep(i))
            .map(|(_, r)| r.as_slice())
            .collect();
        self.digest.recompute(&halves, &prevs, with_std);
    }

    /// Phase 3 (push-mode ablation only): sender → recipient routes. The
    /// scatter for sender `id` comes from the `(seed, round, id, PUSH)`
    /// stream, so routes are reproducible regardless of iteration order.
    /// Pipe-transport workers derive their victims' rows independently,
    /// so with no in-process shard there is nothing to compute here —
    /// but the socket transport needs them for the routing table.
    fn phase_push_routes(&self, round: usize) -> Option<Vec<Vec<usize>>> {
        let s = self.push_s?;
        if !self.local_backends && !self.cfg.transport.is_socket() {
            return None;
        }
        Some(shard::push_routes(
            self.cfg.seed,
            round,
            self.cfg.n,
            s,
            &self.byz,
            &self.node_of,
            self.h,
        ))
    }

    /// The per-round pull **routing table** (socket transport only): per
    /// victim, ascending honest order, the ordered global node ids it
    /// receives from this round — the pull set from the counter-keyed
    /// stream, the push sender list, or the graph neighborhood. This is
    /// all the coordinator ships per worker besides the digest; the
    /// workers fetch the referenced rows from each other.
    ///
    /// MUST stay bit-identical (content AND order) with the receive-set
    /// derivation in `shard::run_agg_jobs` — the in-process and pipe
    /// paths derive per-victim sets locally from the same keys, and any
    /// divergence splits pipe vs socket results. The determinism suite
    /// pins it, but edit both sites together.
    /// Under partial participation an inactive victim's row is shipped
    /// EMPTY: its aggregation job short-circuits before reading the
    /// routes, and the empty reference list is what makes socket workers
    /// skip fetching rows nobody will aggregate — the deterministic
    /// "skip inactive" rule on the wire.
    fn phase_routing_table(
        &self,
        round: usize,
        push_recv: Option<&[Vec<usize>]>,
        active: Option<&[bool]>,
    ) -> Option<Vec<Vec<usize>>> {
        if self.local_backends || !self.cfg.transport.is_socket() {
            return None;
        }
        if let Some(sampler) = self.sampler {
            let mut routes = Vec::with_capacity(self.h);
            for id in 0..self.cfg.n {
                if !self.byz[id] {
                    let hi = routes.len();
                    routes.push(if active.map_or(true, |m| m[hi]) {
                        sampler.sample_at(self.cfg.seed, round, id)
                    } else {
                        Vec::new()
                    });
                }
            }
            return Some(routes);
        }
        if let Some(recv) = push_recv {
            return Some(recv.to_vec());
        }
        if let Some(rows) = &self.gossip_rows {
            let mut routes = Vec::with_capacity(self.h);
            for id in 0..self.cfg.n {
                if self.byz[id] {
                    continue;
                }
                routes.push(
                    rows[id]
                        .iter()
                        .map(|&(j, _)| j)
                        .filter(|&j| j != id)
                        .collect(),
                );
            }
            return Some(routes);
        }
        unreachable!("config validation guarantees a topology")
    }

    /// Phase 4: per victim — pull `S_i^t`, craft the malicious rows
    /// against the digest, robustly aggregate. Remote backends receive
    /// the digest + table first and compute concurrently.
    fn phase_pull_craft_aggregate(
        &mut self,
        round: usize,
        push_recv: Option<&[Vec<usize>]>,
        active: Option<&[bool]>,
    ) -> Result<()> {
        let routes_tbl = self.phase_routing_table(round, push_recv, active);
        // round-scope the distance memo: the half-step table it keys
        // over is rebuilt every round
        self.dist_cache.clear();
        let ctx = AggCtx {
            agg: &self.agg,
            attack: self.attack.as_deref(),
            digest: &self.digest,
            halves: &self.tbl_halves,
            push_recv,
            routes: routes_tbl.as_ref().map(|r| (0usize, r.as_slice())),
            byz: &self.byz,
            node_of: &self.node_of,
            sampler: self.sampler,
            gossip_rows: self.gossip_rows.as_deref(),
            seed: self.cfg.seed,
            n: self.cfg.n,
            b: self.cfg.b,
            push: self.push_s.is_some(),
            dos: self.cfg.attack == crate::attacks::AttackKind::Dos,
            dist_cache: self.dist_cache_on.then_some(&self.dist_cache),
            wire_frame: std::sync::OnceLock::new(),
            participation: self.cfg.participation,
        };
        // serve-pulls phase: socket workers get the digest + their slice
        // of the routing table and start fetching from each other
        for backend in self.backends.iter_mut() {
            backend.serve_pulls(round, &ctx)?;
        }
        for backend in self.backends.iter_mut() {
            backend.aggregate_begin(round, &ctx)?;
        }
        let pool = &self.pool;
        if self.local_backends {
            // flatten all in-process shards into one pool dispatch
            let mut triples = Vec::with_capacity(self.backends.len());
            let mut byz_rest: &mut [usize] = &mut self.tbl_byz_seen;
            let mut recv_rest: &mut [usize] = &mut self.tbl_recv;
            for backend in self.backends.iter_mut() {
                let len = backend.len();
                let (bm, br) = std::mem::take(&mut byz_rest).split_at_mut(len);
                let (rm, rr) = std::mem::take(&mut recv_rest).split_at_mut(len);
                byz_rest = br;
                recv_rest = rr;
                let shard = backend
                    .as_node_shard()
                    .expect("local backends are NodeShards");
                triples.push((shard, bm, rm));
            }
            shard::aggregate_shards(triples, round, &ctx, pool)?;
        } else {
            for backend in self.backends.iter_mut() {
                let (start, len) = (backend.start(), backend.len());
                backend.aggregate_end(
                    round,
                    &ctx,
                    pool,
                    &mut self.tbl_byz_seen[start..start + len],
                    &mut self.tbl_recv[start..start + len],
                )?;
            }
        }
        Ok(())
    }

    /// Phase 5: commit every backend and fold the round telemetry in
    /// index order (identical for every grid point).
    fn phase_commit(&mut self) -> Result<()> {
        let mut wire = (0u64, 0u64, 0u64);
        let mut codec_bytes = (0u64, 0u64);
        let mut retries = 0u32;
        for backend in self.backends.iter_mut() {
            let (start, len) = (backend.start(), backend.len());
            backend.commit(&mut self.tbl_params[start..start + len])?;
            let (out, inn, peer) = backend.take_wire_bytes();
            wire.0 += out;
            wire.1 += inn;
            wire.2 += peer;
            let (raw, enc) = backend.take_codec_bytes();
            codec_bytes.0 += raw;
            codec_bytes.1 += enc;
            retries += backend.take_retries();
        }
        self.last_round_wire = wire;
        self.last_round_codec = codec_bytes;
        self.last_round_retries = retries;
        self.last_round_byz_max = self.tbl_byz_seen.iter().copied().max().unwrap_or(0);
        self.last_round_delivered = self.tbl_recv.iter().sum();
        if !self.cfg.compression.is_none() {
            // next round's delta reference: this round's digest mean.
            // Workers derive the identical f32 bits from the digest in
            // their aggregate frames, after their own commit
            self.wire_ref = wire_codec::reference_from_mean(&self.digest.mean);
        }
        Ok(())
    }

    /// Evaluate every honest node on the shared test set (parallel over
    /// nodes; read-only against the committed-params mirror).
    pub fn evaluate(&self, round: usize) -> Result<EvalPoint> {
        let n_test = self.test_y.len() as f64;
        let h = self.h;
        let engine: &dyn ComputeEngine = self.engine.as_ref();
        let test_x = &self.test_x;
        let test_y = &self.test_y;
        let mut accs = vec![0.0f64; h];
        let mut losses = vec![0.0f64; h];
        let mut jobs: Vec<(&mut f64, &mut f64)> =
            accs.iter_mut().zip(losses.iter_mut()).collect();
        if let Some(v) = self.backends[0].as_virtual() {
            // the mirror is empty on purpose: materialize each node's
            // committed row inside its own job — O(d) scratch per worker,
            // never h rows at once
            self.pool.try_for_each(&mut jobs, |i, job| {
                let row = v.committed_row(i);
                let (correct, loss_sum) = engine.evaluate(&row, test_x, test_y)?;
                *job.0 = correct / n_test;
                *job.1 = loss_sum / n_test;
                Ok(())
            })?;
        } else {
            let params: Vec<&[f32]> = self.tbl_params.iter().map(|r| r.as_slice()).collect();
            let params = &params;
            self.pool.try_for_each(&mut jobs, |i, job| {
                let (correct, loss_sum) = engine.evaluate(params[i], test_x, test_y)?;
                *job.0 = correct / n_test;
                *job.1 = loss_sum / n_test;
                Ok(())
            })?;
        }
        drop(jobs);
        Ok(EvalPoint {
            round,
            avg_acc: crate::util::stats::mean(&accs),
            worst_acc: crate::util::stats::min(&accs),
            avg_loss: crate::util::stats::mean(&losses),
        })
    }

    /// One honest node's committed parameters, by value — works for
    /// every backend: the virtual engine XOR-folds the node's delta log
    /// on demand (O(d·log-length)); the dense engines clone the mirror
    /// row. This is the accessor the cross-engine equality pins use.
    pub fn committed_params(&self, honest_idx: usize) -> Vec<f32> {
        debug_assert!(honest_idx < self.h);
        match self.backends[0].as_virtual() {
            Some(v) => v.committed_row(honest_idx),
            None => self.tbl_params[honest_idx].clone(),
        }
    }

    /// Immutable view of one honest node's committed parameters. O(1):
    /// the contiguous partition makes the honest index a direct row index
    /// into the committed-params mirror (the former per-shard linear
    /// scan — and its unreachable `panic!` — are gone). Dense engines
    /// only — the virtual backend keeps no mirror rows; use
    /// [`Self::committed_params`] there.
    pub fn params_of(&self, honest_idx: usize) -> &[f32] {
        debug_assert!(
            honest_idx < self.h,
            "honest index {honest_idx} out of range ({})",
            self.h
        );
        &self.tbl_params[honest_idx]
    }

    /// Global ids of the Byzantine nodes (tests/diagnostics).
    pub fn byzantine_ids(&self) -> Vec<usize> {
        (0..self.cfg.n).filter(|&i| self.byz[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::RuleKind;
    use crate::attacks::AttackKind;
    use crate::config::presets;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = presets::quickstart_config();
        cfg.rounds = 12;
        cfg.eval_every = 6;
        cfg
    }

    #[test]
    fn builds_and_places_adversaries() {
        let cfg = quick_cfg();
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.honest_count(), cfg.n - cfg.b);
        assert_eq!(t.byzantine_ids().len(), cfg.b);
        assert_eq!(t.bhat, 2);
        assert!(t.thread_count() >= 1);
        assert_eq!(t.shard_count(), 1);
    }

    #[test]
    fn shard_partition_is_contiguous_and_covers_all_nodes() {
        let mut cfg = quick_cfg();
        cfg.shards = 3;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.shard_count(), 3);
        let mut covered = 0usize;
        let mut next_start = 0usize;
        for backend in &t.backends {
            assert_eq!(backend.start(), next_start, "contiguous ranges");
            next_start += backend.len();
            covered += backend.len();
        }
        assert_eq!(covered, t.honest_count());
        // every honest index resolves to some mirrored params row
        for i in 0..t.honest_count() {
            assert!(!t.params_of(i).is_empty());
        }
    }

    #[test]
    fn oversubscribed_shards_clamp_to_honest_count() {
        let mut cfg = quick_cfg();
        cfg.shards = 1000;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.shard_count(), t.honest_count());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let h1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let h2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(h1.train_loss, h2.train_loss);
        assert_eq!(h1.final_avg_accuracy(), h2.final_avg_accuracy());
        let mut cfg3 = cfg;
        cfg3.seed = 99;
        let h3 = Trainer::from_config(&cfg3).unwrap().run().unwrap();
        assert_ne!(h1.train_loss, h3.train_loss);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut serial_cfg = quick_cfg();
        serial_cfg.threads = 1;
        let serial = Trainer::from_config(&serial_cfg).unwrap().run().unwrap();
        for threads in [2usize, 3, 8] {
            let mut cfg = quick_cfg();
            cfg.threads = threads;
            let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
            assert_eq!(serial.train_loss, hist.train_loss, "threads={threads}");
            assert_eq!(
                serial.observed_byz_max, hist.observed_byz_max,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn no_attack_training_learns() {
        let mut cfg = quick_cfg();
        cfg.b = 0;
        cfg.attack = AttackKind::None;
        cfg.rounds = 40;
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert!(
            hist.final_avg_accuracy() > 0.7,
            "acc={}",
            hist.final_avg_accuracy()
        );
        // loss decreased
        assert!(hist.final_train_loss() < hist.train_loss[0] * 0.8);
    }

    #[test]
    fn robust_rule_survives_sign_flip() {
        let mut cfg = quick_cfg();
        cfg.rounds = 40;
        cfg.b = 2; // 25% Byzantine: scaled SF reverses a plain average
        cfg.attack = AttackKind::SignFlip;
        let robust = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let mut mean_cfg = cfg.clone();
        mean_cfg.rule = RuleChoice::Epidemic(RuleKind::Mean);
        mean_cfg.name = "quickstart/mean".into();
        let mean = Trainer::from_config(&mean_cfg).unwrap().run().unwrap();
        assert!(
            robust.final_avg_accuracy() > mean.final_avg_accuracy() + 0.15,
            "robust={} mean={}",
            robust.final_avg_accuracy(),
            mean.final_avg_accuracy()
        );
    }

    #[test]
    fn message_accounting() {
        let cfg = quick_cfg();
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(hist.messages_per_round, cfg.n * 7);
        assert_eq!(hist.total_messages, cfg.n * 7 * cfg.rounds);
        // delivered ledger: with s = n−1 every honest victim receives a
        // row from every peer (the single Byzantine node responds under
        // SignFlip), so h·s models arrive per round — the nominal budget
        // additionally counts the Byzantine node's own pulls
        let h = cfg.n - cfg.b;
        assert_eq!(hist.delivered_per_round.len(), cfg.rounds);
        assert!(hist.delivered_per_round.iter().all(|&x| x == h * 7));
        assert_eq!(hist.total_delivered, h * 7 * cfg.rounds);
        assert!(hist.total_delivered < hist.total_messages);
    }

    #[test]
    fn eval_cadence_includes_final_round() {
        let mut cfg = quick_cfg();
        cfg.rounds = 13; // not divisible by eval_every=6
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let rounds: Vec<usize> = hist.evals.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![6, 12, 13]);
    }

    #[test]
    fn fixed_graph_topology_runs() {
        let mut cfg = quick_cfg();
        cfg.topology = Topology::FixedGraph { edges: 16 };
        cfg.rule = RuleChoice::Gossip(crate::aggregation::gossip::GossipRuleKind::CsPlus);
        cfg.rounds = 10;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let hist = t.run().unwrap();
        assert_eq!(hist.train_loss.len(), 10);
        assert_eq!(hist.messages_per_round, 32);
    }

    #[test]
    fn breakdown_detected_at_construction() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        cfg.n = 10;
        cfg.b = 4; // 40% byzantine, s=7: b̂ will hit 4 of 8 = 1/2
        cfg.topology = Topology::Epidemic { s: 7 };
        let err = match Trainer::from_config(&cfg) {
            Ok(_) => panic!("breakdown setting should fail construction"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("1/2"), "{err}");
    }

    #[test]
    fn algorithm2_resolves_bhat_when_unset() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        let t = Trainer::from_config(&cfg).unwrap();
        // 1 byzantine among 8, s=7 all-to-all: b̂ must be exactly 1
        assert_eq!(t.bhat, 1);
    }

    #[test]
    fn params_stay_finite_under_attacks() {
        for attack in AttackKind::panel() {
            let mut cfg = quick_cfg();
            cfg.attack = attack;
            cfg.rounds = 15;
            let mut t = Trainer::from_config(&cfg).unwrap();
            t.run().unwrap();
            for i in 0..t.honest_count() {
                assert!(
                    crate::util::vecmath::all_finite(t.params_of(i)),
                    "{:?} produced non-finite params",
                    attack
                );
            }
        }
    }

    #[test]
    fn neutral_async_config_is_bit_identical_to_sync() {
        let cfg = quick_cfg();
        let mut t_sync = Trainer::from_config(&cfg).unwrap();
        let sync = t_sync.run().unwrap();
        // quorum = h with every other knob default: the async machinery
        // runs (schedule, serve transform, fresh-only folds) but must
        // reproduce the synchronous engine bit-for-bit
        let mut acfg = quick_cfg();
        acfg.asyn.quorum = acfg.honest();
        let mut t_async = Trainer::from_config(&acfg).unwrap();
        let asy = t_async.run().unwrap();
        assert_eq!(sync.train_loss, asy.train_loss);
        assert_eq!(sync.observed_byz_max, asy.observed_byz_max);
        assert_eq!(sync.total_delivered, asy.total_delivered);
        for i in 0..t_sync.honest_count() {
            assert_eq!(t_sync.params_of(i), t_async.params_of(i), "node {i}");
        }
        let h = acfg.honest() as u32;
        assert_eq!(asy.participation_per_round, vec![h; acfg.rounds]);
        assert!(sync.participation_per_round.is_empty(), "sync runs keep no async ledgers");
    }

    #[test]
    fn straggler_run_is_reproducible_and_keeps_ledgers() {
        let mut cfg = quick_cfg();
        cfg.asyn.quorum = 5;
        cfg.asyn.max_staleness = 2;
        cfg.asyn.stale_policy = crate::config::StalePolicyKind::Decay;
        cfg.asyn.straggler = crate::config::StragglerKind::TwoPoint;
        cfg.asyn.slow_prob = 0.35;
        cfg.asyn.crash_prob = 0.1;
        let a = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let b = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(a.train_loss, b.train_loss, "modeled asynchrony is deterministic");
        assert_eq!(a.participation_per_round, b.participation_per_round);
        assert_eq!(a.virtual_close_per_round, b.virtual_close_per_round);
        assert_eq!(a.staleness_hist, b.staleness_hist);
        // ledger shape: one close/participation entry per round, one
        // histogram increment per honest node per round
        assert_eq!(a.participation_per_round.len(), cfg.rounds);
        assert_eq!(a.virtual_close_per_round.len(), cfg.rounds);
        assert_eq!(a.staleness_hist.len(), cfg.asyn.max_staleness + 2);
        let total: u64 = a.staleness_hist.iter().sum();
        assert_eq!(total, (cfg.rounds * cfg.honest()) as u64);
        let fresh: u64 = a.participation_per_round.iter().map(|&p| p as u64).sum();
        assert_eq!(a.staleness_hist[0], fresh);
        // slow_prob 0.35 with quorum 5/7 over 12 rounds must straggle
        assert!(a.staleness_hist[1..].iter().sum::<u64>() > 0);
    }

    #[test]
    fn virtual_backend_reproduces_dense_bit_for_bit() {
        let cfg = quick_cfg();
        let mut dense = Trainer::from_config(&cfg).unwrap();
        let dh = dense.run().unwrap();
        let mut vcfg = quick_cfg();
        vcfg.virtual_nodes = true;
        let mut virt = Trainer::from_config(&vcfg).unwrap();
        let vh = virt.run().unwrap();
        // same losses, same telemetry, same committed bits — the XOR
        // delta-log representation and lazy materialization must be
        // invisible
        assert_eq!(dh.train_loss, vh.train_loss);
        assert_eq!(dh.observed_byz_max, vh.observed_byz_max);
        assert_eq!(dh.total_delivered, vh.total_delivered);
        for i in 0..dense.honest_count() {
            assert_eq!(
                dense.committed_params(i),
                virt.committed_params(i),
                "node {i}"
            );
        }
        // full participation: every node active and materialized, ledgers
        // present only because the backend is virtual
        assert_eq!(vh.active_per_round, vec![cfg.honest() as u32; cfg.rounds]);
        assert!(dh.active_per_round.is_empty(), "dense full participation keeps no sparse ledgers");
    }

    #[test]
    fn partial_participation_freezes_inactive_nodes() {
        let mut cfg = quick_cfg();
        cfg.participation = 0.5;
        let a = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let b = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(a.train_loss, b.train_loss, "participation coins are counter-keyed");
        assert_eq!(a.active_per_round, b.active_per_round);
        assert_eq!(a.active_per_round.len(), cfg.rounds);
        let h = cfg.honest() as u32;
        assert!(a.active_per_round.iter().all(|&x| x <= h));
        assert!(
            a.active_per_round.iter().any(|&x| x < h),
            "p=0.5 over 12 rounds must rest someone: {:?}",
            a.active_per_round
        );
        // the ledger recomputes byte-exactly from the public stream
        let t = Trainer::from_config(&cfg).unwrap();
        for (round, &led) in a.active_per_round.iter().enumerate() {
            let expect = t
                .honest_ids
                .iter()
                .filter(|&&id| vnode::is_active(cfg.seed, round, id, cfg.participation))
                .count() as u32;
            assert_eq!(led, expect, "round {round}");
        }
        // fewer rows move: delivered is bounded by the dense run's
        let dense = Trainer::from_config(&quick_cfg()).unwrap().run().unwrap();
        assert!(a.total_delivered < dense.total_delivered);
    }

    #[test]
    fn virtual_matches_dense_under_partial_participation() {
        let mut dcfg = quick_cfg();
        dcfg.participation = 0.6;
        let mut dense = Trainer::from_config(&dcfg).unwrap();
        let dh = dense.run().unwrap();
        let mut vcfg = dcfg.clone();
        vcfg.virtual_nodes = true;
        let mut virt = Trainer::from_config(&vcfg).unwrap();
        let vh = virt.run().unwrap();
        assert_eq!(dh.train_loss, vh.train_loss);
        assert_eq!(dh.active_per_round, vh.active_per_round);
        for i in 0..dense.honest_count() {
            assert_eq!(dense.committed_params(i), virt.committed_params(i), "node {i}");
        }
        // the sparse backend holds fewer resident bytes than the dense
        // engine's full tables once someone has rested
        let dmax = dh.resident_bytes_per_round.iter().max().unwrap();
        let vmax = vh.resident_bytes_per_round.iter().max().unwrap();
        assert!(vmax < dmax, "virtual {vmax} >= dense {dmax}");
        // materialized = active ∪ pulled ≤ h, ≥ active
        for (m, a) in vh.materialized_per_round.iter().zip(&vh.active_per_round) {
            assert!(m >= a && *m <= dcfg.honest() as u32);
        }
    }

    #[test]
    fn dos_rounds_deliver_fewer_messages_than_nominal() {
        let mut cfg = quick_cfg();
        cfg.attack = AttackKind::Dos;
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let h = cfg.n - cfg.b;
        // withheld Byzantine responses: strictly fewer than h·s arrive
        assert!(hist
            .delivered_per_round
            .iter()
            .all(|&x| x < h * 7), "{:?}", hist.delivered_per_round);
        assert!(hist.total_delivered < hist.total_messages);
    }
}
