//! The RPEL coordinator: Algorithm 1 as a synchronous round engine.
//!
//! Per round t, for every honest node i (paper Algorithm 1):
//!
//! 1. local stochastic gradient + Polyak momentum + half-step
//!    `x_i^{t+1/2} = x_i^t − η m_i^t` (delegated to the compute engine —
//!    the AOT HLO graph or its native twin);
//! 2. pull sampling: `S_i^t` = s uniform peers (epidemic topology) or the
//!    fixed graph neighborhood (baseline topology);
//! 3. the omniscient adversary crafts per-victim malicious models for the
//!    Byzantine members of `S_i^t` (it sees every honest half-step);
//! 4. robust aggregation `x_i^{t+1} = R(x_i^{t+1/2}; received)` — the
//!    Pallas NNM∘CWTM executable on the HLO path, or a native rule.
//!
//! All honest updates within a round are computed against the same
//! snapshot (synchronous model, §3.3) — nodes never see intra-round
//! updates of their peers.

pub mod engine;
pub mod sampler;

pub use engine::{build_engine, ComputeEngine, HloEngine, NativeEngine};
pub use sampler::PullSampler;

use crate::aggregation::gossip::GossipAggregator;
use crate::aggregation::Aggregator;
use crate::attacks::{Attack, AttackContext};
use crate::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use crate::data::{partition_dirichlet, Shard};
use crate::graph::Graph;
use crate::metrics::{EvalPoint, History};
use crate::runtime::{AggregateExec, Runtime};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::time::Instant;

/// State owned by one honest node.
struct NodeState {
    /// global node id in [0, n)
    id: usize,
    params: Vec<f32>,
    momentum: Vec<f32>,
    shard: Shard,
}

/// Which aggregation backend executes step 4.
enum AggBackend {
    /// Native Definition-5.1 rule over the pulled set.
    Native(Box<dyn Aggregator>),
    /// The AOT Pallas NNM∘CWTM executable (production path).
    Hlo(AggregateExec),
    /// Fixed-graph gossip rule over the node's neighborhood.
    Gossip(Box<dyn GossipAggregator>),
}

impl AggBackend {
    fn name(&self) -> &'static str {
        match self {
            AggBackend::Native(r) => r.name(),
            AggBackend::Hlo(_) => "nnm_cwtm[pallas]",
            AggBackend::Gossip(r) => r.name(),
        }
    }
}

/// A fully constructed training run.
pub struct Trainer {
    cfg: ExperimentConfig,
    engine: Box<dyn ComputeEngine>,
    agg: AggBackend,
    attack: Option<Box<dyn Attack>>,
    /// resolved effective adversaries b̂ (Algorithm 2 output when the
    /// config left it unset)
    pub bhat: usize,
    /// per-id Byzantine flag and id → honest-index map
    byz: Vec<bool>,
    node_of: Vec<usize>,
    nodes: Vec<NodeState>,
    sampler: Option<PullSampler>,
    /// push mode (pull-vs-push ablation): fan-out per honest sender
    push_s: Option<usize>,
    /// fixed-graph topology: metropolis rows per node id
    gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
    rng: Rng,
    /// §4.2 telemetry: max Byzantine rows any honest node received in the
    /// last round (the *observed* b̂)
    last_round_byz_max: usize,
    // reusable round buffers
    halves: Vec<Vec<f32>>,
    next_params: Vec<Vec<f32>>,
    byz_buf: Vec<Vec<f32>>,
    mean_buf: Vec<f32>,
    prev_mean_buf: Vec<f32>,
}

impl Trainer {
    /// Build everything: engine, adversary placement, shards, topology,
    /// b̂ resolution (Algorithm 2 when unset).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!("invalid config: {e}"))?;
        let mut cfg = cfg.clone();
        let mut rng = Rng::new(cfg.seed);

        // --- compute engine -------------------------------------------------
        let mut runtime = match cfg.engine {
            EngineKind::Hlo => Some(
                Runtime::open(&cfg.artifacts_dir)
                    .context("HLO engine requires built artifacts")?,
            ),
            EngineKind::Native => None,
        };
        let mut engine = build_engine(&cfg, runtime.as_mut())?;
        if engine.batch() != cfg.batch {
            log::info!(
                "batch {} overridden to {} (baked into HLO artifact)",
                cfg.batch,
                engine.batch()
            );
            cfg.batch = engine.batch();
        }
        let d = engine.d();

        // --- resolve b̂ (Algorithm 2 / §6.1) --------------------------------
        // b̂ resolution uses Appendix B Remark 2's "more precise" method:
        // the exact 90%-quantile of max_{i,t} b_i^t from the closed-form
        // hypergeometric CDF (deterministic; Algorithm 2's simulation is
        // available via `rpel select` / sampling::select_params).
        const BHAT_CONFIDENCE: f64 = 0.9;
        let bhat = match (cfg.bhat, &cfg.topology) {
            (Some(bh), _) => bh,
            (None, _) if cfg.b == 0 => 0,
            // push mode deliberately reuses the pull-mode b̂ (Appendix D:
            // flooding voids the hypergeometric bound — that mismatch IS
            // the ablation)
            (None, Topology::Epidemic { s }) | (None, Topology::EpidemicPush { s }) => {
                crate::sampling::selector::select_bhat_exact(
                    cfg.n as u64,
                    cfg.b as u64,
                    cfg.rounds as u64,
                    *s as u64,
                    BHAT_CONFIDENCE,
                ) as usize
            }
            (None, Topology::FixedGraph { .. }) => {
                // Remark C.2: under random placement use the same b̂ an
                // epidemic run of equal budget would use
                let s_equiv = (2 * cfg.messages_per_round() / cfg.n).clamp(1, cfg.n - 1);
                crate::sampling::selector::select_bhat_exact(
                    cfg.n as u64,
                    cfg.b as u64,
                    cfg.rounds as u64,
                    s_equiv as u64,
                    BHAT_CONFIDENCE,
                ) as usize
            }
        };
        if let Topology::Epidemic { s } = cfg.topology {
            if cfg.b > 0 && 2 * bhat >= s + 1 {
                bail!(
                    "effective adversarial fraction {bhat}/{} ≥ 1/2 — robust \
                     aggregation breaks down (paper §6.2); increase s or reduce b",
                    s + 1
                );
            }
        }

        // --- aggregation backend -------------------------------------------
        let agg = match (&cfg.topology, cfg.rule) {
            (Topology::Epidemic { s }, RuleChoice::Epidemic(kind)) => {
                // DoS shrinks receive sets; the fixed-shape Pallas
                // executable cannot apply, so fall back to the native rule
                let want_hlo = cfg.engine == EngineKind::Hlo
                    && kind == crate::aggregation::RuleKind::NnmCwtm
                    && cfg.attack != crate::attacks::AttackKind::Dos;
                if want_hlo {
                    let rt = runtime.as_mut().unwrap();
                    match rt.aggregate_exec(&cfg.arch, s + 1, bhat) {
                        Ok(exec) => AggBackend::Hlo(exec),
                        Err(e) => {
                            log::warn!(
                                "no Pallas aggregate artifact (m={}, b̂={bhat}): {e}; \
                                 falling back to native rule",
                                s + 1
                            );
                            AggBackend::Native(kind.build(bhat))
                        }
                    }
                } else {
                    AggBackend::Native(kind.build(bhat))
                }
            }
            (Topology::EpidemicPush { .. }, RuleChoice::Epidemic(kind)) => {
                AggBackend::Native(kind.build(bhat))
            }
            (Topology::FixedGraph { .. }, RuleChoice::Gossip(kind)) => {
                AggBackend::Gossip(kind.build(bhat))
            }
            _ => bail!("rule/topology mismatch (config validation bug)"),
        };

        // --- adversary placement (uniform random, Remark C.1) ---------------
        let mut byz = vec![false; cfg.n];
        for id in rng.fork(0xB12).sample_distinct(cfg.n, cfg.b) {
            byz[id] = true;
        }
        let attack = if cfg.b > 0 { cfg.attack.build() } else { None };

        // --- data ------------------------------------------------------------
        let task = cfg.task.spec().instantiate(cfg.seed);
        let mut data_rng = rng.fork(0xDA7A);
        let shard_labels = partition_dirichlet(
            cfg.n,
            task.spec.classes,
            cfg.samples_per_node,
            cfg.alpha,
            &mut data_rng,
        );
        let test_n = if engine.eval_n() > 0 {
            if engine.eval_n() != cfg.test_samples {
                log::info!(
                    "test_samples {} overridden to {} (baked into HLO eval artifact)",
                    cfg.test_samples,
                    engine.eval_n()
                );
            }
            engine.eval_n()
        } else {
            cfg.test_samples
        };
        let test = task.sample_uniform(test_n, &mut data_rng);

        // --- honest node states ----------------------------------------------
        let mut nodes = Vec::with_capacity(cfg.honest());
        let mut node_of = vec![usize::MAX; cfg.n];
        for id in 0..cfg.n {
            if byz[id] {
                continue;
            }
            let labels = &shard_labels[id];
            let data = task.sample_labels(labels, &mut data_rng);
            let shard = Shard::new(data, rng.fork(0x5AD + id as u64));
            node_of[id] = nodes.len();
            let params = engine.init_params(cfg.seed as i32)?;
            nodes.push(NodeState {
                id,
                params,
                momentum: vec![0.0f32; d],
                shard,
            });
        }

        // --- topology ----------------------------------------------------------
        let (sampler, push_s, gossip_rows) = match cfg.topology {
            Topology::Epidemic { s } => (Some(PullSampler::new(cfg.n, s)), None, None),
            Topology::EpidemicPush { s } => (None, Some(s), None),
            Topology::FixedGraph { edges } => {
                let g = Graph::random_connected(cfg.n, edges, &mut rng.fork(0x6AF));
                (None, None, Some(g.metropolis_weights()))
            }
        };

        let h = nodes.len();
        // worst-case malicious rows per victim: s for pulls, b for a
        // flooding push round, degree ≤ n−1 for graphs
        let s_max = cfg.n - 1;
        log::info!(
            "trainer '{}': n={} b={} b̂={bhat} rule={} engine={} d={d}",
            cfg.name,
            cfg.n,
            cfg.b,
            agg.name(),
            engine.name()
        );
        Ok(Trainer {
            bhat,
            byz,
            node_of,
            sampler,
            push_s,
            gossip_rows,
            test_x: test.x,
            test_y: test.y,
            rng,
            last_round_byz_max: 0,
            halves: vec![vec![0.0f32; d]; h],
            next_params: vec![vec![0.0f32; d]; h],
            byz_buf: vec![vec![0.0f32; d]; s_max],
            mean_buf: vec![0.0f32; d],
            prev_mean_buf: vec![0.0f32; d],
            nodes,
            engine,
            agg,
            attack,
            cfg,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Which aggregation backend actually runs (for logs/tests).
    pub fn aggregation_name(&self) -> &'static str {
        self.agg.name()
    }

    /// Number of honest nodes.
    pub fn honest_count(&self) -> usize {
        self.nodes.len()
    }

    /// Run the full training; returns the metric history.
    pub fn run(&mut self) -> Result<History> {
        let t0 = Instant::now();
        let mut hist = History::new(&self.cfg.name, self.cfg.messages_per_round());
        for round in 0..self.cfg.rounds {
            let loss = self.round(round)?;
            hist.train_loss.push(loss);
            hist.observed_byz_max.push(self.last_round_byz_max);
            hist.total_messages += self.cfg.messages_per_round();
            let last = round + 1 == self.cfg.rounds;
            if last || (round + 1) % self.cfg.eval_every == 0 {
                hist.evals.push(self.evaluate(round + 1)?);
            }
        }
        hist.wall_secs = t0.elapsed().as_secs_f64();
        Ok(hist)
    }

    /// Execute one synchronous round; returns the mean honest train loss.
    pub fn round(&mut self, round: usize) -> Result<f64> {
        let lr = self.cfg.lr_at(round);
        let beta = self.cfg.momentum;
        let wd = self.cfg.weight_decay;
        let k = self.engine.local_steps();
        let batch = self.engine.batch();
        let h = self.nodes.len();

        // 1. local half-steps (Algorithm 1 lines 3–6)
        let mut loss_sum = 0.0f64;
        for (i, node) in self.nodes.iter_mut().enumerate() {
            self.halves[i].copy_from_slice(&node.params);
            let b = node.shard.next_batches(k, batch);
            loss_sum += self.engine.train_step(
                &mut self.halves[i],
                &mut node.momentum,
                &b.x,
                &b.y,
                lr,
                beta,
                wd,
            )? as f64;
        }

        // 2. omniscient-adversary context: honest means
        column_mean(&self.halves, &mut self.mean_buf);
        {
            let prev: Vec<&[f32]> = self.nodes.iter().map(|n| n.params.as_slice()).collect();
            crate::util::vecmath::mean_of(&prev, &mut self.prev_mean_buf);
        }

        // push mode: honest senders scatter to s recipients; Byzantine
        // senders flood every honest node (the Appendix-D failure mode)
        let push_received: Option<Vec<Vec<usize>>> = self.push_s.map(|s| {
            let mut recv: Vec<Vec<usize>> = vec![Vec::new(); h];
            for sender in 0..h {
                let id = self.nodes[sender].id;
                for dest in self.rng.sample_distinct_excluding(self.cfg.n, s, id) {
                    if !self.byz[dest] {
                        recv[self.node_of[dest]].push(id);
                    }
                    // pushes to Byzantine recipients are wasted messages
                }
            }
            recv
        });

        // DoS (Appendix D): Byzantine nodes withhold their models; the
        // synchronous round proceeds with whatever honest peers sent
        let dos = self.cfg.attack == crate::attacks::AttackKind::Dos;

        // 3.+4. pull, attack, aggregate — against the immutable half-step
        // snapshot (synchronous model)
        self.last_round_byz_max = 0;
        for i in 0..h {
            let peers: Vec<usize> = match (&self.sampler, &push_received, &self.gossip_rows)
            {
                (Some(sampler), _, _) => sampler.sample(self.nodes[i].id, &mut self.rng),
                (None, Some(recv), _) => recv[i].clone(),
                (None, None, Some(rows)) => rows[self.nodes[i].id]
                    .iter()
                    .map(|&(j, _)| j)
                    .filter(|&j| j != self.nodes[i].id)
                    .collect(),
                _ => unreachable!(),
            };

            // split into honest refs and byzantine slots
            let mut honest_rows: Vec<&[f32]> = Vec::with_capacity(peers.len());
            let mut byz_count = 0usize;
            for &p in &peers {
                if self.byz[p] {
                    byz_count += 1;
                } else {
                    honest_rows.push(&self.halves[self.node_of[p]]);
                }
            }
            if push_received.is_some() && self.cfg.b > 0 && !dos {
                // flooding: every Byzantine node reaches every honest node
                byz_count = self.cfg.b;
            }
            if dos {
                byz_count = 0; // withheld responses simply never arrive
            }
            self.last_round_byz_max = self.last_round_byz_max.max(byz_count);

            // craft per-victim malicious models
            if byz_count > 0 {
                if let Some(attack) = &self.attack {
                    let all: Vec<&[f32]> = self.halves.iter().map(|v| v.as_slice()).collect();
                    let ctx = AttackContext {
                        victim_half: &self.halves[i],
                        victim_prev: &self.nodes[i].params,
                        honest_received: &honest_rows,
                        honest_all: &all,
                        honest_mean: &self.mean_buf,
                        honest_prev_mean: &self.prev_mean_buf,
                        n: self.cfg.n,
                        b: self.cfg.b,
                    };
                    attack.craft(&ctx, &mut self.byz_buf[..byz_count]);
                } else {
                    // b > 0 but attack "none": byzantine nodes behave as
                    // silent crashers sending their init... treat as the
                    // honest mean (benign)
                    for row in &mut self.byz_buf[..byz_count] {
                        row.copy_from_slice(&self.mean_buf);
                    }
                }
            }

            match &self.agg {
                AggBackend::Native(rule) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    rows.push(&self.halves[i]);
                    rows.extend_from_slice(&honest_rows);
                    for rbuf in &self.byz_buf[..byz_count] {
                        rows.push(rbuf);
                    }
                    if rows.len() < rule.min_inputs() {
                        // too few responses to aggregate robustly (push /
                        // DoS rounds): keep the local half-step
                        self.next_params[i].copy_from_slice(&self.halves[i]);
                    } else {
                        rule.aggregate(&rows, &mut self.next_params[i]);
                    }
                }
                AggBackend::Hlo(exec) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    rows.push(&self.halves[i]);
                    rows.extend_from_slice(&honest_rows);
                    for rbuf in &self.byz_buf[..byz_count] {
                        rows.push(rbuf);
                    }
                    let out = exec.run(&rows)?;
                    self.next_params[i].copy_from_slice(&out);
                }
                AggBackend::Gossip(rule) => {
                    // gossip needs (model, weight) pairs in graph order
                    let rows = self.gossip_rows.as_ref().unwrap();
                    let id = self.nodes[i].id;
                    let mut neigh: Vec<(&[f32], f64)> = Vec::with_capacity(peers.len());
                    let mut byz_used = 0usize;
                    for &(j, w) in &rows[id] {
                        if j == id {
                            continue;
                        }
                        if self.byz[j] {
                            neigh.push((&self.byz_buf[byz_used], w));
                            byz_used += 1;
                        } else {
                            neigh.push((&self.halves[self.node_of[j]], w));
                        }
                    }
                    rule.aggregate(&self.halves[i], &neigh, &mut self.next_params[i]);
                }
            }
        }

        // 5. synchronous swap
        for (node, next) in self.nodes.iter_mut().zip(&self.next_params) {
            node.params.copy_from_slice(next);
        }
        Ok(loss_sum / h as f64)
    }

    /// Evaluate every honest node on the shared test set.
    pub fn evaluate(&mut self, round: usize) -> Result<EvalPoint> {
        let n_test = self.test_y.len() as f64;
        let mut accs = Vec::with_capacity(self.nodes.len());
        let mut losses = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let (correct, loss_sum) =
                self.engine
                    .evaluate(&node.params, &self.test_x, &self.test_y)?;
            accs.push(correct / n_test);
            losses.push(loss_sum / n_test);
        }
        Ok(EvalPoint {
            round,
            avg_acc: crate::util::stats::mean(&accs),
            worst_acc: crate::util::stats::min(&accs),
            avg_loss: crate::util::stats::mean(&losses),
        })
    }

    /// Immutable view of one honest node's parameters (tests).
    pub fn params_of(&self, honest_idx: usize) -> &[f32] {
        &self.nodes[honest_idx].params
    }

    /// Global ids of the Byzantine nodes (tests/diagnostics).
    pub fn byzantine_ids(&self) -> Vec<usize> {
        (0..self.cfg.n).filter(|&i| self.byz[i]).collect()
    }
}

/// Column mean over equal-length rows.
fn column_mean(rows: &[Vec<f32>], out: &mut [f32]) {
    out.fill(0.0);
    for r in rows {
        crate::util::vecmath::axpy(out, 1.0, r);
    }
    crate::util::vecmath::scale(out, 1.0 / rows.len() as f32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::RuleKind;
    use crate::attacks::AttackKind;
    use crate::config::presets;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = presets::quickstart_config();
        cfg.rounds = 12;
        cfg.eval_every = 6;
        cfg
    }

    #[test]
    fn builds_and_places_adversaries() {
        let cfg = quick_cfg();
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.honest_count(), cfg.n - cfg.b);
        assert_eq!(t.byzantine_ids().len(), cfg.b);
        assert_eq!(t.bhat, 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let h1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let h2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(h1.train_loss, h2.train_loss);
        assert_eq!(h1.final_avg_accuracy(), h2.final_avg_accuracy());
        let mut cfg3 = cfg;
        cfg3.seed = 99;
        let h3 = Trainer::from_config(&cfg3).unwrap().run().unwrap();
        assert_ne!(h1.train_loss, h3.train_loss);
    }

    #[test]
    fn no_attack_training_learns() {
        let mut cfg = quick_cfg();
        cfg.b = 0;
        cfg.attack = AttackKind::None;
        cfg.rounds = 40;
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert!(
            hist.final_avg_accuracy() > 0.7,
            "acc={}",
            hist.final_avg_accuracy()
        );
        // loss decreased
        assert!(hist.final_train_loss() < hist.train_loss[0] * 0.8);
    }

    #[test]
    fn robust_rule_survives_sign_flip() {
        let mut cfg = quick_cfg();
        cfg.rounds = 40;
        cfg.b = 2; // 25% Byzantine: scaled SF reverses a plain average
        cfg.attack = AttackKind::SignFlip;
        let robust = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let mut mean_cfg = cfg.clone();
        mean_cfg.rule = RuleChoice::Epidemic(RuleKind::Mean);
        mean_cfg.name = "quickstart/mean".into();
        let mean = Trainer::from_config(&mean_cfg).unwrap().run().unwrap();
        assert!(
            robust.final_avg_accuracy() > mean.final_avg_accuracy() + 0.15,
            "robust={} mean={}",
            robust.final_avg_accuracy(),
            mean.final_avg_accuracy()
        );
    }

    #[test]
    fn message_accounting() {
        let cfg = quick_cfg();
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(hist.messages_per_round, cfg.n * 7);
        assert_eq!(hist.total_messages, cfg.n * 7 * cfg.rounds);
    }

    #[test]
    fn eval_cadence_includes_final_round() {
        let mut cfg = quick_cfg();
        cfg.rounds = 13; // not divisible by eval_every=6
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let rounds: Vec<usize> = hist.evals.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![6, 12, 13]);
    }

    #[test]
    fn fixed_graph_topology_runs() {
        let mut cfg = quick_cfg();
        cfg.topology = Topology::FixedGraph { edges: 16 };
        cfg.rule = RuleChoice::Gossip(crate::aggregation::gossip::GossipRuleKind::CsPlus);
        cfg.rounds = 10;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let hist = t.run().unwrap();
        assert_eq!(hist.train_loss.len(), 10);
        assert_eq!(hist.messages_per_round, 32);
    }

    #[test]
    fn breakdown_detected_at_construction() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        cfg.n = 10;
        cfg.b = 4; // 40% byzantine, s=7: b̂ will hit 4 of 8 = 1/2
        cfg.topology = Topology::Epidemic { s: 7 };
        let err = match Trainer::from_config(&cfg) {
            Ok(_) => panic!("breakdown setting should fail construction"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("1/2"), "{err}");
    }

    #[test]
    fn algorithm2_resolves_bhat_when_unset() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        let t = Trainer::from_config(&cfg).unwrap();
        // 1 byzantine among 8, s=7 all-to-all: b̂ must be exactly 1
        assert_eq!(t.bhat, 1);
    }

    #[test]
    fn params_stay_finite_under_attacks() {
        for attack in AttackKind::panel() {
            let mut cfg = quick_cfg();
            cfg.attack = attack;
            cfg.rounds = 15;
            let mut t = Trainer::from_config(&cfg).unwrap();
            t.run().unwrap();
            for i in 0..t.honest_count() {
                assert!(
                    crate::util::vecmath::all_finite(t.params_of(i)),
                    "{:?} produced non-finite params",
                    attack
                );
            }
        }
    }
}
