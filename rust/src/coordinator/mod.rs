//! The RPEL coordinator: Algorithm 1 as a synchronous round engine.
//!
//! Per round t, for every honest node i (paper Algorithm 1):
//!
//! 1. local stochastic gradient + Polyak momentum + half-step
//!    `x_i^{t+1/2} = x_i^t − η m_i^t` (delegated to the compute engine —
//!    the AOT HLO graph or its native twin);
//! 2. pull sampling: `S_i^t` = s uniform peers (epidemic topology) or the
//!    fixed graph neighborhood (baseline topology);
//! 3. the omniscient adversary crafts per-victim malicious models for the
//!    Byzantine members of `S_i^t`;
//! 4. robust aggregation `x_i^{t+1} = R(x_i^{t+1/2}; received)` — the
//!    Pallas NNM∘CWTM executable on the HLO path, or a native rule.
//!
//! All honest updates within a round are computed against the same
//! snapshot (synchronous model, §3.3) — nodes never see intra-round
//! updates of their peers.
//!
//! # Shard-partitioned round engine
//!
//! Honest-node state is partitioned into [`shard::NodeShard`]s, each
//! owning a **contiguous range of honest nodes** (params, momentum, data
//! shards, half/next buffers). [`Trainer`] is an orchestrator over
//! `Vec<NodeShard>`; every round runs the explicit shard protocol:
//!
//! 1. **half-step** — every owned node's local train step, data-parallel
//!    over all shards' nodes;
//! 2. **publish + digest** — each shard publishes a read-only
//!    [`shard::RoundDigest`] of its half-steps; the coordinator folds
//!    them, in ascending honest-node order, into one
//!    [`crate::attacks::HonestDigest`] (count, coordinate-wise mean/std,
//!    prev-mean — all f64). This is the only all-nodes reduction in the
//!    round, and it is what the omniscient adversary conditions on:
//!    crafting is O(d) per victim, and no victim ever borrows the full
//!    honest population (the former `honest_all`, an O(h²·d) round cost
//!    under ALIE);
//! 3. **push routes** (push-mode ablation only) — sender → recipient
//!    scatter (serial; cheap index shuffling);
//! 4. **pull + craft + aggregate** — per victim: draw `S_i^t`, pull
//!    exactly those rows from the published shard snapshots, craft the
//!    malicious rows against the digest, aggregate into the victim
//!    shard's next buffer;
//! 5. **commit** — each shard's synchronous swap.
//!
//! # Persistent worker pool
//!
//! The per-node phases (1, 4, eval) are data-parallel on a
//! [`crate::util::pool::WorkerPool`]: `threads − 1` long-lived workers
//! plus the coordinator thread, fed via channels — no scoped-thread
//! respawn per phase, and per-worker scratch (gradient buffers, attack
//! crafting rows) lives in thread-locals that survive across rounds.
//! `threads` comes from [`ExperimentConfig::threads`] (`--threads`; `0` =
//! all cores, `1` = inline serial); the shard count from
//! [`ExperimentConfig::shards`] (`--shards`, default 1).
//!
//! # Determinism
//!
//! Results are **bit-identical for every (shards × threads)
//! combination**: all round-path randomness comes from counter-based
//! streams keyed `(seed, round, node, purpose)`
//! ([`crate::util::rng::Rng::stream`]) so no draw depends on scheduling
//! or partitioning; the digest is folded serially in ascending
//! honest-node order regardless of shard boundaries; and scalar
//! reductions (loss mean, observed-b̂ max) collect per-node values and
//! fold them serially in index order. `rust/tests/determinism.rs`
//! enforces the grid. This is the stepping stone to multi-process
//! shards: a remote shard ships the same `RoundDigest` payload its
//! in-process twin publishes by borrow.

pub mod engine;
pub mod sampler;
pub(crate) mod shard;

pub use engine::{build_engine, ComputeEngine, HloEngine, NativeEngine};
pub use sampler::PullSampler;

use crate::aggregation::gossip::GossipAggregator;
use crate::aggregation::Aggregator;
use crate::attacks::{Attack, AttackContext, HonestDigest};
use crate::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use crate::data::partition_dirichlet;
use crate::graph::Graph;
use crate::metrics::{EvalPoint, History};
use crate::runtime::{AggregateExec, Runtime};
use crate::util::pool::WorkerPool;
use crate::util::rng::{stream_tag, Rng};
use anyhow::{anyhow, bail, Context, Result};
use shard::{NodeShard, NodeState};
use std::cell::RefCell;
use std::time::Instant;

/// Which aggregation backend executes step 4.
enum AggBackend {
    /// Native Definition-5.1 rule over the pulled set.
    Native(Box<dyn Aggregator>),
    /// The AOT Pallas NNM∘CWTM executable (production path).
    Hlo(AggregateExec),
    /// Fixed-graph gossip rule over the node's neighborhood.
    Gossip(Box<dyn GossipAggregator>),
}

impl AggBackend {
    fn name(&self) -> &'static str {
        match self {
            AggBackend::Native(r) => r.name(),
            AggBackend::Hlo(_) => "nnm_cwtm[pallas]",
            AggBackend::Gossip(r) => r.name(),
        }
    }
}

/// One node's slot in the parallel half-step phase.
struct HalfStepJob<'a> {
    node: &'a mut NodeState,
    half: &'a mut Vec<f32>,
    loss: &'a mut f64,
}

/// One victim's slot in the parallel pull/craft/aggregate phase.
struct AggJob<'a> {
    out: &'a mut Vec<f32>,
    byz_seen: &'a mut usize,
}

thread_local! {
    /// Per-worker crafting scratch (`b` rows of length d). Thread-local so
    /// the persistent pool's workers retain it across rounds instead of
    /// reallocating per dispatch.
    static CRAFT_ROWS: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
}

/// A fully constructed training run.
pub struct Trainer {
    cfg: ExperimentConfig,
    engine: Box<dyn ComputeEngine>,
    agg: AggBackend,
    attack: Option<Box<dyn Attack>>,
    /// resolved effective adversaries b̂ (Algorithm 2 output when the
    /// config left it unset)
    pub bhat: usize,
    /// per-id Byzantine flag and id → honest-index map
    byz: Vec<bool>,
    node_of: Vec<usize>,
    /// shard-owned honest node state (contiguous honest-index ranges)
    shards: Vec<NodeShard>,
    /// honest count |H| (sum of shard lengths)
    h: usize,
    sampler: Option<PullSampler>,
    /// push mode (pull-vs-push ablation): fan-out per honest sender
    push_s: Option<usize>,
    /// fixed-graph topology: metropolis rows per node id
    gossip_rows: Option<Vec<Vec<(usize, f64)>>>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
    /// persistent worker pool for the per-node phases
    pool: WorkerPool,
    /// §4.2 telemetry: max Byzantine rows any honest node received in the
    /// last round (the *observed* b̂)
    last_round_byz_max: usize,
    /// per-round digest of the honest population (phase 2 output)
    digest: HonestDigest,
}

impl Trainer {
    /// Build everything: engine, adversary placement, shards, topology,
    /// b̂ resolution (Algorithm 2 when unset).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!("invalid config: {e}"))?;
        let mut cfg = cfg.clone();
        let mut rng = Rng::new(cfg.seed);

        // --- compute engine -------------------------------------------------
        let mut runtime = match cfg.engine {
            EngineKind::Hlo => Some(
                Runtime::open(&cfg.artifacts_dir)
                    .context("HLO engine requires built artifacts")?,
            ),
            EngineKind::Native => None,
        };
        let engine = build_engine(&cfg, runtime.as_mut())?;
        if engine.batch() != cfg.batch {
            log::info!(
                "batch {} overridden to {} (baked into HLO artifact)",
                cfg.batch,
                engine.batch()
            );
            cfg.batch = engine.batch();
        }
        let d = engine.d();

        // --- resolve b̂ (Algorithm 2 / §6.1) --------------------------------
        // b̂ resolution uses Appendix B Remark 2's "more precise" method:
        // the exact 90%-quantile of max_{i,t} b_i^t from the closed-form
        // hypergeometric CDF (deterministic; Algorithm 2's simulation is
        // available via `rpel select` / sampling::select_params).
        const BHAT_CONFIDENCE: f64 = 0.9;
        let bhat = match (cfg.bhat, &cfg.topology) {
            (Some(bh), _) => bh,
            (None, _) if cfg.b == 0 => 0,
            // push mode deliberately reuses the pull-mode b̂ (Appendix D:
            // flooding voids the hypergeometric bound — that mismatch IS
            // the ablation)
            (None, Topology::Epidemic { s }) | (None, Topology::EpidemicPush { s }) => {
                crate::sampling::selector::select_bhat_exact(
                    cfg.n as u64,
                    cfg.b as u64,
                    cfg.rounds as u64,
                    *s as u64,
                    BHAT_CONFIDENCE,
                ) as usize
            }
            (None, Topology::FixedGraph { .. }) => {
                // Remark C.2: under random placement use the same b̂ an
                // epidemic run of equal budget would use
                let s_equiv = (2 * cfg.messages_per_round() / cfg.n).clamp(1, cfg.n - 1);
                crate::sampling::selector::select_bhat_exact(
                    cfg.n as u64,
                    cfg.b as u64,
                    cfg.rounds as u64,
                    s_equiv as u64,
                    BHAT_CONFIDENCE,
                ) as usize
            }
        };
        if let Topology::Epidemic { s } = cfg.topology {
            if cfg.b > 0 && 2 * bhat >= s + 1 {
                bail!(
                    "effective adversarial fraction {bhat}/{} ≥ 1/2 — robust \
                     aggregation breaks down (paper §6.2); increase s or reduce b",
                    s + 1
                );
            }
        }

        // --- aggregation backend -------------------------------------------
        let agg = match (&cfg.topology, cfg.rule) {
            (Topology::Epidemic { s }, RuleChoice::Epidemic(kind)) => {
                // DoS shrinks receive sets; the fixed-shape Pallas
                // executable cannot apply, so fall back to the native rule
                let want_hlo = cfg.engine == EngineKind::Hlo
                    && kind == crate::aggregation::RuleKind::NnmCwtm
                    && cfg.attack != crate::attacks::AttackKind::Dos;
                if want_hlo {
                    let rt = runtime.as_mut().unwrap();
                    match rt.aggregate_exec(&cfg.arch, s + 1, bhat) {
                        Ok(exec) => AggBackend::Hlo(exec),
                        Err(e) => {
                            log::warn!(
                                "no Pallas aggregate artifact (m={}, b̂={bhat}): {e}; \
                                 falling back to native rule",
                                s + 1
                            );
                            AggBackend::Native(kind.build(bhat))
                        }
                    }
                } else {
                    AggBackend::Native(kind.build(bhat))
                }
            }
            (Topology::EpidemicPush { .. }, RuleChoice::Epidemic(kind)) => {
                AggBackend::Native(kind.build(bhat))
            }
            (Topology::FixedGraph { .. }, RuleChoice::Gossip(kind)) => {
                AggBackend::Gossip(kind.build(bhat))
            }
            _ => bail!("rule/topology mismatch (config validation bug)"),
        };

        // --- adversary placement (uniform random, Remark C.1) ---------------
        let mut byz = vec![false; cfg.n];
        for id in rng.fork(0xB12).sample_distinct(cfg.n, cfg.b) {
            byz[id] = true;
        }
        let attack = if cfg.b > 0 { cfg.attack.build() } else { None };

        // --- data ------------------------------------------------------------
        let task = cfg.task.spec().instantiate(cfg.seed);
        let mut data_rng = rng.fork(0xDA7A);
        let shard_labels = partition_dirichlet(
            cfg.n,
            task.spec.classes,
            cfg.samples_per_node,
            cfg.alpha,
            &mut data_rng,
        );
        let test_n = if engine.eval_n() > 0 {
            if engine.eval_n() != cfg.test_samples {
                log::info!(
                    "test_samples {} overridden to {} (baked into HLO eval artifact)",
                    cfg.test_samples,
                    engine.eval_n()
                );
            }
            engine.eval_n()
        } else {
            cfg.test_samples
        };
        let test = task.sample_uniform(test_n, &mut data_rng);

        // --- honest node states ----------------------------------------------
        let mut nodes = Vec::with_capacity(cfg.honest());
        let mut node_of = vec![usize::MAX; cfg.n];
        for id in 0..cfg.n {
            if byz[id] {
                continue;
            }
            let labels = &shard_labels[id];
            let data = task.sample_labels(labels, &mut data_rng);
            let data_shard = crate::data::Shard::new(data, rng.fork(0x5AD + id as u64));
            node_of[id] = nodes.len();
            let params = engine.init_params(cfg.seed as i32)?;
            nodes.push(NodeState {
                id,
                params,
                momentum: vec![0.0f32; d],
                shard: data_shard,
            });
        }

        // --- topology ----------------------------------------------------------
        let (sampler, push_s, gossip_rows) = match cfg.topology {
            Topology::Epidemic { s } => (Some(PullSampler::new(cfg.n, s)), None, None),
            Topology::EpidemicPush { s } => (None, Some(s), None),
            Topology::FixedGraph { edges } => {
                let g = Graph::random_connected(cfg.n, edges, &mut rng.fork(0x6AF));
                (None, None, Some(g.metropolis_weights()))
            }
        };

        // --- shard partition: contiguous honest-index ranges -----------------
        let h = nodes.len();
        let shard_count = cfg.shards.clamp(1, h.max(1));
        let mut shards = Vec::with_capacity(shard_count);
        let base = h / shard_count;
        let extra = h % shard_count;
        let mut node_iter = nodes.into_iter();
        let mut start = 0usize;
        for k in 0..shard_count {
            let len = base + usize::from(k < extra);
            let shard_nodes: Vec<NodeState> = node_iter.by_ref().take(len).collect();
            shards.push(NodeShard::new(start, shard_nodes, d));
            start += len;
        }

        let pool = WorkerPool::new(cfg.threads);
        log::info!(
            "trainer '{}': n={} b={} b̂={bhat} rule={} engine={} d={d} shards={} threads={}",
            cfg.name,
            cfg.n,
            cfg.b,
            agg.name(),
            engine.name(),
            shards.len(),
            pool.threads()
        );
        Ok(Trainer {
            bhat,
            byz,
            node_of,
            sampler,
            push_s,
            gossip_rows,
            test_x: test.x,
            test_y: test.y,
            pool,
            last_round_byz_max: 0,
            digest: HonestDigest::new(d),
            shards,
            h,
            engine,
            agg,
            attack,
            cfg,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Which aggregation backend actually runs (for logs/tests).
    pub fn aggregation_name(&self) -> &'static str {
        self.agg.name()
    }

    /// Number of honest nodes.
    pub fn honest_count(&self) -> usize {
        self.h
    }

    /// Resolved worker count for the per-node phases.
    pub fn thread_count(&self) -> usize {
        self.pool.threads()
    }

    /// Resolved shard count (≥ 1, ≤ honest count).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Run the full training; returns the metric history.
    pub fn run(&mut self) -> Result<History> {
        let t0 = Instant::now();
        let mut hist = History::new(&self.cfg.name, self.cfg.messages_per_round());
        for round in 0..self.cfg.rounds {
            let loss = self.round(round)?;
            hist.train_loss.push(loss);
            hist.observed_byz_max.push(self.last_round_byz_max);
            hist.total_messages += self.cfg.messages_per_round();
            let last = round + 1 == self.cfg.rounds;
            if last || (round + 1) % self.cfg.eval_every == 0 {
                hist.evals.push(self.evaluate(round + 1)?);
            }
        }
        hist.wall_secs = t0.elapsed().as_secs_f64();
        Ok(hist)
    }

    /// Execute one synchronous round; returns the mean honest train loss.
    ///
    /// Phases 1 and 4 run data-parallel over all shards' nodes (see the
    /// module docs); every phase is bit-deterministic for any
    /// (shards × threads) grid point.
    pub fn round(&mut self, round: usize) -> Result<f64> {
        // 1. local half-steps (Algorithm 1 lines 3–6)
        let loss = self.phase_half_steps(round)?;
        // 2. shards publish their round digests; fold into the global
        // honest digest the omniscient adversary conditions on
        self.phase_attack_context();
        // push mode: honest senders scatter to s recipients; Byzantine
        // senders flood every honest node (the Appendix-D failure mode)
        let push_received = self.phase_push_routes(round);
        // 3.+4. pull, attack, aggregate — against the immutable published
        // snapshots (synchronous model)
        self.phase_pull_craft_aggregate(round, push_received.as_ref())?;
        // 5. synchronous swap, shard by shard
        for shard in self.shards.iter_mut() {
            shard.commit();
        }
        Ok(loss)
    }

    /// Phase 1: every honest node's local train step, in parallel across
    /// all shards.
    fn phase_half_steps(&mut self, round: usize) -> Result<f64> {
        let lr = self.cfg.lr_at(round);
        let beta = self.cfg.momentum;
        let wd = self.cfg.weight_decay;
        let k = self.engine.local_steps();
        let batch = self.engine.batch();
        let h = self.h;
        let engine: &dyn ComputeEngine = self.engine.as_ref();
        let pool = &self.pool;

        let mut jobs: Vec<HalfStepJob<'_>> = Vec::with_capacity(h);
        for shard in self.shards.iter_mut() {
            for ((node, half), loss) in shard
                .nodes
                .iter_mut()
                .zip(shard.halves.iter_mut())
                .zip(shard.losses.iter_mut())
            {
                jobs.push(HalfStepJob { node, half, loss });
            }
        }
        pool.try_for_each(&mut jobs, |_, job| {
            job.half.copy_from_slice(&job.node.params);
            // batch draws come from the node's own shard stream — already
            // independent of scheduling order
            let b = job.node.shard.next_batches(k, batch);
            *job.loss = engine.train_step(
                job.half,
                &mut job.node.momentum,
                &b.x,
                &b.y,
                lr,
                beta,
                wd,
            )? as f64;
            Ok(())
        })?;
        drop(jobs);
        // serial fold in ascending honest order: identical for every
        // (shards × threads) grid point
        let sum: f64 = self.shards.iter().flat_map(|s| s.losses.iter()).sum();
        Ok(sum / h as f64)
    }

    /// Phase 2: fold every shard's published [`shard::RoundDigest`] into
    /// the global honest digest, in ascending honest-node order (per-shard
    /// f64 partial sums would make the result depend on the shard
    /// grouping — see `shard.rs`). Skipped entirely when nothing will read
    /// it (no Byzantine nodes, or DoS where nothing is crafted); the
    /// O(h·d) variance pass runs only for ALIE, its sole consumer.
    fn phase_attack_context(&mut self) {
        use crate::attacks::AttackKind;
        if self.cfg.b == 0 || self.cfg.attack == AttackKind::Dos {
            return;
        }
        let mut halves: Vec<&[f32]> = Vec::with_capacity(self.h);
        let mut prevs: Vec<&[f32]> = Vec::with_capacity(self.h);
        for shard in &self.shards {
            let published = shard.publish();
            debug_assert_eq!(published.start, halves.len());
            for row in published.halves {
                halves.push(row);
            }
            for node in published.nodes {
                prevs.push(&node.params);
            }
        }
        let with_std = self.cfg.attack == AttackKind::Alie;
        self.digest.recompute(&halves, &prevs, with_std);
    }

    /// Phase 3 (push-mode ablation only): sender → recipient routes. The
    /// scatter for sender `id` comes from the `(seed, round, id, PUSH)`
    /// stream, so routes are reproducible regardless of iteration order.
    fn phase_push_routes(&self, round: usize) -> Option<Vec<Vec<usize>>> {
        let s = self.push_s?;
        let mut recv: Vec<Vec<usize>> = vec![Vec::new(); self.h];
        for shard in &self.shards {
            for node in &shard.nodes {
                let id = node.id;
                let mut rng =
                    Rng::stream(self.cfg.seed, round as u64, id as u64, stream_tag::PUSH);
                for dest in rng.sample_distinct_excluding(self.cfg.n, s, id) {
                    if !self.byz[dest] {
                        recv[self.node_of[dest]].push(id);
                    }
                    // pushes to Byzantine recipients are wasted messages
                }
            }
        }
        Some(recv)
    }

    /// Phase 4: per victim — pull `S_i^t`, craft the malicious rows
    /// against the digest, robustly aggregate. Parallel over victims
    /// across all shards; crafting scratch lives in per-worker
    /// thread-locals that the persistent pool retains across rounds.
    fn phase_pull_craft_aggregate(
        &mut self,
        round: usize,
        push_received: Option<&Vec<Vec<usize>>>,
    ) -> Result<()> {
        let h = self.h;
        let d = self.digest.mean.len();
        let dos = self.cfg.attack == crate::attacks::AttackKind::Dos;
        let seed = self.cfg.seed;
        let n = self.cfg.n;
        let b = self.cfg.b;
        // worst-case malicious rows per victim is b in every topology
        // (pull sets and graph neighborhoods are duplicate-free, and a
        // flooding push round delivers each Byzantine node once)
        let byz_rows_cap = b;

        // immutable round snapshot shared by all workers, assembled from
        // the shards' published views in ascending honest order — plus the
        // per-victim output slots (disjoint mutable borrows)
        let mut jobs: Vec<AggJob<'_>> = Vec::with_capacity(h);
        let mut all_halves: Vec<&[f32]> = Vec::with_capacity(h);
        let mut all_prevs: Vec<&[f32]> = Vec::with_capacity(h);
        let mut ids: Vec<usize> = Vec::with_capacity(h);
        for shard in self.shards.iter_mut() {
            let (nodes, halves, next, byz_seen) = shard.split_aggregate();
            for node in nodes {
                ids.push(node.id);
                all_prevs.push(&node.params);
            }
            for row in halves {
                all_halves.push(row);
            }
            for (out, seen) in next.iter_mut().zip(byz_seen.iter_mut()) {
                jobs.push(AggJob {
                    out,
                    byz_seen: seen,
                });
            }
        }
        let all_halves = &all_halves;
        let all_prevs = &all_prevs;
        let ids = &ids;

        let byz = &self.byz;
        let node_of = &self.node_of;
        let sampler = &self.sampler;
        let gossip_rows = &self.gossip_rows;
        let attack = &self.attack;
        let agg = &self.agg;
        let digest = &self.digest;
        let pool = &self.pool;

        pool.try_for_each(&mut jobs, |i, job| {
            let id = ids[i];
            // pull set from the (seed, round, id, PULL) stream; in push
            // mode, borrow the precomputed receive row (no clone)
            let pulled: Vec<usize>;
            let peers: &[usize] = match (sampler, push_received, gossip_rows) {
                (Some(sampler), _, _) => {
                    pulled = sampler.sample_at(seed, round, id);
                    &pulled
                }
                (None, Some(recv), _) => &recv[i],
                (None, None, Some(rows)) => {
                    pulled = rows[id]
                        .iter()
                        .map(|&(j, _)| j)
                        .filter(|&j| j != id)
                        .collect();
                    &pulled
                }
                _ => unreachable!(),
            };

            // split into honest refs and byzantine slots
            let mut honest_rows: Vec<&[f32]> = Vec::with_capacity(peers.len());
            let mut byz_count = 0usize;
            for &p in peers {
                if byz[p] {
                    byz_count += 1;
                } else {
                    honest_rows.push(all_halves[node_of[p]]);
                }
            }
            if push_received.is_some() && b > 0 && !dos {
                // flooding: every Byzantine node reaches every honest node
                byz_count = b;
            }
            if dos {
                byz_count = 0; // withheld responses simply never arrive
            }
            *job.byz_seen = byz_count;

            // craft per-victim malicious models into the worker's retained
            // scratch rows
            let mut byz_buf = CRAFT_ROWS.with(|cell| cell.take());
            if byz_rows_cap > 0
                && (byz_buf.len() < byz_rows_cap || byz_buf[0].len() != d)
            {
                byz_buf = vec![vec![0.0f32; d]; byz_rows_cap];
            }
            if byz_count > 0 {
                if let Some(attack) = attack {
                    let ctx = AttackContext {
                        victim_half: all_halves[i],
                        victim_prev: all_prevs[i],
                        honest_received: &honest_rows,
                        digest,
                        n,
                        b,
                    };
                    attack.craft(&ctx, &mut byz_buf[..byz_count]);
                } else {
                    // b > 0 but attack "none": byzantine nodes behave as
                    // silent crashers; model them as sending the honest
                    // mean (benign)
                    for row in &mut byz_buf[..byz_count] {
                        for (o, &mu) in row.iter_mut().zip(digest.mean.iter()) {
                            *o = mu as f32;
                        }
                    }
                }
            }

            match agg {
                AggBackend::Native(rule) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    rows.push(all_halves[i]);
                    rows.extend_from_slice(&honest_rows);
                    for rbuf in &byz_buf[..byz_count] {
                        rows.push(rbuf);
                    }
                    if rows.len() < rule.min_inputs() {
                        // too few responses to aggregate robustly (push /
                        // DoS rounds): keep the local half-step
                        job.out.copy_from_slice(all_halves[i]);
                    } else {
                        rule.aggregate(&rows, job.out);
                    }
                }
                AggBackend::Hlo(exec) => {
                    let mut rows: Vec<&[f32]> = Vec::with_capacity(1 + peers.len());
                    rows.push(all_halves[i]);
                    rows.extend_from_slice(&honest_rows);
                    for rbuf in &byz_buf[..byz_count] {
                        rows.push(rbuf);
                    }
                    let out = exec.run(&rows);
                    job.out.copy_from_slice(&out?);
                }
                AggBackend::Gossip(rule) => {
                    // gossip needs (model, weight) pairs in graph order
                    let rows = gossip_rows.as_ref().unwrap();
                    let mut neigh: Vec<(&[f32], f64)> = Vec::with_capacity(peers.len());
                    let mut byz_used = 0usize;
                    for &(j, w) in &rows[id] {
                        if j == id {
                            continue;
                        }
                        if byz[j] {
                            // DoS: the withheld model simply never
                            // arrives — drop the edge this round
                            if dos {
                                continue;
                            }
                            neigh.push((&byz_buf[byz_used], w));
                            byz_used += 1;
                        } else {
                            neigh.push((all_halves[node_of[j]], w));
                        }
                    }
                    rule.aggregate(all_halves[i], &neigh, job.out);
                }
            }
            CRAFT_ROWS.with(|cell| cell.replace(byz_buf));
            Ok(())
        })?;
        drop(jobs);
        // serial index-order max: identical for every grid point
        self.last_round_byz_max = self
            .shards
            .iter()
            .flat_map(|s| s.byz_seen.iter().copied())
            .max()
            .unwrap_or(0);
        Ok(())
    }

    /// Evaluate every honest node on the shared test set (parallel over
    /// nodes; read-only against the committed models).
    pub fn evaluate(&self, round: usize) -> Result<EvalPoint> {
        let n_test = self.test_y.len() as f64;
        let h = self.h;
        let engine: &dyn ComputeEngine = self.engine.as_ref();
        let params: Vec<&[f32]> = self
            .shards
            .iter()
            .flat_map(|s| s.nodes.iter().map(|node| node.params.as_slice()))
            .collect();
        let params = &params;
        let test_x = &self.test_x;
        let test_y = &self.test_y;
        let mut accs = vec![0.0f64; h];
        let mut losses = vec![0.0f64; h];
        let mut jobs: Vec<(&mut f64, &mut f64)> =
            accs.iter_mut().zip(losses.iter_mut()).collect();
        self.pool.try_for_each(&mut jobs, |i, job| {
            let (correct, loss_sum) = engine.evaluate(params[i], test_x, test_y)?;
            *job.0 = correct / n_test;
            *job.1 = loss_sum / n_test;
            Ok(())
        })?;
        drop(jobs);
        Ok(EvalPoint {
            round,
            avg_acc: crate::util::stats::mean(&accs),
            worst_acc: crate::util::stats::min(&accs),
            avg_loss: crate::util::stats::mean(&losses),
        })
    }

    /// Immutable view of one honest node's parameters (tests).
    pub fn params_of(&self, honest_idx: usize) -> &[f32] {
        for shard in &self.shards {
            if honest_idx < shard.start + shard.len() {
                return &shard.nodes[honest_idx - shard.start].params;
            }
        }
        panic!("honest index {honest_idx} out of range ({})", self.h);
    }

    /// Global ids of the Byzantine nodes (tests/diagnostics).
    pub fn byzantine_ids(&self) -> Vec<usize> {
        (0..self.cfg.n).filter(|&i| self.byz[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::RuleKind;
    use crate::attacks::AttackKind;
    use crate::config::presets;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = presets::quickstart_config();
        cfg.rounds = 12;
        cfg.eval_every = 6;
        cfg
    }

    #[test]
    fn builds_and_places_adversaries() {
        let cfg = quick_cfg();
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.honest_count(), cfg.n - cfg.b);
        assert_eq!(t.byzantine_ids().len(), cfg.b);
        assert_eq!(t.bhat, 2);
        assert!(t.thread_count() >= 1);
        assert_eq!(t.shard_count(), 1);
    }

    #[test]
    fn shard_partition_is_contiguous_and_covers_all_nodes() {
        let mut cfg = quick_cfg();
        cfg.shards = 3;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.shard_count(), 3);
        let mut covered = 0usize;
        let mut next_start = 0usize;
        for shard in &t.shards {
            assert_eq!(shard.start, next_start, "contiguous ranges");
            next_start += shard.len();
            covered += shard.len();
        }
        assert_eq!(covered, t.honest_count());
        // every honest index resolves to some shard-owned params
        for i in 0..t.honest_count() {
            assert!(!t.params_of(i).is_empty());
        }
    }

    #[test]
    fn oversubscribed_shards_clamp_to_honest_count() {
        let mut cfg = quick_cfg();
        cfg.shards = 1000;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.shard_count(), t.honest_count());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let h1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let h2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(h1.train_loss, h2.train_loss);
        assert_eq!(h1.final_avg_accuracy(), h2.final_avg_accuracy());
        let mut cfg3 = cfg;
        cfg3.seed = 99;
        let h3 = Trainer::from_config(&cfg3).unwrap().run().unwrap();
        assert_ne!(h1.train_loss, h3.train_loss);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut serial_cfg = quick_cfg();
        serial_cfg.threads = 1;
        let serial = Trainer::from_config(&serial_cfg).unwrap().run().unwrap();
        for threads in [2usize, 3, 8] {
            let mut cfg = quick_cfg();
            cfg.threads = threads;
            let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
            assert_eq!(serial.train_loss, hist.train_loss, "threads={threads}");
            assert_eq!(
                serial.observed_byz_max, hist.observed_byz_max,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn no_attack_training_learns() {
        let mut cfg = quick_cfg();
        cfg.b = 0;
        cfg.attack = AttackKind::None;
        cfg.rounds = 40;
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert!(
            hist.final_avg_accuracy() > 0.7,
            "acc={}",
            hist.final_avg_accuracy()
        );
        // loss decreased
        assert!(hist.final_train_loss() < hist.train_loss[0] * 0.8);
    }

    #[test]
    fn robust_rule_survives_sign_flip() {
        let mut cfg = quick_cfg();
        cfg.rounds = 40;
        cfg.b = 2; // 25% Byzantine: scaled SF reverses a plain average
        cfg.attack = AttackKind::SignFlip;
        let robust = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let mut mean_cfg = cfg.clone();
        mean_cfg.rule = RuleChoice::Epidemic(RuleKind::Mean);
        mean_cfg.name = "quickstart/mean".into();
        let mean = Trainer::from_config(&mean_cfg).unwrap().run().unwrap();
        assert!(
            robust.final_avg_accuracy() > mean.final_avg_accuracy() + 0.15,
            "robust={} mean={}",
            robust.final_avg_accuracy(),
            mean.final_avg_accuracy()
        );
    }

    #[test]
    fn message_accounting() {
        let cfg = quick_cfg();
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(hist.messages_per_round, cfg.n * 7);
        assert_eq!(hist.total_messages, cfg.n * 7 * cfg.rounds);
    }

    #[test]
    fn eval_cadence_includes_final_round() {
        let mut cfg = quick_cfg();
        cfg.rounds = 13; // not divisible by eval_every=6
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let rounds: Vec<usize> = hist.evals.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![6, 12, 13]);
    }

    #[test]
    fn fixed_graph_topology_runs() {
        let mut cfg = quick_cfg();
        cfg.topology = Topology::FixedGraph { edges: 16 };
        cfg.rule = RuleChoice::Gossip(crate::aggregation::gossip::GossipRuleKind::CsPlus);
        cfg.rounds = 10;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let hist = t.run().unwrap();
        assert_eq!(hist.train_loss.len(), 10);
        assert_eq!(hist.messages_per_round, 32);
    }

    #[test]
    fn breakdown_detected_at_construction() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        cfg.n = 10;
        cfg.b = 4; // 40% byzantine, s=7: b̂ will hit 4 of 8 = 1/2
        cfg.topology = Topology::Epidemic { s: 7 };
        let err = match Trainer::from_config(&cfg) {
            Ok(_) => panic!("breakdown setting should fail construction"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("1/2"), "{err}");
    }

    #[test]
    fn algorithm2_resolves_bhat_when_unset() {
        let mut cfg = quick_cfg();
        cfg.bhat = None;
        let t = Trainer::from_config(&cfg).unwrap();
        // 1 byzantine among 8, s=7 all-to-all: b̂ must be exactly 1
        assert_eq!(t.bhat, 1);
    }

    #[test]
    fn params_stay_finite_under_attacks() {
        for attack in AttackKind::panel() {
            let mut cfg = quick_cfg();
            cfg.attack = attack;
            cfg.rounds = 15;
            let mut t = Trainer::from_config(&cfg).unwrap();
            t.run().unwrap();
            for i in 0..t.honest_count() {
                assert!(
                    crate::util::vecmath::all_finite(t.params_of(i)),
                    "{:?} produced non-finite params",
                    attack
                );
            }
        }
    }
}
