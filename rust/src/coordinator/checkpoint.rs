//! Durable round checkpoints: crash-recoverable snapshots of a round
//! boundary, written atomically every `recovery.checkpoint_every`
//! rounds (`--checkpoint-dir`), resumed with `rpel train --resume`.
//!
//! A checkpoint captures everything the trainer needs to continue a run
//! at a round boundary such that the resumed trajectory is **bit-for-bit
//! identical** to the straight-through run on every (transport × procs ×
//! shards × threads × compression × participation) grid point: the
//! committed-params mirror, per-node momentum, the async carried rows,
//! the wire codec's delta reference, the virtual-clock state, and the
//! metric history so far. Data-shard cursors and RNG positions are
//! deliberately NOT stored — they are pure functions of
//! `(config, completed-round count)` and the resume path fast-forwards
//! them (see `NodeShard::install_resume` / `VirtualShard::install_resume`).
//!
//! # File format (`checkpoint.bin`, version 1)
//!
//! ```text
//! offset  size  field
//! 0       8     magic "RPELCKPT"
//! 8       4     version      u32 LE  (this build reads 1)
//! 12      8     payload_len  u64 LE
//! 20      8     checksum     u64 LE  (FNV-1a-64 over the payload bytes)
//! 28      …     payload      (wire Writer encoding, little-endian):
//!                 config      len-prefixed TOML string (the full
//!                             experiment config — resume rebuilds the
//!                             identical world from it)
//!                 round       u64   completed rounds (boundary)
//!                 h           u32   honest count
//!                 d           u32   model dimension
//!                 wire_ref    f32-row block, exactly 1 row of width d
//!                 params      f32-row block, h rows of width d
//!                 momentum    f32-row block, h rows of width d
//!                 carried     sparse f32-row block, h slots of width d
//!                 vclock      u8 presence; if 1: u64s down_until,
//!                             u64s last_fresh (length h each)
//!                 history     History::encode_wire (everything except
//!                             wall_secs, which is reporting-only)
//! ```
//!
//! Writes go to `checkpoint.bin.tmp` and are renamed into place, so a
//! crash mid-write never corrupts the previous checkpoint. Reads verify
//! magic, version, length and checksum before touching the payload, and
//! every decode failure surfaces as a named error — a truncated or
//! bit-flipped file is reported, never misinterpreted. All row decodes
//! go through the `crate::wire` reader, whose allocations are bounded
//! by checked size math against the actual byte count present.

use crate::config::{file as config_file, ExperimentConfig};
use crate::metrics::History;
use crate::wire::{Reader, Writer};
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// File name inside the checkpoint directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.bin";
/// Format version this build writes and reads.
pub const CHECKPOINT_VERSION: u32 = 1;

const MAGIC: &[u8; 8] = b"RPELCKPT";
const HEADER_LEN: usize = 8 + 4 + 8 + 8;

/// The trainer's state at a round boundary: exactly what must survive a
/// crash for the continuation to be bit-identical. `round` counts
/// completed rounds (the boundary index); all row vectors are in
/// ascending honest order.
#[derive(Clone, Debug, PartialEq)]
pub struct BoundaryState {
    /// completed rounds (resume re-enters the loop at this round index)
    pub round: u64,
    /// the row codec's delta reference for the coming round
    pub wire_ref: Vec<f32>,
    /// committed params mirror, h rows
    pub params: Vec<Vec<f32>>,
    /// per-node momentum, h rows (zeros for never-active nodes)
    pub momentum: Vec<Vec<f32>>,
    /// async engine: last fresh served row per node
    pub carried: Vec<Option<Vec<f32>>>,
    /// virtual clock `(down_until, last_fresh)` (None ⇒ synchronous run)
    pub vclock: Option<(Vec<u64>, Vec<u64>)>,
}

/// A decoded checkpoint: the embedded config, the boundary state, and
/// the metric history up to the boundary.
pub struct ResumeState {
    pub cfg: ExperimentConfig,
    pub state: BoundaryState,
    pub hist: History,
}

/// FNV-1a 64-bit — tiny, dependency-free, and plenty for corruption
/// detection (this is an integrity check, not an authenticity one).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Encode a complete checkpoint file (header + payload) to bytes.
pub fn encode_checkpoint(cfg_toml: &str, state: &BoundaryState, hist: &History) -> Vec<u8> {
    let h = state.params.len();
    let d = state.wire_ref.len();
    let mut w = Writer::new();
    w.put_str(cfg_toml);
    w.put_u64(state.round);
    w.put_u32(h as u32);
    w.put_u32(d as u32);
    w.put_f32_rows(&[state.wire_ref.as_slice()]);
    w.put_f32_rows(&state.params);
    w.put_f32_rows(&state.momentum);
    w.put_opt_f32_rows(&state.carried);
    match &state.vclock {
        Some((down_until, last_fresh)) => {
            w.put_u8(1);
            w.put_u64s(down_until);
            w.put_u64s(last_fresh);
        }
        None => w.put_u8(0),
    }
    hist.encode_wire(&mut w);
    let payload = w.into_bytes();
    let mut out = Vec::with_capacity(HEADER_LEN.saturating_add(payload.len()));
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&CHECKPOINT_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Decode and fully validate a checkpoint file. Every failure mode —
/// truncation, bit flips, a different format version, shape mismatches
/// between the embedded config and the state rows — is a named error.
pub fn decode_checkpoint(bytes: &[u8]) -> Result<ResumeState> {
    ensure!(
        bytes.len() >= HEADER_LEN,
        "checkpoint: file too short for the {HEADER_LEN}-byte header ({} bytes)",
        bytes.len()
    );
    ensure!(
        &bytes[..8] == MAGIC,
        "checkpoint: bad magic (not an RPEL checkpoint file)"
    );
    let version = le_u32(&bytes[8..12]);
    ensure!(
        version == CHECKPOINT_VERSION,
        "checkpoint: unsupported format version {version} (this build reads {CHECKPOINT_VERSION})"
    );
    let payload_len = le_u64(&bytes[12..20]);
    let stored_sum = le_u64(&bytes[20..28]);
    let body = &bytes[HEADER_LEN..];
    ensure!(
        payload_len == body.len() as u64,
        "checkpoint: payload length {payload_len} does not match the {} bytes after the \
         header — truncated or corrupt file",
        body.len()
    );
    let got_sum = fnv1a64(body);
    ensure!(
        got_sum == stored_sum,
        "checkpoint: checksum mismatch (stored {stored_sum:#018x}, computed {got_sum:#018x}) \
         — truncated or corrupt file"
    );

    let mut r = Reader::new(body);
    let toml = r.string().context("checkpoint: malformed embedded config")?;
    let cfg = config_file::from_toml_str(&toml)
        .map_err(|e| anyhow::anyhow!("checkpoint: embedded config does not parse: {e}"))?;
    let round = r.u64().context("checkpoint: malformed round counter")?;
    let h = r.u32().context("checkpoint: malformed honest count")? as usize;
    let d = r.u32().context("checkpoint: malformed model dimension")? as usize;
    ensure!(
        h == cfg.honest(),
        "checkpoint: state holds {h} honest node(s) but the embedded config has {}",
        cfg.honest()
    );
    ensure!(
        round <= cfg.rounds as u64,
        "checkpoint: boundary round {round} exceeds the embedded config's {} round(s)",
        cfg.rounds
    );
    let mut wire_ref_rows = r
        .f32_rows()
        .context("checkpoint: malformed wire reference")?;
    ensure!(
        wire_ref_rows.len() == 1 && wire_ref_rows[0].len() == d,
        "checkpoint: wire reference block holds {} row(s) (expected 1 of width {d})",
        wire_ref_rows.len()
    );
    let wire_ref = match wire_ref_rows.pop() {
        Some(row) => row,
        None => bail!("checkpoint: wire reference block is empty"),
    };
    let params = r.f32_rows().context("checkpoint: malformed params rows")?;
    let momentum = r.f32_rows().context("checkpoint: malformed momentum rows")?;
    let carried = r
        .opt_f32_rows()
        .context("checkpoint: malformed carried rows")?;
    for (what, n) in [("params", params.len()), ("momentum", momentum.len()), ("carried", carried.len())] {
        ensure!(
            n == h,
            "checkpoint: {what} block holds {n} row(s), expected {h}"
        );
    }
    for row in params.iter().chain(momentum.iter()) {
        ensure!(
            row.len() == d,
            "checkpoint: state row width {} does not match model dimension {d}",
            row.len()
        );
    }
    for row in carried.iter().flatten() {
        ensure!(
            row.len() == d,
            "checkpoint: carried row width {} does not match model dimension {d}",
            row.len()
        );
    }
    let vclock = match r.u8().context("checkpoint: malformed vclock presence flag")? {
        0 => None,
        1 => {
            let down_until = r.u64s().context("checkpoint: malformed vclock down_until")?;
            let last_fresh = r.u64s().context("checkpoint: malformed vclock last_fresh")?;
            ensure!(
                down_until.len() == h && last_fresh.len() == h,
                "checkpoint: vclock state holds {}/{} entries, expected {h} each",
                down_until.len(),
                last_fresh.len()
            );
            Some((down_until, last_fresh))
        }
        other => bail!("checkpoint: vclock presence flag is {other} (expected 0 or 1)"),
    };
    let hist = History::decode_wire(&mut r).context("checkpoint: malformed history")?;
    r.finish().context("checkpoint: trailing bytes after payload")?;
    Ok(ResumeState {
        cfg,
        state: BoundaryState {
            round,
            wire_ref,
            params,
            momentum,
            carried,
            vclock,
        },
        hist,
    })
}

/// Path of the checkpoint file inside `dir`.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// Write a checkpoint atomically (`checkpoint.bin.tmp` + rename) and
/// return the file size in bytes. A crash at any point leaves either
/// the previous checkpoint or the new one — never a torn file.
pub fn write_checkpoint(
    dir: &Path,
    cfg_toml: &str,
    state: &BoundaryState,
    hist: &History,
) -> Result<u64> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("checkpoint: creating directory {}", dir.display()))?;
    let bytes = encode_checkpoint(cfg_toml, state, hist);
    let tmp = dir.join("checkpoint.bin.tmp");
    let path = checkpoint_path(dir);
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("checkpoint: writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path).with_context(|| {
        format!(
            "checkpoint: renaming {} into place as {}",
            tmp.display(),
            path.display()
        )
    })?;
    Ok(bytes.len() as u64)
}

/// Read and validate the checkpoint in `dir`.
pub fn read_checkpoint(dir: &Path) -> Result<ResumeState> {
    let path = checkpoint_path(dir);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("checkpoint: reading {}", path.display()))?;
    decode_checkpoint(&bytes).with_context(|| format!("checkpoint: loading {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state_for(h: usize, d: usize) -> BoundaryState {
        BoundaryState {
            round: 3,
            wire_ref: (0..d).map(|j| j as f32 * 0.5 - 1.0).collect(),
            params: (0..h).map(|i| vec![i as f32 + 0.25; d]).collect(),
            momentum: (0..h).map(|i| vec![-(i as f32) * 0.125; d]).collect(),
            carried: (0..h)
                .map(|i| (i % 2 == 1).then(|| vec![9.0 - i as f32; d]))
                .collect(),
            vclock: Some(((0..h as u64).collect(), (0..h as u64).rev().collect())),
        }
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = crate::config::presets::quickstart_config();
        cfg.rounds = 12;
        cfg
    }

    #[test]
    fn roundtrips_bit_for_bit() {
        let cfg = tiny_cfg();
        let toml = config_file::to_toml_str(&cfg);
        let state = state_for(cfg.honest(), 4);
        let mut hist = History::new("ckpt/test", 42);
        hist.train_loss = vec![1.5, 1.25, 1.0];
        hist.checkpoint_bytes_per_round = vec![0, 0, 4096];
        let bytes = encode_checkpoint(&toml, &state, &hist);
        let back = decode_checkpoint(&bytes).unwrap();
        assert_eq!(back.state, state);
        assert_eq!(back.hist, hist);
        assert_eq!(back.cfg, cfg);
        // encoding is deterministic: same inputs, same bytes
        assert_eq!(bytes, encode_checkpoint(&toml, &state, &hist));
    }

    #[test]
    fn rejects_shape_mismatch_against_embedded_config() {
        let cfg = tiny_cfg();
        let toml = config_file::to_toml_str(&cfg);
        let wrong = state_for(2, 4); // quickstart honest() is 7
        let bytes = encode_checkpoint(&toml, &wrong, &History::new("x", 1));
        let err = format!("{:#}", decode_checkpoint(&bytes).unwrap_err());
        assert!(err.contains("2 honest node(s)"), "{err}");
    }

    #[test]
    fn header_faults_are_named() {
        let cfg = tiny_cfg();
        let toml = config_file::to_toml_str(&cfg);
        let bytes =
            encode_checkpoint(&toml, &state_for(cfg.honest(), 4), &History::new("x", 1));
        // short file
        let err = format!("{:#}", decode_checkpoint(&bytes[..10]).unwrap_err());
        assert!(err.contains("too short"), "{err}");
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        let err = format!("{:#}", decode_checkpoint(&bad).unwrap_err());
        assert!(err.contains("bad magic"), "{err}");
        // wrong version
        let mut bad = bytes.clone();
        bad[8] = 9;
        let err = format!("{:#}", decode_checkpoint(&bad).unwrap_err());
        assert!(err.contains("unsupported format version 9"), "{err}");
        // truncated payload
        let err =
            format!("{:#}", decode_checkpoint(&bytes[..bytes.len() - 1]).unwrap_err());
        assert!(err.contains("does not match"), "{err}");
        // flipped payload bit
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let err = format!("{:#}", decode_checkpoint(&bad).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn atomic_write_and_read_back() {
        let cfg = tiny_cfg();
        let toml = config_file::to_toml_str(&cfg);
        let state = state_for(cfg.honest(), 3);
        let dir = std::env::temp_dir().join(format!("rpel_ckpt_unit_{}", std::process::id()));
        let bytes = write_checkpoint(&dir, &toml, &state, &History::new("x", 1)).unwrap();
        assert!(bytes > HEADER_LEN as u64);
        assert!(!dir.join("checkpoint.bin.tmp").exists(), "tmp renamed away");
        let back = read_checkpoint(&dir).unwrap();
        assert_eq!(back.state, state);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fnv_vectors() {
        // published FNV-1a-64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
