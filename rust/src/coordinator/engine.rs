//! Compute-engine abstraction: the HLO/PJRT production path and its
//! native differential twin behind one interface, so the round loop is
//! engine-agnostic.

use crate::config::{EngineKind, ExperimentConfig};
use crate::model::native::{MlpSpec, TrainHyper};
use crate::runtime::{EvalExec, InitExec, Runtime, TrainExec};
use anyhow::{anyhow, ensure, Context, Result};

/// What the round loop needs from a compute backend.
///
/// All methods take `&self` and every implementation is `Send + Sync`:
/// one engine instance is shared by the parallel round engine's worker
/// threads, which call [`ComputeEngine::train_step`] and
/// [`ComputeEngine::evaluate`] concurrently for disjoint nodes. State that
/// varies per call (gradient scratch, staging buffers) lives on the call
/// stack or behind a lock, never in `&mut self`.
pub trait ComputeEngine: Send + Sync {
    /// Flat parameter count d.
    fn d(&self) -> usize;
    /// Effective batch size per local step (HLO artifacts have it baked).
    fn batch(&self) -> usize;
    /// Local steps per round this engine executes.
    fn local_steps(&self) -> usize;
    /// Eval-set size the engine expects (0 = any).
    fn eval_n(&self) -> usize;
    /// Deterministic parameter init.
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;
    /// One training round's local computation (Algorithm 1 lines 3–6),
    /// updating params/momentum in place; returns the (mean) loss.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        params: &mut Vec<f32>,
        momentum: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        beta: f32,
        wd: f32,
    ) -> Result<f32>;
    /// (#correct, loss_sum) over the eval set.
    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)>;
    fn name(&self) -> &'static str;
}

/// Native Rust MLP engine.
pub struct NativeEngine {
    spec: MlpSpec,
    batch: usize,
    local_steps: usize,
}

impl NativeEngine {
    pub fn new(arch: &str, batch: usize, local_steps: usize) -> Result<Self> {
        let spec = MlpSpec::by_name(arch)
            .ok_or_else(|| anyhow!("native engine has no arch '{arch}'"))?;
        Ok(NativeEngine {
            spec,
            batch,
            local_steps,
        })
    }
}

impl ComputeEngine for NativeEngine {
    fn d(&self) -> usize {
        self.spec.param_count()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn local_steps(&self) -> usize {
        self.local_steps
    }

    fn eval_n(&self) -> usize {
        0
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        Ok(self.spec.init_native(seed as u64))
    }

    fn train_step(
        &self,
        params: &mut Vec<f32>,
        momentum: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        beta: f32,
        wd: f32,
    ) -> Result<f32> {
        let hp = TrainHyper {
            lr,
            beta,
            weight_decay: wd,
        };
        let din = self.spec.din;
        let per = self.batch * din;
        ensure!(
            x.len() == self.local_steps * per && y.len() == self.local_steps * self.batch,
            "batch shape mismatch"
        );
        // per-thread gradient scratch: keeping it off `self` lets worker
        // threads share the engine without locking, and the thread-local
        // avoids re-allocating a d-sized buffer for every node every round
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<f32>> =
                std::cell::RefCell::new(Vec::new());
        }
        let mut scratch = SCRATCH.with(|cell| cell.take());
        let mut total = 0.0f32;
        for k in 0..self.local_steps {
            let xs = &x[k * per..(k + 1) * per];
            let ys = &y[k * self.batch..(k + 1) * self.batch];
            total += self
                .spec
                .train_step(params, momentum, xs, ys, hp, &mut scratch);
        }
        SCRATCH.with(|cell| cell.replace(scratch));
        Ok(total / self.local_steps as f32)
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        Ok(self.spec.evaluate(params, x, y))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// HLO/PJRT engine: executes the AOT-compiled L2 graphs.
pub struct HloEngine {
    init: InitExec,
    train: TrainExec,
    eval: EvalExec,
}

impl HloEngine {
    pub fn new(rt: &mut Runtime, arch: &str, local_steps: usize) -> Result<Self> {
        let init = rt.init_exec(arch).context("loading init artifact")?;
        let train = rt
            .train_exec(arch, local_steps)
            .context("loading train artifact")?;
        let eval = rt.eval_exec(arch).context("loading eval artifact")?;
        Ok(HloEngine { init, train, eval })
    }
}

impl ComputeEngine for HloEngine {
    fn d(&self) -> usize {
        self.train.entry.d
    }

    fn batch(&self) -> usize {
        self.train.entry.batch
    }

    fn local_steps(&self) -> usize {
        self.train.entry.local_steps
    }

    fn eval_n(&self) -> usize {
        self.eval.eval_n()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.init.run(seed)
    }

    fn train_step(
        &self,
        params: &mut Vec<f32>,
        momentum: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        beta: f32,
        wd: f32,
    ) -> Result<f32> {
        let out = self.train.run(params, momentum, x, y, lr, beta, wd)?;
        *params = out.params;
        *momentum = out.momentum;
        Ok(out.loss)
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        self.eval.run(params, x, y)
    }

    fn name(&self) -> &'static str {
        "hlo"
    }
}

/// Build the configured engine; `rt` must be Some for the HLO path.
pub fn build_engine(
    cfg: &ExperimentConfig,
    rt: Option<&mut Runtime>,
) -> Result<Box<dyn ComputeEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeEngine::new(
            &cfg.arch,
            cfg.batch,
            cfg.local_steps,
        )?)),
        EngineKind::Hlo => {
            let rt = rt.ok_or_else(|| anyhow!("HLO engine needs a runtime"))?;
            Ok(Box::new(HloEngine::new(rt, &cfg.arch, cfg.local_steps)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_basics() {
        let e = NativeEngine::new("mlp_tiny", 8, 1).unwrap();
        assert_eq!(e.d(), 340);
        assert_eq!(e.batch(), 8);
        let p = e.init_params(3).unwrap();
        assert_eq!(p.len(), 340);
        // deterministic per seed
        assert_eq!(e.init_params(3).unwrap(), p);
        assert_ne!(e.init_params(4).unwrap(), p);
    }

    #[test]
    fn native_engine_trains() {
        let e = NativeEngine::new("mlp_tiny", 16, 1).unwrap();
        let mut params = e.init_params(0).unwrap();
        let mut momentum = vec![0.0f32; params.len()];
        let task = crate::data::TaskKind::Tiny.spec().instantiate(1);
        let mut rng = crate::util::rng::Rng::new(1);
        let data = task.sample_uniform(16, &mut rng);
        let mut losses = Vec::new();
        for _ in 0..30 {
            losses.push(
                e.train_step(&mut params, &mut momentum, &data.x, &data.y, 0.3, 0.9, 0.0)
                    .unwrap(),
            );
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.5));
    }

    #[test]
    fn native_local_steps_consume_stacked_batches() {
        let e = NativeEngine::new("mlp_tiny", 4, 3).unwrap();
        let mut params = e.init_params(0).unwrap();
        let mut momentum = vec![0.0f32; params.len()];
        let task = crate::data::TaskKind::Tiny.spec().instantiate(2);
        let mut rng = crate::util::rng::Rng::new(2);
        let data = task.sample_uniform(12, &mut rng);
        // 3 local steps * batch 4 = 12 samples stacked
        let loss = e
            .train_step(&mut params, &mut momentum, &data.x, &data.y, 0.1, 0.9, 0.0)
            .unwrap();
        assert!(loss.is_finite());
        // wrong size rejected
        assert!(e
            .train_step(&mut params, &mut momentum, &data.x[..16], &data.y[..1], 0.1, 0.9, 0.0)
            .is_err());
    }

    #[test]
    fn unknown_arch_rejected() {
        assert!(NativeEngine::new("resnet152", 8, 1).is_err());
    }
}
