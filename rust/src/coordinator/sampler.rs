//! Pull-based epidemic peer sampling (paper §3.3): every round, every
//! honest node independently samples `s` peers uniformly at random from
//! the other n−1 nodes — the independence of per-node samples is what
//! Lemma 5.2's T₂ variance computation relies on.
//!
//! The sampler itself is stateless (`Copy`, `Send + Sync`); randomness is
//! injected per draw. On the round path the coordinator uses
//! [`PullSampler::sample_at`], which derives the draw from the
//! counter-based `(seed, round, victim, PULL)` stream so pull sets are
//! identical for any worker count or scheduling order.

use crate::util::rng::{stream_tag, Rng};

/// Uniform without-replacement pull sampler.
#[derive(Clone, Copy, Debug)]
pub struct PullSampler {
    pub n: usize,
    pub s: usize,
}

impl PullSampler {
    pub fn new(n: usize, s: usize) -> Self {
        assert!(s >= 1 && s <= n - 1, "need 1 <= s <= n-1");
        PullSampler { n, s }
    }

    /// Sample the pull set S_i^t for `victim` (never includes the victim).
    pub fn sample(&self, victim: usize, rng: &mut Rng) -> Vec<usize> {
        rng.sample_distinct_excluding(self.n, self.s, victim)
    }

    /// Round-t pull set for `victim` from the counter-based
    /// `(seed, round, victim, PULL)` stream: a pure function of its
    /// arguments, independent of execution order and thread count.
    pub fn sample_at(&self, seed: u64, round: usize, victim: usize) -> Vec<usize> {
        let mut rng = Rng::stream(seed, round as u64, victim as u64, stream_tag::PULL);
        self.sample(victim, &mut rng)
    }

    /// Sample into a reusable buffer (hot-path variant).
    pub fn sample_into(&self, victim: usize, rng: &mut Rng, buf: &mut Vec<usize>) {
        buf.clear();
        buf.extend(self.sample(victim, rng));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_self_never_dup() {
        let sampler = PullSampler::new(20, 6);
        let mut rng = Rng::new(1);
        for victim in 0..20 {
            for _ in 0..50 {
                let s = sampler.sample(victim, &mut rng);
                assert_eq!(s.len(), 6);
                assert!(!s.contains(&victim));
                let mut d = s.clone();
                d.sort_unstable();
                d.dedup();
                assert_eq!(d.len(), 6);
            }
        }
    }

    #[test]
    fn uniform_over_peers() {
        let sampler = PullSampler::new(10, 3);
        let mut rng = Rng::new(2);
        let mut counts = vec![0u32; 10];
        let trials = 30_000;
        for _ in 0..trials {
            for j in sampler.sample(0, &mut rng) {
                counts[j] += 1;
            }
        }
        assert_eq!(counts[0], 0);
        let expect = trials as f64 * 3.0 / 9.0;
        for &c in &counts[1..] {
            assert!((c as f64 - expect).abs() < 0.05 * expect, "{counts:?}");
        }
    }

    #[test]
    fn byzantine_hits_follow_hypergeometric_mean() {
        // with b byzantine among the other n-1, mean hits = s*b/(n-1)
        let (n, b, s) = (30usize, 6usize, 15usize);
        let sampler = PullSampler::new(n, s);
        let mut rng = Rng::new(3);
        let byz: std::collections::HashSet<usize> = (0..b).collect();
        let trials = 20_000;
        let mut hits = 0usize;
        for _ in 0..trials {
            hits += sampler
                .sample(n - 1, &mut rng)
                .iter()
                .filter(|j| byz.contains(j))
                .count();
        }
        let mean = hits as f64 / trials as f64;
        let expect = s as f64 * b as f64 / (n - 1) as f64;
        assert!((mean - expect).abs() < 0.1, "mean={mean} expect={expect}");
    }

    #[test]
    fn all_to_all_sampling() {
        let sampler = PullSampler::new(8, 7);
        let mut rng = Rng::new(4);
        let mut s = sampler.sample(3, &mut rng);
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    #[should_panic]
    fn rejects_s_equal_n() {
        PullSampler::new(5, 5);
    }

    #[test]
    fn sample_at_is_pure_and_key_sensitive() {
        let sampler = PullSampler::new(16, 5);
        let a = sampler.sample_at(7, 3, 2);
        assert_eq!(a, sampler.sample_at(7, 3, 2));
        assert_eq!(a.len(), 5);
        assert!(!a.contains(&2));
        // different round or victim ⇒ (almost surely) different sets;
        // these keys are fixed, so this is a deterministic check
        assert_ne!(a, sampler.sample_at(7, 4, 2));
        assert!(!sampler.sample_at(7, 3, 9).contains(&9));
    }
}
