//! Worker-side pull serving for the socket transport.
//!
//! On `--transport socket|tcp` the coordinator never broadcasts the
//! O(h·d) half-step table. Instead every `rpel shard-worker` binds its
//! own listener and the round's model exchange happens worker-to-worker:
//!
//! * [`RowServer`] — the serving half. After each half-step phase the
//!   worker [`publish`](RowServer::publish)es its shard's rows for the
//!   round; a background accept loop answers peers' `PullRequest`s with
//!   exactly the requested rows (`PullReply`), or a `Deny` naming the
//!   root cause (stale round, out-of-range row, protocol mismatch).
//! * [`PeerClient`] — the fetching half. Given the coordinator's address
//!   book (`Peers`) and the round's routing table, it dials the owning
//!   peer (once — connections persist across rounds), requests the
//!   missing honest rows, and verifies the reply echoes the round and
//!   has the expected shape. Every error names the peer worker, its
//!   honest range, and the round — a dead peer surfaces as an actionable
//!   error on the puller, never a hang.
//!
//! Transient pull faults ride the `[recovery]` [`RetryPolicy`]: a failed
//! fetch (dial refused, reset mid-reply) drops the cached connection and
//! re-dials from scratch up to `retry_attempts` times with deterministic
//! backoff, and the retries a round consumed travel back to the
//! coordinator in `RoundDone.retries` for the `peer_retries_per_round`
//! ledger. Exhaustion surfaces the old named error — peer, honest range,
//! round — now also quoting the attempt budget.
//!
//! Lockstep makes the serving side race-free without condvars: a peer
//! can only request round t after the coordinator saw *every* worker's
//! round-t `Snapshot`, and every worker publishes its rows before
//! sending that snapshot; symmetrically, `HalfStep{t+1}` (which
//! republishes) is only sent after every worker's round-t `RoundDone`,
//! which follows its fetches. A request that still misses the published
//! round is answered with `Deny` rather than blocking.

use crate::wire::codec::{EncodedRows, RowCodec};
use crate::wire::proto::{self, PeerEntry, PeerMsg};
use crate::wire::transport::{
    Listener, RetryPolicy, SockAddr, SocketStream, SocketTransport, Transport,
};
use anyhow::{bail, ensure, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How often the accept loop polls for new connections / shutdown.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

#[derive(Default)]
struct Published {
    have: bool,
    round: u64,
    rows: Vec<Vec<f32>>,
    /// the round's encoded row block when the run compresses — replies
    /// gather cached per-row segments from it verbatim, so no row is
    /// ever re-encoded (q8 is not FP-idempotent)
    block: Option<EncodedRows>,
}

struct ServeShared {
    stop: AtomicBool,
    /// this worker's index (error messages name the *serving* worker)
    worker: usize,
    /// owned honest range `[start, start + len)`
    start: usize,
    len: usize,
    state: Mutex<Published>,
}

/// The serving half of worker-side pull exchange: an accept loop plus
/// the per-round published row table.
pub struct RowServer {
    shared: Arc<ServeShared>,
}

impl RowServer {
    /// Start serving on `listener` (one detached accept thread; one
    /// handler thread per peer connection — at most `procs − 1`).
    pub fn spawn(listener: Listener, worker: usize, start: usize, len: usize) -> Result<RowServer> {
        listener
            .set_nonblocking(true)
            .context("row server: nonblocking accept loop")?;
        let shared = Arc::new(ServeShared {
            stop: AtomicBool::new(false),
            worker,
            start,
            len,
            state: Mutex::new(Published::default()),
        });
        let for_thread = Arc::clone(&shared);
        std::thread::Builder::new()
            .name(format!("rpel-serve-{worker}"))
            .spawn(move || accept_loop(listener, for_thread))
            .context("row server: spawning accept loop")?;
        Ok(RowServer { shared })
    }

    /// Publish this shard's half-step rows for `round`, plus the round's
    /// encoded block when the run compresses (`None` at `none` — replies
    /// then encode the raw rows directly). Must happen before the
    /// round's `Snapshot` is sent to the coordinator (the lockstep
    /// argument above relies on it).
    pub fn publish(&self, round: u64, rows: &[Vec<f32>], block: Option<EncodedRows>) {
        debug_assert_eq!(rows.len(), self.shared.len);
        // A poisoned lock means a serve thread panicked while reading;
        // publish overwrites the whole table, so recovery is sound.
        let mut st = self
            .shared
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        // `have` is cleared first and set last: if *this* write is ever
        // interrupted, a recovering reader sees "not published" instead
        // of a half-copied table.
        st.have = false;
        st.round = round;
        st.rows.resize(rows.len(), Vec::new());
        for (dst, src) in st.rows.iter_mut().zip(rows) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        st.block = block;
        st.have = true;
    }
}

impl Drop for RowServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }
}

fn accept_loop(listener: Listener, shared: Arc<ServeShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => {
                let _ = stream.set_nonblocking(false);
                let for_conn = Arc::clone(&shared);
                let worker = shared.worker;
                let spawned = std::thread::Builder::new()
                    .name(format!("rpel-serve-{worker}-conn"))
                    .spawn(move || {
                        if let Err(e) = serve_conn(&for_conn, stream) {
                            log::warn!("worker {worker}: peer connection ended: {e:#}");
                        }
                    });
                if let Err(e) = spawned {
                    log::warn!("worker {worker}: cannot spawn peer handler: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                log::warn!("worker {}: accept failed: {e}", shared.worker);
                return;
            }
        }
    }
}

/// One peer connection: `Hello` then a lockstep request/reply loop.
fn serve_conn(shared: &ServeShared, stream: SocketStream) -> Result<()> {
    let mut t = SocketTransport::from_stream(stream)?;
    loop {
        let Some(frame) = t.recv_opt()? else {
            return Ok(()); // peer closed between requests: orderly
        };
        match proto::decode_peer(&frame) {
            Ok(PeerMsg::Hello { .. }) => {} // identification only
            Ok(PeerMsg::PullRequest { round, rows }) => {
                let reply = {
                    // Poison recovery is safe: `publish` orders its writes
                    // so `have` is only true for a fully-copied table.
                    let st = shared
                        .state
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    pull_reply_frame(shared, &st, round, &rows)
                };
                t.send(&reply)?;
            }
            Ok(other) => {
                let msg = format!(
                    "worker {}: unexpected {:?} on the serving side",
                    shared.worker, other
                );
                let _ = t.send(&proto::encode_peer_deny(&msg));
                bail!("{msg}");
            }
            Err(e) => {
                // bad frame (e.g. a version-mismatched Hello): name the
                // cause for the peer, then drop the connection
                let _ = t.send(&proto::encode_peer_deny(&format!(
                    "worker {}: {e:#}",
                    shared.worker
                )));
                return Err(e);
            }
        }
    }
}

/// Encode the reply to one `PullRequest` under the published-state lock.
fn pull_reply_frame(
    shared: &ServeShared,
    st: &Published,
    round: u64,
    rows: &[u32],
) -> Vec<u8> {
    if !st.have || st.round != round {
        let published = if st.have {
            st.round.to_string()
        } else {
            "none".to_string()
        };
        return proto::encode_peer_deny(&format!(
            "worker {}: pull for round {round} but published round is {published} \
             (stale request or aborted round)",
            shared.worker
        ));
    }
    let end = shared.start + shared.len;
    for &hi in rows {
        let hi = hi as usize;
        if hi < shared.start || hi >= end {
            return proto::encode_peer_deny(&format!(
                "worker {}: row {hi} outside owned honest range {}..{end}",
                shared.worker, shared.start
            ));
        }
    }
    if let Some(block) = &st.block {
        // compressed run: gather the cached encoded segments verbatim
        let idx: Vec<usize> = rows.iter().map(|&hi| hi as usize - shared.start).collect();
        return match block.gather(&idx) {
            Ok(sub) => proto::encode_pull_reply_block(round, &sub),
            Err(e) => proto::encode_peer_deny(&format!("worker {}: {e:#}", shared.worker)),
        };
    }
    let refs: Vec<&[f32]> = rows
        .iter()
        .map(|&hi| st.rows[hi as usize - shared.start].as_slice())
        .collect();
    proto::encode_pull_reply(round, &refs)
}

struct PeerConn {
    transport: SocketTransport,
    /// bytes already attributed to earlier rounds' ledgers
    counted: u64,
}

/// The fetching half: persistent outbound connections to owning peers.
pub struct PeerClient {
    me: usize,
    /// this worker's restart generation — travels in every `Hello` so a
    /// supervisor can tell a respawned worker's traffic from its
    /// predecessor's
    incarnation: u32,
    /// bounded-retry schedule for dial + fetch faults
    retry: RetryPolicy,
    /// retries consumed since the last [`PeerClient::take_retries`]
    retries: u32,
    /// absorb every first `Hello` per peer instead of counting it — set
    /// on clients rebuilt after a respawn or re-broadcast, whose
    /// unfaulted twin counted those hellos in an earlier round already
    absorb_hellos: bool,
    /// per worker: (start, len, listener address)
    entries: Vec<(usize, usize, SockAddr)>,
    conns: Vec<Option<PeerConn>>,
}

impl PeerClient {
    /// Build from the coordinator's `Peers` address book.
    pub fn new(
        me: usize,
        incarnation: u32,
        retry: RetryPolicy,
        book: &[PeerEntry],
    ) -> Result<PeerClient> {
        let mut entries = Vec::with_capacity(book.len());
        for e in book {
            entries.push((
                e.start as usize,
                e.len as usize,
                SockAddr::parse(&e.addr)
                    .with_context(|| format!("peer book entry for range {}..", e.start))?,
            ));
        }
        let conns = (0..entries.len()).map(|_| None).collect();
        Ok(PeerClient {
            me,
            incarnation,
            retry,
            retries: 0,
            absorb_hellos: false,
            entries,
            conns,
        })
    }

    /// When on, the one-time `Hello` of every fresh connection is
    /// recovery traffic: its bytes are absorbed rather than reported in
    /// the fetch delta. Used for clients rebuilt mid-run (respawned
    /// worker, re-broadcast address book) so faulted runs keep the
    /// unfaulted runs' byte ledgers.
    pub fn set_absorb_hellos(&mut self, on: bool) {
        self.absorb_hellos = on;
    }

    /// Drain the retry counter (called once per round; the count ships
    /// in that round's `RoundDone`).
    pub fn take_retries(&mut self) -> u32 {
        std::mem::take(&mut self.retries)
    }

    /// The worker owning global honest index `hi`.
    pub fn owner_of(&self, hi: usize) -> Option<usize> {
        self.entries
            .iter()
            .position(|&(start, len, _)| hi >= start && hi < start + len)
    }

    pub fn peer_count(&self) -> usize {
        self.entries.len()
    }

    /// Owned range of worker `w` (for validation against the local
    /// partition derivation).
    pub fn range_of(&self, w: usize) -> (usize, usize) {
        (self.entries[w].0, self.entries[w].1)
    }

    /// Drop every cached peer connection: the next [`fetch`] to each
    /// owner re-dials and re-identifies with a fresh `PeerHello`. This is
    /// the rejoin path — a worker that crashed and came back (or healed
    /// from a partition) re-handshakes exactly like a first contact, and
    /// the `Hello` bytes land in that fetch's ledger delta.
    ///
    /// [`fetch`]: PeerClient::fetch
    pub fn reset_conns(&mut self) {
        for conn in self.conns.iter_mut() {
            *conn = None;
        }
    }

    fn ensure_conn(&mut self, owner: usize) -> Result<&mut PeerConn> {
        if self.conns[owner].is_none() {
            let mut transport = SocketTransport::connect(&self.entries[owner].2)?;
            transport.send(&proto::encode_peer_hello(
                self.me as u32,
                self.incarnation,
                "",
            ))?;
            self.conns[owner] = Some(PeerConn {
                transport,
                counted: 0,
            });
        }
        self.conns[owner]
            .as_mut()
            .with_context(|| format!("internal: no connection to peer worker {owner} after dial"))
    }

    /// Fetch the given rows (global honest indices owned by `owner`) of
    /// round `round`'s table, decoding the reply through `rc` (the same
    /// codec + reference the owner encoded with; `none` reads raw f32).
    /// Returns the decoded rows in request order plus the wire bytes
    /// this call consumed (requests + replies + the one-time `Hello` on
    /// a fresh connection).
    ///
    /// A failed attempt drops the cached connection (it may be half-dead
    /// with a frame in flight) and the [`RetryPolicy`] re-dials from
    /// scratch. Ledger bytes stay fault-independent: when a retry
    /// replaces a connection that already existed, the replacement
    /// `Hello`'s bytes are absorbed rather than counted, so a fetch that
    /// needed a retry reports the same delta as one that did not.
    pub fn fetch(
        &mut self,
        round: u64,
        owner: usize,
        rows: &[u32],
        d: usize,
        rc: &RowCodec<'_>,
    ) -> Result<(Vec<Vec<f32>>, u64)> {
        let (start, len, _) = self.entries[owner];
        let who = format!(
            "peer worker {owner} (honest nodes {start}..{}): pull for round {round}",
            start + len
        );
        let had_conn = self.conns[owner].is_some();
        let absorb_all = self.absorb_hellos;
        let retry = self.retry;
        let mut used = 0u32;
        let result = retry.run(&who, |attempt| {
            if attempt > 0 {
                used += 1;
                self.conns[owner] = None;
            }
            let absorb = absorb_all || (attempt > 0 && had_conn);
            self.fetch_inner(round, owner, rows, d, rc, absorb)
        });
        self.retries += used;
        result
    }

    fn fetch_inner(
        &mut self,
        round: u64,
        owner: usize,
        rows: &[u32],
        d: usize,
        rc: &RowCodec<'_>,
        absorb_hello: bool,
    ) -> Result<(Vec<Vec<f32>>, u64)> {
        let fresh = self.conns[owner].is_none();
        let conn = self.ensure_conn(owner)?;
        if fresh && absorb_hello {
            // the unfaulted run counted this peer's Hello long ago; the
            // respawned connection's copy is recovery traffic
            conn.counted = conn.transport.bytes_out() + conn.transport.bytes_in();
        }
        conn.transport.send(&proto::encode_pull_request(round, rows))?;
        let frame = conn.transport.recv()?;
        let reply = proto::decode_peer_c(&frame, rc)?;
        let bytes_now = conn.transport.bytes_out() + conn.transport.bytes_in();
        let delta = bytes_now - conn.counted;
        conn.counted = bytes_now;
        match reply {
            PeerMsg::PullReply { round: got, rows: got_rows } => {
                ensure!(
                    got == round,
                    "stale PullReply for round {got} (expected {round}) — an \
                     earlier round aborted mid-pull"
                );
                ensure!(
                    got_rows.len() == rows.len() && got_rows.iter().all(|r| r.len() == d),
                    "malformed PullReply ({} rows; expected {} of width {d})",
                    got_rows.len(),
                    rows.len()
                );
                Ok((got_rows, delta))
            }
            PeerMsg::Deny { message } => bail!("peer refused: {message}"),
            other => bail!("expected PullReply, got {other:?}"),
        }
    }
}
