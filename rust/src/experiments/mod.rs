//! Figure harnesses: regenerate every table/figure of the paper's
//! evaluation section (`rpel figure --id <ID>` / `make figures`).
//!
//! Output per figure: paper-style printed series + CSV files under
//! `results/<figure>/`.

use crate::config::presets::{EafScenario, Figure, FigureSeries, Scale};
use crate::config::{EngineKind, ExperimentConfig, TransportKind};
use crate::coordinator::{checkpoint, Trainer};
use crate::metrics::{write_histories, History};
use crate::sampling::EafSimulator;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Outcome of running one figure.
pub struct FigureOutcome {
    pub id: String,
    pub histories: Vec<History>,
    pub eaf_rows: Vec<EafRow>,
    pub csv_paths: Vec<String>,
}

/// One (scenario, s) grid point of Figure 3.
#[derive(Clone, Debug)]
pub struct EafRow {
    pub label: String,
    pub n: u64,
    pub b: u64,
    pub s: u64,
    pub bhat: u64,
    pub eaf: f64,
    pub eaf_mean: f64,
    pub eaf_ci95: f64,
}

/// Run one training config and report progress.
pub fn run_training(cfg: &ExperimentConfig) -> Result<History> {
    let mut trainer =
        Trainer::from_config(cfg).with_context(|| format!("building '{}'", cfg.name))?;
    let hist = trainer
        .run()
        .with_context(|| format!("running '{}'", cfg.name))?;
    println!("  {}", hist.report_line());
    Ok(hist)
}

/// Resume a checkpointed run (`rpel train --resume DIR`): load the
/// durable checkpoint, rebuild the world from its embedded config,
/// install the boundary state, and continue the round loop from the
/// boundary. The returned history is bit-identical to the
/// straight-through run's on every trajectory ledger (`wall_secs` and
/// `checkpoint_bytes_per_round` are reporting-only and excluded from
/// that guarantee).
pub fn resume_training(dir: &str) -> Result<History> {
    let resumed = checkpoint::read_checkpoint(std::path::Path::new(dir))?;
    let boundary = resumed.state.round as usize;
    println!(
        "  resuming '{}' from round {boundary}/{} ({dir})",
        resumed.cfg.name, resumed.cfg.rounds
    );
    let mut trainer = Trainer::from_config_with_resume(&resumed.cfg, Some(&resumed.state))
        .with_context(|| format!("rebuilding '{}' from {dir}", resumed.cfg.name))?;
    let hist = trainer
        .run_from(resumed.hist, boundary)
        .with_context(|| format!("resuming '{}'", resumed.cfg.name))?;
    println!("  {}", hist.report_line());
    Ok(hist)
}

/// Run the Figure-3 scenarios.
pub fn run_eaf(scenarios: &[EafScenario], seed: u64) -> Vec<EafRow> {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for sc in scenarios {
        println!("  scenario {} (T={})", sc.label, sc.t);
        let sim = EafSimulator::new(sc.n, sc.b, sc.t, sc.sims);
        for p in sim.sweep(&sc.grid, &mut rng) {
            println!(
                "    s={:<4} b̂={:<3} EAF={:.3} (mean {:.3} ± {:.3})",
                p.s, p.bhat, p.eaf, p.eaf_mean, p.eaf_ci95
            );
            rows.push(EafRow {
                label: sc.label.clone(),
                n: sc.n,
                b: sc.b,
                s: p.s,
                bhat: p.bhat,
                eaf: p.eaf,
                eaf_mean: p.eaf_mean,
                eaf_ci95: p.eaf_ci95,
            });
        }
    }
    rows
}

fn eaf_csv(rows: &[EafRow]) -> String {
    let mut out = String::from("scenario,n,b,s,bhat,eaf,eaf_mean,eaf_ci95\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{:.6},{:.6},{:.6}\n",
            r.label, r.n, r.b, r.s, r.bhat, r.eaf, r.eaf_mean, r.eaf_ci95
        ));
    }
    out
}

/// Run one figure end to end. `threads_override` / `shards_override` /
/// `procs_override` / `transport_override` force the round-engine
/// worker, shard, shard-process, and wire-transport settings on every
/// series config (None = keep the preset's value; results are identical
/// either way).
#[allow(clippy::too_many_arguments)]
pub fn run_figure(
    fig: &Figure,
    scale: Scale,
    engine_override: Option<EngineKind>,
    threads_override: Option<usize>,
    shards_override: Option<usize>,
    procs_override: Option<usize>,
    transport_override: Option<TransportKind>,
    out_dir: &str,
) -> Result<FigureOutcome> {
    println!("figure {} — {}", fig.id, fig.title);
    println!("paper expectation: {}", fig.expectation);
    let dir = format!("{out_dir}/{}", fig.id);
    match fig.series(scale) {
        FigureSeries::Training(mut cfgs) => {
            let mut histories = Vec::new();
            for cfg in &mut cfgs {
                if let Some(engine) = engine_override {
                    cfg.engine = engine;
                }
                if let Some(threads) = threads_override {
                    cfg.threads = threads;
                }
                if let Some(shards) = shards_override {
                    cfg.shards = shards;
                }
                if let Some(procs) = procs_override {
                    cfg.procs = procs;
                }
                if let Some(transport) = transport_override {
                    cfg.transport = transport;
                }
                histories.push(run_training(cfg)?);
            }
            let csv_paths = write_histories(&dir, &histories)?;
            Ok(FigureOutcome {
                id: fig.id.to_string(),
                histories,
                eaf_rows: Vec::new(),
                csv_paths,
            })
        }
        FigureSeries::Eaf(scenarios) => {
            let rows = run_eaf(&scenarios, 2025);
            std::fs::create_dir_all(&dir)?;
            let path = format!("{dir}/eaf.csv");
            std::fs::write(&path, eaf_csv(&rows))?;
            Ok(FigureOutcome {
                id: fig.id.to_string(),
                histories: Vec::new(),
                eaf_rows: rows,
                csv_paths: vec![path],
            })
        }
    }
}

/// Summary table printed after a figure run (and captured into
/// EXPERIMENTS.md).
pub fn summary_table(outcome: &FigureOutcome) -> String {
    let mut out = String::new();
    if !outcome.histories.is_empty() {
        out.push_str(&format!(
            "{:<36} {:>9} {:>9} {:>10} {:>12}\n",
            "series", "final", "worst", "loss", "msgs/round"
        ));
        for h in &outcome.histories {
            out.push_str(&format!(
                "{:<36} {:>9.3} {:>9.3} {:>10.4} {:>12}\n",
                h.name,
                h.final_avg_accuracy(),
                h.final_worst_accuracy(),
                h.final_train_loss(),
                h.messages_per_round
            ));
        }
    }
    if !outcome.eaf_rows.is_empty() {
        out.push_str(&format!(
            "{:<24} {:>8} {:>6} {:>6} {:>8}\n",
            "scenario", "s", "b̂", "EAF", "±CI"
        ));
        for r in &outcome.eaf_rows {
            out.push_str(&format!(
                "{:<24} {:>8} {:>6} {:>6.3} {:>8.3}\n",
                r.label, r.s, r.bhat, r.eaf, r.eaf_ci95
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn eaf_figure_runs_quickly() {
        let scens = vec![EafScenario {
            label: "test".into(),
            n: 100,
            b: 10,
            t: 20,
            grid: vec![5, 15],
            sims: 2,
        }];
        let rows = run_eaf(&scens, 7);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].eaf >= rows[1].eaf - 0.05, "EAF should shrink with s");
    }

    #[test]
    fn training_run_produces_history() {
        let mut cfg = presets::quickstart_config();
        cfg.rounds = 6;
        cfg.eval_every = 3;
        let h = run_training(&cfg).unwrap();
        assert_eq!(h.train_loss.len(), 6);
        assert_eq!(h.evals.len(), 2);
    }

    #[test]
    fn figure_outcome_to_disk() {
        let fig = presets::figure("fig3").unwrap();
        let tmp = std::env::temp_dir().join("rpel_fig_test");
        let tmp = tmp.to_str().unwrap();
        // shrink fig3 by running only the first scenario at tiny T
        let scens = vec![EafScenario {
            label: "mini".into(),
            n: 50,
            b: 5,
            t: 10,
            grid: vec![5, 10],
            sims: 2,
        }];
        let rows = run_eaf(&scens, 1);
        std::fs::create_dir_all(format!("{tmp}/{}", fig.id)).unwrap();
        let csv = super::eaf_csv(&rows);
        assert!(csv.lines().count() == 3);
        std::fs::remove_dir_all(tmp).ok();
    }

    #[test]
    fn summary_table_formats() {
        let outcome = FigureOutcome {
            id: "figX".into(),
            histories: vec![],
            eaf_rows: vec![EafRow {
                label: "l".into(),
                n: 10,
                b: 1,
                s: 3,
                bhat: 1,
                eaf: 0.25,
                eaf_mean: 0.2,
                eaf_ci95: 0.01,
            }],
            csv_paths: vec![],
        };
        let t = summary_table(&outcome);
        assert!(t.contains("0.250"));
    }
}
