//! Dirichlet non-IID partitioning (Hsu et al. 2019) — the paper's
//! heterogeneity model (§6.1): for each class, draw node proportions
//! `p ~ Dir(α · 1_n)` and scatter that class's samples accordingly.
//! Larger α → more homogeneous shards; smaller α → highly skewed.

use crate::util::rng::Rng;

/// Assign per-class sample labels to `nodes` shards with Dirichlet(alpha)
/// proportions. Returns `labels[node] = Vec<class-label>` with
/// `samples_per_node` entries each (exact sizes, resolved by largest-
/// remainder rounding so every node trains on the same batch count).
pub fn partition_dirichlet(
    nodes: usize,
    classes: usize,
    samples_per_node: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<i32>> {
    assert!(nodes > 0 && classes > 0 && samples_per_node > 0);
    let total = nodes * samples_per_node;

    // per-class Dirichlet proportions over nodes: weight[c][i]
    let weights: Vec<Vec<f64>> = (0..classes)
        .map(|_| rng.dirichlet_sym(alpha, nodes))
        .collect();

    // target count of class c on node i (real-valued), assuming the global
    // class marginal is uniform (total/classes per class)
    let per_class = total as f64 / classes as f64;
    let mut shards: Vec<Vec<i32>> = vec![Vec::with_capacity(samples_per_node + classes); nodes];

    // Fill node-by-node using each node's class profile:
    // node i's class distribution q_i(c) ∝ weights[c][i].
    for i in 0..nodes {
        let mut q: Vec<f64> = (0..classes).map(|c| weights[c][i] * per_class).collect();
        let qsum: f64 = q.iter().sum();
        if qsum <= 0.0 {
            q = vec![1.0; classes];
        }
        let qsum: f64 = q.iter().sum();
        // largest-remainder apportionment of samples_per_node among classes
        let mut counts: Vec<usize> = q
            .iter()
            .map(|&w| ((w / qsum) * samples_per_node as f64).floor() as usize)
            .collect();
        let assigned: usize = counts.iter().sum();
        let mut rema: Vec<(f64, usize)> = q
            .iter()
            .enumerate()
            .map(|(c, &w)| {
                let exact = (w / qsum) * samples_per_node as f64;
                (exact - exact.floor(), c)
            })
            .collect();
        rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for k in 0..(samples_per_node - assigned) {
            counts[rema[k % classes].1] += 1;
        }
        for (c, &cnt) in counts.iter().enumerate() {
            for _ in 0..cnt {
                shards[i].push(c as i32);
            }
        }
        debug_assert_eq!(shards[i].len(), samples_per_node);
        rng.shuffle(&mut shards[i]);
    }
    shards
}

/// Heterogeneity diagnostic: mean total-variation distance between each
/// node's empirical label distribution and the global uniform marginal.
/// 0 = IID, → (classes−1)/classes as shards become one-class.
pub fn label_skew(shards: &[Vec<i32>], classes: usize) -> f64 {
    let uniform = 1.0 / classes as f64;
    let mut acc = 0.0;
    for shard in shards {
        let mut counts = vec![0usize; classes];
        for &y in shard {
            counts[y as usize] += 1;
        }
        let n = shard.len() as f64;
        let tv: f64 = counts
            .iter()
            .map(|&c| (c as f64 / n - uniform).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
    }
    acc / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_shard_sizes() {
        let mut rng = Rng::new(1);
        let shards = partition_dirichlet(10, 10, 57, 1.0, &mut rng);
        assert_eq!(shards.len(), 10);
        for s in &shards {
            assert_eq!(s.len(), 57);
        }
    }

    #[test]
    fn labels_in_range() {
        let mut rng = Rng::new(2);
        let shards = partition_dirichlet(5, 62, 100, 10.0, &mut rng);
        for s in &shards {
            assert!(s.iter().all(|&y| (0..62).contains(&y)));
        }
    }

    #[test]
    fn alpha_controls_skew() {
        let mut rng = Rng::new(3);
        let skew_lo_alpha = label_skew(&partition_dirichlet(20, 10, 200, 0.1, &mut rng), 10);
        let skew_hi_alpha = label_skew(&partition_dirichlet(20, 10, 200, 100.0, &mut rng), 10);
        assert!(
            skew_lo_alpha > skew_hi_alpha + 0.2,
            "alpha=0.1 skew {skew_lo_alpha} should far exceed alpha=100 skew {skew_hi_alpha}"
        );
    }

    #[test]
    fn high_alpha_near_iid() {
        let mut rng = Rng::new(4);
        let shards = partition_dirichlet(10, 10, 500, 1000.0, &mut rng);
        assert!(label_skew(&shards, 10) < 0.1);
    }

    #[test]
    fn deterministic_given_rng_state() {
        let a = partition_dirichlet(6, 4, 30, 1.0, &mut Rng::new(9));
        let b = partition_dirichlet(6, 4, 30, 1.0, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn single_node_gets_everything() {
        let mut rng = Rng::new(5);
        let shards = partition_dirichlet(1, 10, 100, 1.0, &mut rng);
        assert_eq!(shards[0].len(), 100);
    }

    #[test]
    fn skew_bounds() {
        let mut rng = Rng::new(6);
        for alpha in [0.1, 1.0, 10.0] {
            let s = label_skew(&partition_dirichlet(8, 10, 100, alpha, &mut rng), 10);
            assert!((0.0..=1.0).contains(&s));
        }
    }
}
