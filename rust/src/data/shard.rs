//! Per-node data shards and batch iteration.
//!
//! Each honest node owns a [`Shard`]: a local dataset plus a cursor that
//! yields mini-batches forever (reshuffling at epoch boundaries with the
//! node's own RNG stream), matching "Randomly sample a data point ξ from
//! D_i" in Algorithm 1 line 3.

use crate::data::synth::Dataset;
use crate::util::rng::Rng;

/// A borrowed mini-batch view (row-major features).
#[derive(Debug)]
pub struct Batch {
    pub x: Vec<f32>, // batch * dim
    pub y: Vec<i32>, // batch
    pub dim: usize,
}

/// A node-local dataset with epoch shuffling.
#[derive(Clone, Debug)]
pub struct Shard {
    data: Dataset,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl Shard {
    pub fn new(data: Dataset, rng: Rng) -> Self {
        let order: Vec<usize> = (0..data.len()).collect();
        let mut s = Shard {
            data,
            order,
            cursor: 0,
            rng,
        };
        s.reshuffle();
        s
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.data.dim
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next mini-batch of exactly `batch` samples (wraps with reshuffle —
    /// sampling with per-epoch permutation, the standard SGD regime).
    pub fn next_batch(&mut self, batch: usize) -> Batch {
        assert!(!self.is_empty(), "empty shard");
        let dim = self.data.dim;
        let mut x = Vec::with_capacity(batch * dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            x.extend_from_slice(self.data.row(idx));
            y.push(self.data.y[idx]);
        }
        Batch { x, y, dim }
    }

    /// `k` consecutive batches stacked (for local-steps artifacts whose
    /// input carries a leading [k] axis).
    pub fn next_batches(&mut self, k: usize, batch: usize) -> Batch {
        let dim = self.data.dim;
        let mut x = Vec::with_capacity(k * batch * dim);
        let mut y = Vec::with_capacity(k * batch);
        for _ in 0..k {
            let b = self.next_batch(batch);
            x.extend_from_slice(&b.x);
            y.extend_from_slice(&b.y);
        }
        Batch { x, y, dim }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::TaskKind;

    fn shard(n: usize, seed: u64) -> Shard {
        let data = TaskKind::Tiny
            .spec()
            .instantiate(seed)
            .sample_uniform(n, &mut Rng::new(seed));
        Shard::new(data, Rng::new(seed))
    }

    #[test]
    fn batch_shapes() {
        let mut s = shard(30, 0);
        let b = s.next_batch(8);
        assert_eq!(b.x.len(), 8 * s.dim());
        assert_eq!(b.y.len(), 8);
    }

    #[test]
    fn wraps_past_epoch() {
        let mut s = shard(10, 1);
        for _ in 0..10 {
            let b = s.next_batch(7); // crosses epoch boundaries repeatedly
            assert_eq!(b.y.len(), 7);
        }
    }

    #[test]
    fn epoch_covers_all_samples() {
        let mut s = shard(12, 2);
        let mut seen = std::collections::HashSet::new();
        let b = s.next_batch(12);
        for i in 0..12 {
            seen.insert(
                b.x[i * s.dim()..(i + 1) * s.dim()]
                    .iter()
                    .map(|f| f.to_bits())
                    .collect::<Vec<_>>(),
            );
        }
        assert_eq!(seen.len(), 12, "one epoch must touch every sample once");
    }

    #[test]
    fn batches_stacked_for_local_steps() {
        let mut s = shard(40, 3);
        let b = s.next_batches(3, 5);
        assert_eq!(b.x.len(), 3 * 5 * s.dim());
        assert_eq!(b.y.len(), 15);
    }

    #[test]
    fn deterministic_stream() {
        let mut a = shard(20, 4);
        let mut b = shard(20, 4);
        for _ in 0..5 {
            let ba = a.next_batch(6);
            let bb = b.next_batch(6);
            assert_eq!(ba.x, bb.x);
            assert_eq!(ba.y, bb.y);
        }
    }

    #[test]
    #[should_panic]
    fn empty_shard_panics() {
        let data = Dataset {
            dim: 4,
            classes: 2,
            x: vec![],
            y: vec![],
        };
        Shard::new(data, Rng::new(0)).next_batch(1);
    }
}
