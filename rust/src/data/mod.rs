//! Data substrate: synthetic classification tasks (the offline stand-ins
//! for MNIST / CIFAR-10 / FEMNIST — DESIGN.md §Substitutions), Dirichlet
//! non-IID partitioning (§6.1 "Heterogeneity"), and per-node shards with
//! infinite batch iterators.

pub mod dirichlet;
pub mod shard;
pub mod synth;

pub use dirichlet::partition_dirichlet;
pub use shard::{Batch, Shard};
pub use synth::{Dataset, TaskInstance, TaskKind, TaskSpec};
