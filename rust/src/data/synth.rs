//! Synthetic Gaussian-mixture classification tasks.
//!
//! The environment has no network access, so the paper's MNIST / CIFAR-10 /
//! FEMNIST datasets are replaced by deterministic synthetic tasks with the
//! same class counts and a controllable difficulty knob (DESIGN.md
//! §Substitutions documents why this preserves the phenomena under study).
//!
//! Class `c` has a mean vector `μ_c` drawn uniformly on a sphere of radius
//! `sep`; samples are `x = μ_c + noise · N(0, I)`. Lowering `sep/noise`
//! makes the task harder (CIFAR-like); raising it makes it MNIST-like.

use crate::util::rng::Rng;

/// Which paper dataset a task stands in for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    MnistLike,
    CifarLike,
    FemnistLike,
    Tiny,
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::MnistLike => "mnistlike",
            TaskKind::CifarLike => "cifarlike",
            TaskKind::FemnistLike => "femnistlike",
            TaskKind::Tiny => "tiny",
        }
    }

    /// Default arch name in the artifact manifest for this task.
    pub fn default_arch(&self) -> &'static str {
        match self {
            TaskKind::MnistLike => "mlp_mnistlike",
            TaskKind::CifarLike => "mlp_cifarlike",
            TaskKind::FemnistLike => "mlp_femnistlike",
            TaskKind::Tiny => "mlp_tiny",
        }
    }

    pub fn spec(&self) -> TaskSpec {
        match self {
            // MNIST: easy, well-separated classes (paper reaches >90% fast)
            TaskKind::MnistLike => TaskSpec {
                kind: *self,
                dim: 64,
                classes: 10,
                sep: 3.0,
                noise: 1.0,
            },
            // CIFAR: harder — closer means, more noise (paper tops ~75%)
            TaskKind::CifarLike => TaskSpec {
                kind: *self,
                dim: 96,
                classes: 10,
                sep: 1.7,
                noise: 1.2,
            },
            // FEMNIST: many classes
            TaskKind::FemnistLike => TaskSpec {
                kind: *self,
                dim: 64,
                classes: 62,
                sep: 3.2,
                noise: 1.0,
            },
            TaskKind::Tiny => TaskSpec {
                kind: *self,
                dim: 16,
                classes: 4,
                sep: 3.0,
                noise: 0.8,
            },
        }
    }
}

/// Generative parameters of a synthetic task.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub kind: TaskKind,
    pub dim: usize,
    pub classes: usize,
    /// radius of the class-mean sphere
    pub sep: f32,
    /// per-coordinate sample noise std
    pub noise: f32,
}

/// A fully materialized dataset (row-major features + labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub classes: usize,
    pub x: Vec<f32>, // n * dim, row-major
    pub y: Vec<i32>, // n
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }
}

impl TaskSpec {
    /// Fix the class means for one experiment. All of an experiment's data
    /// (training shards AND the global test set) must come from the same
    /// instance — means are part of the task identity.
    pub fn instantiate(&self, seed: u64) -> TaskInstance {
        let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
        let means = (0..self.classes)
            .map(|_| {
                // uniform direction via normalized gaussian, scaled to sep
                let mut v: Vec<f32> = (0..self.dim).map(|_| rng.gaussian() as f32).collect();
                let norm = crate::util::vecmath::norm(&v).max(1e-9) as f32;
                for x in &mut v {
                    *x *= self.sep / norm;
                }
                v
            })
            .collect();
        TaskInstance { spec: *self, means }
    }
}

/// A concrete task: spec + frozen class means.
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub spec: TaskSpec,
    means: Vec<Vec<f32>>,
}

impl TaskInstance {
    pub fn means(&self) -> &[Vec<f32>] {
        &self.means
    }

    /// Generate samples for a given label sequence (Dirichlet-skewed
    /// shards pass their assigned labels here).
    pub fn sample_labels(&self, labels: &[i32], rng: &mut Rng) -> Dataset {
        let spec = &self.spec;
        let mut x = Vec::with_capacity(labels.len() * spec.dim);
        for &c in labels {
            let mu = &self.means[c as usize];
            for j in 0..spec.dim {
                x.push(rng.gaussian32(mu[j], spec.noise));
            }
        }
        Dataset {
            dim: spec.dim,
            classes: spec.classes,
            x,
            y: labels.to_vec(),
        }
    }

    /// Generate `n` samples with uniform class marginals (the global
    /// test set in the paper's evaluation is class-balanced).
    pub fn sample_uniform(&self, n: usize, rng: &mut Rng) -> Dataset {
        let mut labels: Vec<i32> = (0..n).map(|i| (i % self.spec.classes) as i32).collect();
        rng.shuffle(&mut labels);
        self.sample_labels(&labels, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath;

    #[test]
    fn deterministic_generation() {
        let spec = TaskKind::MnistLike.spec();
        let a = spec.instantiate(3).sample_uniform(100, &mut Rng::new(5));
        let b = spec.instantiate(3).sample_uniform(100, &mut Rng::new(5));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = spec.instantiate(4).sample_uniform(100, &mut Rng::new(5));
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn shapes_and_label_range() {
        let spec = TaskKind::FemnistLike.spec();
        let d = spec.instantiate(0).sample_uniform(200, &mut Rng::new(0));
        assert_eq!(d.x.len(), 200 * spec.dim);
        assert_eq!(d.y.len(), 200);
        assert!(d.y.iter().all(|&y| (0..spec.classes as i32).contains(&y)));
    }

    #[test]
    fn uniform_marginals_balanced() {
        let spec = TaskKind::MnistLike.spec();
        let d = spec.instantiate(1).sample_uniform(1000, &mut Rng::new(1));
        let mut counts = vec![0usize; spec.classes];
        for &y in &d.y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn class_means_have_requested_radius() {
        let spec = TaskKind::CifarLike.spec();
        for mu in spec.instantiate(5).means() {
            let r = vecmath::norm(mu);
            assert!((r - spec.sep as f64).abs() < 1e-4, "r={r}");
        }
    }

    #[test]
    fn classes_are_separable_by_nearest_mean() {
        // sanity: with sep >> noise, nearest-mean classification should be
        // far above chance — the synthetic task is actually learnable
        let spec = TaskKind::MnistLike.spec();
        let inst = spec.instantiate(7);
        let d = inst.sample_uniform(500, &mut Rng::new(7));
        let means = inst.means();
        let mut correct = 0;
        for i in 0..d.len() {
            let xi = d.row(i);
            let pred = (0..spec.classes)
                .min_by(|&a, &b| {
                    vecmath::dist_sq(xi, &means[a])
                        .partial_cmp(&vecmath::dist_sq(xi, &means[b]))
                        .unwrap()
                })
                .unwrap();
            if pred as i32 == d.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.6, "nearest-mean acc {acc}");
    }

    #[test]
    fn train_and_test_share_means() {
        // regression: the task identity (class means) must be frozen per
        // instance, not redrawn per sample call
        let inst = TaskKind::Tiny.spec().instantiate(9);
        let train = inst.sample_uniform(50, &mut Rng::new(1));
        let test = inst.sample_uniform(50, &mut Rng::new(2));
        // same-class samples across the two sets must be closer on average
        // than different-class ones
        let (mut same, mut diff, mut ns, mut nd) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..train.len() {
            for j in 0..test.len() {
                let d = vecmath::dist_sq(train.row(i), test.row(j));
                if train.y[i] == test.y[j] {
                    same += d;
                    ns += 1;
                } else {
                    diff += d;
                    nd += 1;
                }
            }
        }
        assert!(same / ns as f64 + 1.0 < diff / nd as f64);
    }

    #[test]
    fn cifarlike_harder_than_mnistlike() {
        let m = TaskKind::MnistLike.spec();
        let c = TaskKind::CifarLike.spec();
        assert!(c.sep / c.noise < m.sep / m.noise);
    }

    #[test]
    fn row_accessor() {
        let spec = TaskKind::Tiny.spec();
        let d = spec.instantiate(2).sample_uniform(10, &mut Rng::new(2));
        assert_eq!(d.row(3).len(), spec.dim);
        assert_eq!(d.row(0), &d.x[0..spec.dim]);
    }
}
