//! Hypergeometric distribution HG(total, marked, draws): the law of the
//! number of Byzantine nodes an honest node pulls in one round
//! (`b_i^t ~ HG(n−1, b, s)`, paper §4.2).
//!
//! Provides exact log-space PMF/CDF (stable up to the paper's Figure 3
//! scale of n = 100 000), a table-inversion sampler (O(log s) per draw —
//! the EAF simulator draws tens of millions of variates), quantiles, and
//! the KL tail bound of Lemma A.4.

use crate::util::rng::Rng;
use crate::util::special::{kl_bernoulli, ln_binom};

/// An immutable hypergeometric distribution with precomputed CDF table.
#[derive(Clone, Debug)]
pub struct Hypergeometric {
    pub total: u64,  // n − 1 in the paper (peers available to pull from)
    pub marked: u64, // b: Byzantine nodes
    pub draws: u64,  // s: sampled peers
    /// support is [lo, hi]
    lo: u64,
    hi: u64,
    /// cdf[k - lo] = P(X <= k)
    cdf: Vec<f64>,
}

impl Hypergeometric {
    pub fn new(total: u64, marked: u64, draws: u64) -> Self {
        assert!(marked <= total, "marked {marked} > total {total}");
        assert!(draws <= total, "draws {draws} > total {total}");
        let lo = draws.saturating_sub(total - marked);
        let hi = marked.min(draws);
        let denom = ln_binom(total, draws);
        let mut cdf = Vec::with_capacity((hi - lo + 1) as usize);
        let mut acc = 0.0f64;
        for k in lo..=hi {
            let lp = ln_binom(marked, k) + ln_binom(total - marked, draws - k) - denom;
            acc += lp.exp();
            cdf.push(acc.min(1.0));
        }
        // normalize tail rounding: force the last entry to exactly 1
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Hypergeometric {
            total,
            marked,
            draws,
            lo,
            hi,
            cdf,
        }
    }

    /// P(X = k).
    pub fn pmf(&self, k: u64) -> f64 {
        if k < self.lo || k > self.hi {
            return 0.0;
        }
        let lp = ln_binom(self.marked, k)
            + ln_binom(self.total - self.marked, self.draws - k)
            - ln_binom(self.total, self.draws);
        lp.exp()
    }

    /// P(X <= k).
    pub fn cdf(&self, k: u64) -> f64 {
        if k < self.lo {
            0.0
        } else if k >= self.hi {
            1.0
        } else {
            self.cdf[(k - self.lo) as usize]
        }
    }

    /// P(X >= k).
    pub fn sf_ge(&self, k: u64) -> f64 {
        if k <= self.lo {
            1.0
        } else if k > self.hi {
            0.0
        } else {
            (1.0 - self.cdf(k - 1)).max(0.0)
        }
    }

    /// Mean = draws * marked / total.
    pub fn mean(&self) -> f64 {
        self.draws as f64 * self.marked as f64 / self.total as f64
    }

    /// Smallest k with P(X <= k) >= q.
    pub fn quantile(&self, q: f64) -> u64 {
        debug_assert!((0.0..=1.0).contains(&q));
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&q).unwrap())
        {
            Ok(i) => self.lo + i as u64,
            Err(i) => self.lo + (i as u64).min(self.hi - self.lo),
        }
    }

    /// One draw via CDF-table inversion — O(log(support)).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => self.lo + i as u64 + 1, // u exactly on boundary: next value
            Err(i) => self.lo + (i as u64).min(self.hi - self.lo),
        }
    }

    /// Quantile of the **maximum** of `count` i.i.d. draws: smallest k with
    /// `P(X <= k)^count >= q`. The exact-analytic alternative to the
    /// paper's Algorithm 2 simulation (`count = |H| · T`).
    pub fn max_of_quantile(&self, count: u64, q: f64) -> u64 {
        debug_assert!(count > 0 && (0.0..=1.0).contains(&q));
        let target = q.powf(1.0 / count as f64);
        self.quantile(target)
    }

    /// Lemma A.4 / Lemma 13 (Allouah et al. 2024a) KL upper bound:
    /// `P(X >= bhat) <= exp(−s · D(bhat/s, b/(n−1)))`, valid for
    /// `bhat/s > b/(n−1)`.
    pub fn tail_bound_kl(&self, bhat: u64) -> f64 {
        let s = self.draws as f64;
        if s == 0.0 {
            return 1.0;
        }
        let alpha = bhat as f64 / s;
        let beta = self.marked as f64 / self.total as f64;
        if alpha <= beta {
            return 1.0; // bound not applicable below the mean
        }
        (-s * kl_bernoulli(alpha.min(1.0), beta)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        for &(n, b, s) in &[(29u64, 6u64, 15u64), (99, 10, 15), (19, 3, 6), (7, 7, 3)] {
            let hg = Hypergeometric::new(n, b, s);
            let total: f64 = (0..=s.min(b)).map(|k| hg.pmf(k)).sum();
            assert!((total - 1.0).abs() < 1e-9, "n={n} b={b} s={s} sum={total}");
        }
    }

    #[test]
    fn pmf_known_value() {
        // HG(total=10, marked=4, draws=3), P(X=2) = C(4,2)C(6,1)/C(10,3) = 36/120
        let hg = Hypergeometric::new(10, 4, 3);
        assert!((hg.pmf(2) - 0.3).abs() < 1e-12);
        assert!((hg.pmf(0) - 20.0 / 120.0).abs() < 1e-12);
        assert_eq!(hg.pmf(5), 0.0);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let hg = Hypergeometric::new(99, 10, 15);
        let mut prev = -1.0;
        for k in 0..=10 {
            let c = hg.cdf(k);
            assert!(c >= prev && (0.0..=1.0).contains(&c));
            prev = c;
        }
        assert_eq!(hg.cdf(10), 1.0);
    }

    #[test]
    fn support_truncation() {
        // draws > total - marked forces a minimum number of marked draws
        let hg = Hypergeometric::new(10, 8, 5);
        assert_eq!(hg.cdf(2), 0.0); // lo = 5 - 2 = 3
        assert!(hg.pmf(3) > 0.0);
        assert_eq!(hg.pmf(2), 0.0);
    }

    #[test]
    fn mean_formula() {
        let hg = Hypergeometric::new(99, 10, 15);
        assert!((hg.mean() - 15.0 * 10.0 / 99.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let hg = Hypergeometric::new(99, 10, 15);
        for q in [0.01, 0.5, 0.9, 0.999] {
            let k = hg.quantile(q);
            assert!(hg.cdf(k) >= q);
            if k > 0 {
                assert!(hg.cdf(k - 1) < q);
            }
        }
    }

    #[test]
    fn sampler_matches_pmf() {
        let hg = Hypergeometric::new(29, 6, 15);
        let mut rng = Rng::new(42);
        let n = 200_000;
        let mut counts = vec![0u32; 8];
        for _ in 0..n {
            counts[hg.sample(&mut rng) as usize] += 1;
        }
        for k in 0..=6u64 {
            let got = counts[k as usize] as f64 / n as f64;
            let want = hg.pmf(k);
            assert!(
                (got - want).abs() < 0.01,
                "k={k} got={got} want={want}"
            );
        }
    }

    #[test]
    fn sampler_agrees_with_sequential_rng_method() {
        let hg = Hypergeometric::new(99, 10, 15);
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mean_inv: f64 =
            (0..n).map(|_| hg.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        let mean_seq: f64 = (0..n)
            .map(|_| rng.hypergeometric(99, 10, 15) as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean_inv - mean_seq).abs() < 0.03, "{mean_inv} vs {mean_seq}");
    }

    #[test]
    fn kl_tail_bound_dominates_true_tail() {
        let hg = Hypergeometric::new(99, 10, 15);
        for bhat in 3..=10u64 {
            let bound = hg.tail_bound_kl(bhat);
            let true_tail = hg.sf_ge(bhat);
            assert!(
                bound + 1e-12 >= true_tail,
                "bhat={bhat} bound={bound} tail={true_tail}"
            );
        }
    }

    #[test]
    fn max_of_quantile_grows_with_count() {
        let hg = Hypergeometric::new(999, 100, 30);
        let q1 = hg.max_of_quantile(1, 0.99);
        let q2 = hg.max_of_quantile(100_000, 0.99);
        assert!(q2 >= q1);
        assert!(q2 <= 30);
    }

    #[test]
    fn large_scale_figure3_regime() {
        // n = 100 000, b = 10 000 (10%), s = 30: the paper's §6.3 claim is
        // that 30 neighbors suffice to keep an honest majority whp.
        let hg = Hypergeometric::new(99_999, 10_000, 30);
        // P(more than 15 of 30 sampled are Byzantine) must be astronomically small
        let p_no_majority = hg.sf_ge(16);
        assert!(p_no_majority < 1e-8, "p={p_no_majority}");
        // union over 80k honest nodes * 200 rounds: honest majority holds
        // for the whole training with high probability (paper §6.3)
        assert!(p_no_majority * 80_000.0 * 200.0 < 0.1);
    }
}
