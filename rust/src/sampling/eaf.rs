//! Effective-adversarial-fraction simulation — the paper's Algorithm 2 and
//! the engine behind Figure 3 (§6.3 scalability study).
//!
//! For each candidate `s`, draw `|H| · T` variates `b_i^t ~ HG(n−1, b, s)`,
//! take `b̂_s = max` over `m` independent simulations, and report the
//! Effective adversarial fraction `κ_s = b̂_s / (s+1)`.

use crate::sampling::hypergeometric::Hypergeometric;
use crate::util::rng::Rng;
use crate::util::stats;

/// One simulated grid point of Figure 3.
#[derive(Clone, Debug)]
pub struct EafPoint {
    pub n: u64,
    pub b: u64,
    pub s: u64,
    pub t: u64,
    /// max-selected attackers per simulation run
    pub bhat_runs: Vec<u64>,
    /// b̂ = max over runs (Algorithm 2 line 7)
    pub bhat: u64,
    /// κ_s = b̂ / (s+1) (Algorithm 2 line 8)
    pub eaf: f64,
    /// mean EAF across runs and its 95% CI half-width (the paper's bands)
    pub eaf_mean: f64,
    pub eaf_ci95: f64,
}

/// Algorithm 2 driver.
#[derive(Clone, Debug)]
pub struct EafSimulator {
    pub n: u64,
    pub b: u64,
    pub t: u64,
    /// number of independent simulations m (paper: 5)
    pub sims: usize,
}

/// Simulate `b̂ = max_{i∈H, t≤T} b_i^t` once (Algorithm 2 lines 4–5).
///
/// Instead of materializing `|H|·T` draws, walks them with the CDF-table
/// sampler; early-exits when the max hits the distribution's upper support
/// bound (nothing can exceed it).
pub fn simulate_bhat_max(hg: &Hypergeometric, count: u64, rng: &mut Rng) -> u64 {
    let hard_max = hg.marked.min(hg.draws);
    let mut best = 0u64;
    for _ in 0..count {
        let x = hg.sample(rng);
        if x > best {
            best = x;
            if best == hard_max {
                break;
            }
        }
    }
    best
}

impl EafSimulator {
    pub fn new(n: u64, b: u64, t: u64, sims: usize) -> Self {
        assert!(b < n, "need b < n");
        EafSimulator { n, b, t, sims }
    }

    /// Simulate one grid point for neighbor count `s`.
    pub fn point(&self, s: u64, rng: &mut Rng) -> EafPoint {
        assert!(s <= self.n - 1);
        let hg = Hypergeometric::new(self.n - 1, self.b, s);
        let honest = self.n - self.b;
        let count = honest * self.t;
        let bhat_runs: Vec<u64> = (0..self.sims)
            .map(|_| simulate_bhat_max(&hg, count, rng))
            .collect();
        let bhat = bhat_runs.iter().copied().max().unwrap_or(0);
        let fracs: Vec<f64> = bhat_runs
            .iter()
            .map(|&x| x as f64 / (s + 1) as f64)
            .collect();
        EafPoint {
            n: self.n,
            b: self.b,
            s,
            t: self.t,
            bhat,
            eaf: bhat as f64 / (s + 1) as f64,
            eaf_mean: stats::mean(&fracs),
            eaf_ci95: stats::ci95_half_width(&fracs),
            bhat_runs,
        }
    }

    /// Sweep a grid of s values (Figure 3's x-axis).
    pub fn sweep(&self, grid: &[u64], rng: &mut Rng) -> Vec<EafPoint> {
        grid.iter().map(|&s| self.point(s, rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bhat_max_bounded_by_support() {
        let hg = Hypergeometric::new(29, 6, 15);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let m = simulate_bhat_max(&hg, 1000, &mut rng);
            assert!(m <= 6);
        }
    }

    #[test]
    fn bhat_max_increases_with_count() {
        let hg = Hypergeometric::new(999, 100, 20);
        let mut rng = Rng::new(2);
        let avg = |count: u64, rng: &mut Rng| -> f64 {
            (0..30)
                .map(|_| simulate_bhat_max(&hg, count, rng) as f64)
                .sum::<f64>()
                / 30.0
        };
        let small = avg(10, &mut rng);
        let large = avg(10_000, &mut rng);
        assert!(large > small, "small={small} large={large}");
    }

    #[test]
    fn eaf_decreases_with_s() {
        // the paper's headline monotonicity: larger s -> smaller EAF
        let sim = EafSimulator::new(1_000, 100, 50, 3);
        let mut rng = Rng::new(3);
        let pts = sim.sweep(&[20, 60, 200, 600], &mut rng);
        for w in pts.windows(2) {
            assert!(
                w[1].eaf <= w[0].eaf + 0.02,
                "EAF should not grow: {} (s={}) -> {} (s={})",
                w[0].eaf,
                w[0].s,
                w[1].eaf,
                w[1].s
            );
        }
    }

    #[test]
    fn all_to_all_eaf_is_exact_fraction() {
        // s = n-1 pulls everyone: b̂ = b exactly, EAF = b/n
        let sim = EafSimulator::new(30, 6, 10, 2);
        let mut rng = Rng::new(4);
        let p = sim.point(29, &mut rng);
        assert_eq!(p.bhat, 6);
        assert!((p.eaf - 0.2).abs() < 1e-12);
    }

    #[test]
    fn paper_fig1_left_setting() {
        // n=100, b=10, s=15, T=200: the paper reports b̂=7 (EAF ≈ 0.44)
        let sim = EafSimulator::new(100, 10, 200, 5);
        let mut rng = Rng::new(5);
        let p = sim.point(15, &mut rng);
        assert!(
            (6..=9).contains(&p.bhat),
            "paper found b̂=7 for this setting; got {}",
            p.bhat
        );
        assert!(p.eaf < 0.5 + 1e-9);
    }

    #[test]
    fn ci_fields_populated() {
        let sim = EafSimulator::new(200, 20, 20, 5);
        let mut rng = Rng::new(6);
        let p = sim.point(12, &mut rng);
        assert_eq!(p.bhat_runs.len(), 5);
        assert!(p.eaf_mean > 0.0);
        assert!(p.eaf_ci95 >= 0.0);
        assert!(p.eaf >= p.eaf_mean); // max >= mean
    }
}
