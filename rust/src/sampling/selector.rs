//! Hyper-parameter selection for (s, b̂) — the paper's §6.1 methodology.
//!
//! Three entry points:
//!
//!  * [`lemma41_min_s`]     — Equation (3): the sufficient log-scaling
//!                            sample size of Lemma 4.1.
//!  * [`lemma_a4_threshold`]— Equation (7): the KL-divergence sufficient
//!                            condition on (s, b̂) of Lemma A.4.
//!  * [`select_params`]     — Algorithm 2: the practical simulation-based
//!                            grid search the experiments actually use
//!                            ("pick the smallest s whose simulated EAF is
//!                            below the target q").

use crate::sampling::eaf::EafSimulator;
use crate::sampling::hypergeometric::Hypergeometric;
use crate::util::rng::Rng;
use crate::util::special::kl_bernoulli;

/// Result of Algorithm 2 / the theoretical threshold checks.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub s: u64,
    pub bhat: u64,
    /// Effective adversarial fraction b̂/(s+1)
    pub eaf: f64,
}

/// Lemma 4.1, Equation (3): minimum s guaranteeing that some b̂ exists with
/// `Γ` holding w.p. ≥ p and `b̂/(s+1) = O(b/n)`:
///
/// `s ≥ ⌈ max{ 1/(1/2 − b/n)², 3/(b/n) } · ln(4·T·|H| / (1−p)) ⌉ + 2`
pub fn lemma41_min_s(n: u64, b: u64, t: u64, p: f64) -> u64 {
    assert!(b > 0 && b < n / 2, "requires 0 < b < n/2");
    assert!((0.0..1.0).contains(&p));
    let frac = b as f64 / n as f64;
    let honest = (n - b) as f64;
    let factor = (1.0 / (0.5 - frac).powi(2)).max(3.0 / frac);
    let log_term = (4.0 * t as f64 * honest / (1.0 - p)).ln();
    (factor * log_term).ceil() as u64 + 2
}

/// Lemma A.4, Equation (7): check whether `(s, b̂)` satisfies the
/// sufficient condition
/// `s ≥ min{ n−1, D(b̂/s, b/(n−1))⁻¹ · ln(T·|H|/(1−p)) }`
/// together with the sandwich `b/n < b̂/(s+1) < 1/2`.
pub fn lemma_a4_threshold(n: u64, b: u64, t: u64, p: f64, s: u64, bhat: u64) -> bool {
    assert!((0.0..1.0).contains(&p));
    if s == 0 || s > n - 1 {
        return false;
    }
    let eaf = bhat as f64 / (s + 1) as f64;
    let frac = b as f64 / n as f64;
    if !(eaf > frac && eaf < 0.5) {
        return false;
    }
    if s == n - 1 {
        // sampling everyone: b̂ = b deterministically
        return bhat >= b;
    }
    let alpha = bhat as f64 / s as f64;
    let beta = b as f64 / (n - 1) as f64;
    if alpha <= beta {
        return false;
    }
    let d = kl_bernoulli(alpha.min(1.0), beta);
    if d <= 0.0 {
        return false;
    }
    let honest = (n - b) as f64;
    let needed = (t as f64 * honest / (1.0 - p)).ln() / d;
    s as f64 >= needed
}

/// For a given s, the smallest b̂ for which Lemma A.4's condition holds
/// (None if no b̂ < (s+1)/2 works).
pub fn lemma_a4_min_bhat(n: u64, b: u64, t: u64, p: f64, s: u64) -> Option<u64> {
    (1..=s)
        .find(|&bhat| lemma_a4_threshold(n, b, t, p, s, bhat))
        .filter(|&bhat| (bhat as f64) / (s as f64 + 1.0) < 0.5)
}

/// Algorithm 2 (Appendix B): simulation-based hyper-parameter selection.
///
/// For each s in `grid` (ascending), simulate `m` runs of
/// `b̂_s = max_{i∈H,t≤T} b_i^t`, set `κ_s = b̂_s/(s+1)`, and return the
/// smallest s with `κ_s ≤ q`. Returns None when the grid is exhausted
/// (Remark 1: including s = n−1 in the grid guarantees a solution whenever
/// `b/n ≤ q`).
pub fn select_params(
    n: u64,
    b: u64,
    t: u64,
    grid: &[u64],
    sims: usize,
    q: f64,
    rng: &mut Rng,
) -> Option<Selection> {
    assert!(q < 0.5, "the aggregation breakdown point is 1/2");
    let sim = EafSimulator::new(n, b.max(0), t, sims);
    let mut sorted: Vec<u64> = grid.to_vec();
    sorted.sort_unstable();
    for s in sorted {
        if s == 0 || s > n - 1 {
            continue;
        }
        if b == 0 {
            return Some(Selection { s, bhat: 0, eaf: 0.0 });
        }
        let point = sim.point(s, rng);
        if point.eaf <= q {
            return Some(Selection {
                s,
                bhat: point.bhat,
                eaf: point.eaf,
            });
        }
    }
    None
}

/// Exact-analytic variant: choose b̂ as the q-quantile of the max of
/// |H|·T hypergeometric draws (Appendix B, Remark 2's "more precise
/// method", implemented with the closed-form CDF instead of an empirical
/// one). Used by ablation benches to validate Algorithm 2.
pub fn select_bhat_exact(n: u64, b: u64, t: u64, s: u64, confidence: f64) -> u64 {
    if b == 0 {
        return 0;
    }
    let hg = Hypergeometric::new(n - 1, b, s);
    let honest = n - b;
    hg.max_of_quantile(honest * t, confidence)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma41_logarithmic_in_n() {
        // fixing b/n, s should grow ~log n
        let s1 = lemma41_min_s(1_000, 100, 200, 0.99);
        let s2 = lemma41_min_s(100_000, 10_000, 200, 0.99);
        assert!(s2 > s1);
        // ratio of the log terms is << ratio of n
        assert!((s2 as f64 / s1 as f64) < 3.0, "s1={s1} s2={s2}");
    }

    #[test]
    fn lemma41_grows_with_confidence() {
        let lo = lemma41_min_s(100, 10, 200, 0.9);
        let hi = lemma41_min_s(100, 10, 200, 0.999);
        assert!(hi >= lo);
    }

    #[test]
    #[should_panic]
    fn lemma41_rejects_majority_byzantine() {
        lemma41_min_s(10, 5, 10, 0.9);
    }

    #[test]
    fn lemma_a4_scaling_preserves_feasibility() {
        // if (s, bhat) passes Eq. (7), doubling both (same ratio b̂/s, so
        // the same KL exponent with larger s) must also pass
        let (n, b, t, p) = (1_000, 100, 200, 0.9);
        let mut found = None;
        for s in 10..400u64 {
            let bhat = ((s + 1) as f64 * 0.45) as u64;
            if lemma_a4_threshold(n, b, t, p, s, bhat) {
                found = Some((s, bhat));
                break;
            }
        }
        let (s0, b0) = found.expect("some (s, b̂) must satisfy Eq. (7)");
        assert!(lemma_a4_threshold(n, b, t, p, 2 * s0, 2 * b0));
    }

    #[test]
    fn lemma_a4_rejects_eaf_above_half() {
        assert!(!lemma_a4_threshold(100, 10, 200, 0.9, 15, 8)); // 8/16 = 0.5
        assert!(!lemma_a4_threshold(100, 10, 200, 0.9, 15, 12));
    }

    #[test]
    fn lemma_a4_rejects_eaf_below_true_fraction() {
        // b̂/(s+1) must exceed b/n
        assert!(!lemma_a4_threshold(100, 10, 200, 0.9, 15, 1));
    }

    #[test]
    fn lemma_a4_min_bhat_is_minimal() {
        let (n, b, t, p) = (1_000, 100, 200, 0.9);
        // pick s large enough to have a feasible bhat
        let s = 400;
        if let Some(bh) = lemma_a4_min_bhat(n, b, t, p, s) {
            assert!(lemma_a4_threshold(n, b, t, p, s, bh));
            assert!(!lemma_a4_threshold(n, b, t, p, s, bh - 1));
        } else {
            panic!("expected feasible bhat at s={s}");
        }
    }

    #[test]
    fn algorithm2_returns_smallest_feasible_s() {
        let mut rng = Rng::new(9);
        let grid: Vec<u64> = (5..30).collect();
        let sel = select_params(100, 10, 200, &grid, 5, 0.49, &mut rng).unwrap();
        assert!(grid.contains(&sel.s));
        assert!(sel.eaf <= 0.49);
        // paper: s=15 has EAF ≈ 0.44 for this setting, so selection ≤ 15-ish
        assert!(sel.s <= 18, "selected s={}", sel.s);
    }

    #[test]
    fn algorithm2_remark1_all_to_all_fallback() {
        // with s = n−1 in the grid and q >= b/n, a solution always exists
        let mut rng = Rng::new(10);
        let sel = select_params(30, 6, 200, &[29], 3, 0.21, &mut rng).unwrap();
        assert_eq!(sel.s, 29);
        assert_eq!(sel.bhat, 6);
    }

    #[test]
    fn algorithm2_no_attackers() {
        let mut rng = Rng::new(11);
        let sel = select_params(50, 0, 100, &[4, 8], 3, 0.4, &mut rng).unwrap();
        assert_eq!(sel.bhat, 0);
        assert_eq!(sel.s, 4);
    }

    #[test]
    fn algorithm2_infeasible_grid_returns_none() {
        let mut rng = Rng::new(12);
        // 40% Byzantine, tiny s: EAF can't reach below 0.405 with s=2
        let sel = select_params(10, 4, 1_000, &[2], 5, 0.405, &mut rng);
        assert!(sel.is_none());
    }

    #[test]
    fn exact_bhat_close_to_simulated() {
        let mut rng = Rng::new(13);
        let sim = EafSimulator::new(100, 10, 200, 5);
        let p = sim.point(15, &mut rng);
        let exact = select_bhat_exact(100, 10, 200, 15, 0.99);
        assert!(
            (p.bhat as i64 - exact as i64).abs() <= 2,
            "sim={} exact={exact}",
            p.bhat
        );
    }

    #[test]
    fn exact_bhat_monotone_in_t() {
        let a = select_bhat_exact(100, 10, 10, 15, 0.99);
        let b = select_bhat_exact(100, 10, 10_000, 15, 0.99);
        assert!(b >= a);
    }
}
