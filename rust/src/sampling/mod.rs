//! The paper's §4.2 machinery: hypergeometric distribution substrate,
//! Effective-adversarial-fraction simulation (Algorithm 2), and the
//! theoretical sampling thresholds (Lemma 4.1 / Lemma A.4).

pub mod eaf;
pub mod hypergeometric;
pub mod selector;

pub use eaf::{simulate_bhat_max, EafPoint, EafSimulator};
pub use hypergeometric::Hypergeometric;
pub use selector::{lemma41_min_s, lemma_a4_threshold, select_params, Selection};
