//! Sign-Flipping attack (Li et al. 2020).
//!
//! The honest population moves by u = x̄_H^{t+1/2} − x̄_H^t this round; the
//! attacker reports the model that moves by −γ·u instead, i.e.
//! `mal = x̄_H^t − γ (x̄_H^{t+1/2} − x̄_H^t)`. With γ = 1 this is the exact
//! mirrored update; the published attack scales the flip (γ > 1) so that a
//! Byzantine *minority* can stall or reverse a plain average — with γ = 1
//! and b/m < 1/2 the poisoned mean still moves forward by
//! (h − b)/m · u and the attack is toothless. Default γ = 4 (the
//! magnitude range used by Li et al. 2020 / Karimireddy et al. 2020).
//!
//! Both means come from the per-round [`HonestDigest`]; crafting is O(d)
//! per victim and identical for every Byzantine identity.

use super::{Attack, AttackContext};

#[derive(Clone, Copy, Debug)]
pub struct SignFlip {
    /// flip magnitude γ
    pub gamma: f32,
}

impl Default for SignFlip {
    fn default() -> Self {
        SignFlip { gamma: 4.0 }
    }
}

impl Attack for SignFlip {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]) {
        let gamma = self.gamma as f64;
        let Some((first, rest)) = out.split_first_mut() else {
            return;
        };
        for ((o, &mu), &prev) in first
            .iter_mut()
            .zip(ctx.digest.mean.iter())
            .zip(ctx.digest.prev_mean.iter())
        {
            let update = mu - prev;
            *o = (prev - gamma * update) as f32;
        }
        for row in rest {
            row.copy_from_slice(first);
        }
    }

    fn name(&self) -> &'static str {
        "sf"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;

    #[test]
    fn mirrors_the_honest_update() {
        let f = Fixture::new(4);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let ctx = f.ctx(0, &refs[..2], 7, 2);
        let mut out = vec![vec![0.0f32; 4]; 2];
        SignFlip { gamma: 1.0 }.craft(&ctx, &mut out);
        for row in &out {
            for j in 0..4 {
                let u = f.mean32(j) - f.prev_mean32(j);
                assert!((row[j] - (f.prev_mean32(j) - u)).abs() < 1e-5);
            }
        }
        // both malicious copies identical for SF (direction attack)
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn opposes_honest_direction() {
        let f = Fixture::new(3);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let ctx = f.ctx(0, &refs, 6, 1);
        let mut out = vec![vec![0.0f32; 3]];
        SignFlip::default().craft(&ctx, &mut out);
        // inner product of (mal - prev_mean) with (mean - prev_mean) < 0
        let mut ip = 0.0f64;
        for j in 0..3 {
            ip += (out[0][j] as f64 - f.digest.prev_mean[j])
                * (f.digest.mean[j] - f.digest.prev_mean[j]);
        }
        assert!(ip < 0.0);
    }
}
