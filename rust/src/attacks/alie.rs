//! A Little Is Enough (Baruch et al. 2019).
//!
//! The attacker stays *inside the variance envelope* of honest updates:
//! `mal_j = μ_j − z_max · σ_j` per coordinate, where μ, σ are the honest
//! coordinate-wise mean and std, and z_max is the largest deviation that
//! still leaves the malicious value "covered" by enough honest points:
//!
//!   s_idx = ⌊n/2 + 1⌋ − b,    φ = (n − b − s_idx) / (n − b),
//!   z_max = Φ⁻¹(max(φ, φ_min)).
//!
//! Small, coordinated perturbations beat distance-based defenses that
//! huge outliers (SF) cannot.
//!
//! μ and σ come from the per-round [`HonestDigest`], so crafting is O(d)
//! per victim. The engine used to hand each victim a borrow of *all*
//! honest half-steps and this attack rescanned them per coordinate — an
//! O(h²·d) round cost that dominated large-n runs.

use super::{Attack, AttackContext};
use crate::util::special::inverse_normal_cdf;

#[derive(Clone, Copy, Debug, Default)]
pub struct Alie {
    /// Optional manual z override (None = Baruch formula).
    pub z: Option<f32>,
}

impl Alie {
    /// z_max from the Baruch et al. supporters formula.
    pub fn z_max(n: usize, b: usize) -> f32 {
        if n <= b {
            return 1.0;
        }
        let honest = (n - b) as f64;
        let s_idx = (n as f64 / 2.0 + 1.0).floor() - b as f64;
        let phi = ((honest - s_idx) / honest).clamp(1e-6, 1.0 - 1e-6);
        // guard: for tiny b the formula can give phi < 0.5 (z < 0); the
        // published attack uses the positive tail
        inverse_normal_cdf(phi.max(0.5 + 1e-6)) as f32
    }
}

impl Attack for Alie {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]) {
        let z = self.z.unwrap_or_else(|| Self::z_max(ctx.n, ctx.b)).max(0.05) as f64;
        let Some((first, rest)) = out.split_first_mut() else {
            return;
        };
        for ((o, &mu), &sigma) in first
            .iter_mut()
            .zip(ctx.digest.mean.iter())
            .zip(ctx.digest.std.iter())
        {
            *o = (mu - z * sigma) as f32;
        }
        // every Byzantine identity reports the same envelope point
        for row in rest {
            row.copy_from_slice(first);
        }
    }

    fn name(&self) -> &'static str {
        "alie"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;

    #[test]
    fn z_max_reasonable_range() {
        // paper settings
        for (n, b) in [(100usize, 10usize), (30, 6), (20, 3)] {
            let z = Alie::z_max(n, b);
            assert!(z > 0.0 && z < 4.0, "n={n} b={b} z={z}");
        }
    }

    #[test]
    fn z_grows_with_byzantine_fraction() {
        assert!(Alie::z_max(100, 20) > Alie::z_max(100, 2));
    }

    #[test]
    fn stays_within_envelope() {
        let f = Fixture::new(6);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let ctx = f.ctx(0, &refs[..3], 7, 2);
        let mut out = vec![vec![0.0f32; 6]; 2];
        Alie::default().craft(&ctx, &mut out);
        // per coordinate the malicious value is within ~4 sigma of the mean
        for j in 0..6 {
            let mu = f.digest.mean[j];
            let sigma = f.digest.std[j];
            let dev = (out[0][j] as f64 - mu).abs();
            assert!(dev <= 4.0 * sigma + 1e-9, "j={j} dev={dev} sigma={sigma}");
            // and it actually deviates (non-trivial attack)
            assert!(dev > 0.0);
        }
        // all Byzantine rows identical (coordinated attack)
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn manual_z_override() {
        let f = Fixture::new(2);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let ctx = f.ctx(0, &refs, 7, 2);
        let mut small = vec![vec![0.0f32; 2]];
        let mut large = vec![vec![0.0f32; 2]];
        Alie { z: Some(0.1) }.craft(&ctx, &mut small);
        Alie { z: Some(3.0) }.craft(&ctx, &mut large);
        for j in 0..2 {
            assert!(
                (small[0][j] - f.mean32(j)).abs() < (large[0][j] - f.mean32(j)).abs() + 1e-9
            );
        }
    }
}
