//! The omniscient Byzantine adversary engine (paper §3.2, §6.1).
//!
//! The threat model is maximal: the adversary controls b nodes, knows every
//! honest update, knows which of its nodes each victim sampled this round,
//! and may send **different** malicious vectors to different victims within
//! the same iteration. Accordingly, [`Attack::craft`] is invoked once per
//! (victim, round) with full visibility into the honest state, after the
//! honest half-steps are computed and the pull sets are drawn — exactly the
//! information an omniscient attacker has in the paper.
//!
//! Implemented state-of-the-art attacks (§6.1 + Appendix C.2):
//!
//! * [`SignFlip`]  — flip the direction of the mean honest update
//!   (Li et al. 2020).
//! * [`Foe`]       — Fall of Empires: inner-product manipulation, sends a
//!   small negative multiple of the honest update (Xie et al. 2020).
//! * [`Alie`]      — A Little Is Enough: stays z_max standard deviations
//!   from the coordinate-wise honest mean, inside the variance envelope
//!   (Baruch et al. 2019).
//! * [`Dissensus`] — pushes each victim *away* from its neighborhood
//!   consensus direction (He et al. 2022, tailored to gossip updates).

pub mod alie;
pub mod dissensus;
pub mod foe;
pub mod sign_flip;

pub use alie::Alie;
pub use dissensus::Dissensus;
pub use foe::Foe;
pub use sign_flip::SignFlip;

/// Everything the omniscient adversary sees when attacking one victim in
/// one round.
pub struct AttackContext<'a> {
    /// The victim's own half-step model x_i^{t+1/2}.
    pub victim_half: &'a [f32],
    /// The victim's model at the start of the round, x_i^t.
    pub victim_prev: &'a [f32],
    /// Honest half-step models the victim actually pulled this round.
    pub honest_received: &'a [&'a [f32]],
    /// All honest half-step models in the system (omniscience).
    pub honest_all: &'a [&'a [f32]],
    /// Coordinate-wise mean of all honest half-steps (precomputed once per
    /// round by the coordinator — every attack uses it).
    pub honest_mean: &'a [f32],
    /// Coordinate-wise mean of the honest models at round start.
    pub honest_prev_mean: &'a [f32],
    /// Total nodes / Byzantine nodes (for ALIE's z_max).
    pub n: usize,
    pub b: usize,
}

/// A Byzantine attack: craft `count` malicious models for this victim.
///
/// `out` arrives as `count` preallocated rows of length d; the attack
/// overwrites them (no allocation on the round path). `Send + Sync`: the
/// parallel round engine crafts per-victim payloads from worker threads
/// against one shared attack instance.
pub trait Attack: Send + Sync {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]);
    fn name(&self) -> &'static str;
}

/// Named attack selection for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AttackKind {
    None,
    SignFlip,
    Foe,
    Alie,
    Dissensus,
    /// Denial of service (paper Appendix D): Byzantine nodes withhold
    /// their model when pulled. Under the synchronous model the
    /// coordinator simply proceeds with the honest responses — the
    /// appendix's argument that pull + synchrony neutralizes DoS.
    Dos,
}

impl AttackKind {
    pub fn parse(s: &str) -> Option<AttackKind> {
        Some(match s {
            "none" | "no_attack" => AttackKind::None,
            "sf" | "sign_flip" | "signflip" => AttackKind::SignFlip,
            "foe" | "fall_of_empires" => AttackKind::Foe,
            "alie" | "a_little_is_enough" => AttackKind::Alie,
            "dissensus" => AttackKind::Dissensus,
            "dos" | "denial_of_service" => AttackKind::Dos,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::None => "none",
            AttackKind::SignFlip => "sf",
            AttackKind::Foe => "foe",
            AttackKind::Alie => "alie",
            AttackKind::Dissensus => "dissensus",
            AttackKind::Dos => "dos",
        }
    }

    /// Build the attack with paper-default strengths. Returns None for
    /// `AttackKind::None` and `AttackKind::Dos` (nothing to craft — DoS is
    /// a withholding behavior the coordinator models by dropping rows).
    pub fn build(&self) -> Option<Box<dyn Attack>> {
        match self {
            AttackKind::None | AttackKind::Dos => None,
            AttackKind::SignFlip => Some(Box::new(SignFlip::default())),
            AttackKind::Foe => Some(Box::new(Foe::default())),
            AttackKind::Alie => Some(Box::new(Alie::default())),
            AttackKind::Dissensus => Some(Box::new(Dissensus::default())),
        }
    }

    /// All attacks a figure sweeps over (the paper's standard panel).
    pub fn panel() -> [AttackKind; 4] {
        [
            AttackKind::SignFlip,
            AttackKind::Foe,
            AttackKind::Alie,
            AttackKind::Dissensus,
        ]
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    /// Build a small honest population + context views for attack tests.
    pub struct Fixture {
        pub honest: Vec<Vec<f32>>,
        pub prev: Vec<Vec<f32>>,
        pub mean: Vec<f32>,
        pub prev_mean: Vec<f32>,
    }

    impl Fixture {
        pub fn new(d: usize) -> Self {
            let honest: Vec<Vec<f32>> = (0..5)
                .map(|i| (0..d).map(|j| (i as f32) * 0.1 + j as f32).collect())
                .collect();
            let prev: Vec<Vec<f32>> = (0..5)
                .map(|i| (0..d).map(|j| (i as f32) * 0.1 + j as f32 + 1.0).collect())
                .collect();
            let mut mean = vec![0.0f32; d];
            let mut prev_mean = vec![0.0f32; d];
            for j in 0..d {
                mean[j] = honest.iter().map(|h| h[j]).sum::<f32>() / 5.0;
                prev_mean[j] = prev.iter().map(|h| h[j]).sum::<f32>() / 5.0;
            }
            Fixture {
                honest,
                prev,
                mean,
                prev_mean,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            AttackKind::None,
            AttackKind::SignFlip,
            AttackKind::Foe,
            AttackKind::Alie,
            AttackKind::Dissensus,
        ] {
            assert_eq!(AttackKind::parse(k.name()), Some(k));
        }
        assert_eq!(AttackKind::parse("zzz"), None);
    }

    #[test]
    fn none_builds_nothing() {
        assert!(AttackKind::None.build().is_none());
        assert!(AttackKind::Alie.build().is_some());
    }

    #[test]
    fn panel_has_all_four() {
        assert_eq!(AttackKind::panel().len(), 4);
    }
}
