//! The omniscient Byzantine adversary engine (paper §3.2, §6.1).
//!
//! The threat model is maximal: the adversary controls b nodes, knows every
//! honest update, knows which of its nodes each victim sampled this round,
//! and may send **different** malicious vectors to different victims within
//! the same iteration. Accordingly, [`Attack::craft`] is invoked once per
//! (victim, round) with full visibility into the honest state, after the
//! honest half-steps are computed and the pull sets are drawn — exactly the
//! information an omniscient attacker has in the paper.
//!
//! Implemented state-of-the-art attacks (§6.1 + Appendix C.2):
//!
//! * [`SignFlip`]  — flip the direction of the mean honest update
//!   (Li et al. 2020).
//! * [`Foe`]       — Fall of Empires: inner-product manipulation, sends a
//!   small negative multiple of the honest update (Xie et al. 2020).
//! * [`Alie`]      — A Little Is Enough: stays z_max standard deviations
//!   from the coordinate-wise honest mean, inside the variance envelope
//!   (Baruch et al. 2019).
//! * [`Dissensus`] — pushes each victim *away* from its neighborhood
//!   consensus direction (He et al. 2022, tailored to gossip updates).

pub mod alie;
pub mod dissensus;
pub mod foe;
pub mod sign_flip;

pub use alie::Alie;
pub use dissensus::Dissensus;
pub use foe::Foe;
pub use sign_flip::SignFlip;

/// Per-round digest of the honest population — everything the implemented
/// attacks need from omniscience, reduced to O(d) state.
///
/// The coordinator computes this **once per round** (phase 2) by folding
/// every honest half-step in ascending honest-node order with f64
/// accumulators, so the digest — and therefore every crafted vector — is
/// bit-identical for any shard partitioning and any worker count. Crafting
/// against the digest costs O(d) per victim; the engine never hands an
/// attack a borrow of all honest rows (the removed `honest_all`), which is
/// what used to make ALIE an O(h²·d) round and capped n near 10³.
#[derive(Clone, Debug, Default)]
pub struct HonestDigest {
    /// Number of honest half-steps folded in.
    pub count: usize,
    /// Coordinate-wise mean of all honest half-steps x̄_H^{t+1/2}.
    pub mean: Vec<f64>,
    /// Coordinate-wise population standard deviation of the half-steps
    /// (σ_j = √(Σ(x−μ)²/count), the normalization ALIE's envelope uses).
    pub std: Vec<f64>,
    /// Coordinate-wise mean of the honest round-start models x̄_H^t.
    pub prev_mean: Vec<f64>,
}

impl HonestDigest {
    /// Empty digest with zeroed d-length buffers (reused across rounds).
    pub fn new(d: usize) -> HonestDigest {
        HonestDigest {
            count: 0,
            mean: vec![0.0; d],
            std: vec![0.0; d],
            prev_mean: vec![0.0; d],
        }
    }

    /// Recompute in place from the round's honest half-steps and the
    /// corresponding round-start params, folding rows in the order given
    /// (the coordinator passes ascending honest-node order). Two-pass
    /// moments in f64: exact enough that shard boundaries are invisible.
    ///
    /// `with_std = false` skips the second O(h·d) variance pass and leaves
    /// `std` zeroed — ALIE is the only consumer of σ, so the coordinator
    /// requests it only for that attack.
    pub fn recompute(&mut self, halves: &[&[f32]], prevs: &[&[f32]], with_std: bool) {
        debug_assert_eq!(halves.len(), prevs.len());
        self.count = halves.len();
        self.mean.fill(0.0);
        self.prev_mean.fill(0.0);
        self.std.fill(0.0);
        if self.count == 0 {
            return;
        }
        for row in halves {
            for (acc, &x) in self.mean.iter_mut().zip(row.iter()) {
                *acc += x as f64;
            }
        }
        for row in prevs {
            for (acc, &x) in self.prev_mean.iter_mut().zip(row.iter()) {
                *acc += x as f64;
            }
        }
        let inv = 1.0 / self.count as f64;
        for acc in self.mean.iter_mut() {
            *acc *= inv;
        }
        for acc in self.prev_mean.iter_mut() {
            *acc *= inv;
        }
        if !with_std {
            return;
        }
        for row in halves {
            for ((acc, &mu), &x) in self.std.iter_mut().zip(self.mean.iter()).zip(row.iter()) {
                let dlt = x as f64 - mu;
                *acc += dlt * dlt;
            }
        }
        for acc in self.std.iter_mut() {
            *acc = (*acc * inv).sqrt();
        }
    }

    /// One-shot construction with all moments (tests/fixtures).
    pub fn compute(halves: &[&[f32]], prevs: &[&[f32]]) -> HonestDigest {
        let d = halves.first().map_or(0, |r| r.len());
        let mut digest = HonestDigest::new(d);
        digest.recompute(halves, prevs, true);
        digest
    }
}

/// Everything the omniscient adversary sees when attacking one victim in
/// one round.
pub struct AttackContext<'a> {
    /// The victim's own half-step model x_i^{t+1/2}.
    pub victim_half: &'a [f32],
    /// The victim's model at the start of the round, x_i^t.
    pub victim_prev: &'a [f32],
    /// Honest half-step models the victim actually pulled this round —
    /// the only raw rows an attack ever sees.
    pub honest_received: &'a [&'a [f32]],
    /// O(d) digest of the whole honest population (omniscience, without
    /// materializing it per victim).
    pub digest: &'a HonestDigest,
    /// Total nodes / Byzantine nodes (for ALIE's z_max).
    pub n: usize,
    pub b: usize,
}

/// A Byzantine attack: craft `count` malicious models for this victim.
///
/// `out` arrives as `count` preallocated rows of length d; the attack
/// overwrites them (no allocation on the round path). `Send + Sync`: the
/// parallel round engine crafts per-victim payloads from worker threads
/// against one shared attack instance.
pub trait Attack: Send + Sync {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]);
    fn name(&self) -> &'static str;
}

/// Named attack selection for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AttackKind {
    None,
    SignFlip,
    Foe,
    Alie,
    Dissensus,
    /// Denial of service (paper Appendix D): Byzantine nodes withhold
    /// their model when pulled. Under the synchronous model the
    /// coordinator simply proceeds with the honest responses — the
    /// appendix's argument that pull + synchrony neutralizes DoS.
    Dos,
}

impl AttackKind {
    pub fn parse(s: &str) -> Option<AttackKind> {
        Some(match s {
            "none" | "no_attack" => AttackKind::None,
            "sf" | "sign_flip" | "signflip" => AttackKind::SignFlip,
            "foe" | "fall_of_empires" => AttackKind::Foe,
            "alie" | "a_little_is_enough" => AttackKind::Alie,
            "dissensus" => AttackKind::Dissensus,
            "dos" | "denial_of_service" => AttackKind::Dos,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::None => "none",
            AttackKind::SignFlip => "sf",
            AttackKind::Foe => "foe",
            AttackKind::Alie => "alie",
            AttackKind::Dissensus => "dissensus",
            AttackKind::Dos => "dos",
        }
    }

    /// Build the attack with paper-default strengths. Returns None for
    /// `AttackKind::None` and `AttackKind::Dos` (nothing to craft — DoS is
    /// a withholding behavior the coordinator models by dropping rows).
    pub fn build(&self) -> Option<Box<dyn Attack>> {
        match self {
            AttackKind::None | AttackKind::Dos => None,
            AttackKind::SignFlip => Some(Box::new(SignFlip::default())),
            AttackKind::Foe => Some(Box::new(Foe::default())),
            AttackKind::Alie => Some(Box::new(Alie::default())),
            AttackKind::Dissensus => Some(Box::new(Dissensus::default())),
        }
    }

    /// All attacks a figure sweeps over (the paper's standard panel).
    pub fn panel() -> [AttackKind; 4] {
        [
            AttackKind::SignFlip,
            AttackKind::Foe,
            AttackKind::Alie,
            AttackKind::Dissensus,
        ]
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::{AttackContext, HonestDigest};

    /// Build a small honest population + digest for attack tests.
    pub struct Fixture {
        pub honest: Vec<Vec<f32>>,
        pub prev: Vec<Vec<f32>>,
        pub digest: HonestDigest,
    }

    impl Fixture {
        pub fn new(d: usize) -> Self {
            let honest: Vec<Vec<f32>> = (0..5)
                .map(|i| (0..d).map(|j| (i as f32) * 0.1 + j as f32).collect())
                .collect();
            let prev: Vec<Vec<f32>> = (0..5)
                .map(|i| (0..d).map(|j| (i as f32) * 0.1 + j as f32 + 1.0).collect())
                .collect();
            let halves: Vec<&[f32]> = honest.iter().map(|v| v.as_slice()).collect();
            let prevs: Vec<&[f32]> = prev.iter().map(|v| v.as_slice()).collect();
            let digest = HonestDigest::compute(&halves, &prevs);
            Fixture {
                honest,
                prev,
                digest,
            }
        }

        /// f32 view of the digest mean (what tests compare rows against).
        pub fn mean32(&self, j: usize) -> f32 {
            self.digest.mean[j] as f32
        }

        pub fn prev_mean32(&self, j: usize) -> f32 {
            self.digest.prev_mean[j] as f32
        }

        /// Context for one victim that received `received` honest rows.
        pub fn ctx<'a>(
            &'a self,
            victim: usize,
            received: &'a [&'a [f32]],
            n: usize,
            b: usize,
        ) -> AttackContext<'a> {
            AttackContext {
                victim_half: &self.honest[victim],
                victim_prev: &self.prev[victim],
                honest_received: received,
                digest: &self.digest,
                n,
                b,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            AttackKind::None,
            AttackKind::SignFlip,
            AttackKind::Foe,
            AttackKind::Alie,
            AttackKind::Dissensus,
        ] {
            assert_eq!(AttackKind::parse(k.name()), Some(k));
        }
        assert_eq!(AttackKind::parse("zzz"), None);
    }

    #[test]
    fn none_builds_nothing() {
        assert!(AttackKind::None.build().is_none());
        assert!(AttackKind::Alie.build().is_some());
    }

    #[test]
    fn panel_has_all_four() {
        assert_eq!(AttackKind::panel().len(), 4);
    }

    #[test]
    fn digest_moments_match_direct_computation() {
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..5).map(|j| (i * 5 + j) as f32 * 0.25 - 3.0).collect())
            .collect();
        let prevs: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| r.iter().map(|x| x + 1.0).collect())
            .collect();
        let hr: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let pr: Vec<&[f32]> = prevs.iter().map(|v| v.as_slice()).collect();
        let digest = HonestDigest::compute(&hr, &pr);
        assert_eq!(digest.count, 7);
        for j in 0..5 {
            let mu: f64 = hr.iter().map(|r| r[j] as f64).sum::<f64>() / 7.0;
            let var: f64 = hr.iter().map(|r| (r[j] as f64 - mu).powi(2)).sum::<f64>() / 7.0;
            let pm: f64 = pr.iter().map(|r| r[j] as f64).sum::<f64>() / 7.0;
            assert!((digest.mean[j] - mu).abs() < 1e-12, "j={j}");
            assert!((digest.std[j] - var.sqrt()).abs() < 1e-12, "j={j}");
            assert!((digest.prev_mean[j] - pm).abs() < 1e-12, "j={j}");
        }
    }

    #[test]
    fn digest_recompute_reuses_buffers_and_handles_empty() {
        let mut digest = HonestDigest::new(3);
        digest.recompute(&[], &[], true);
        assert_eq!(digest.count, 0);
        assert!(digest.mean.iter().all(|&x| x == 0.0));
        let r1 = [1.0f32, 2.0, 3.0];
        let r2 = [3.0f32, 2.0, 1.0];
        digest.recompute(&[&r1, &r2], &[&r1, &r2], true);
        assert_eq!(digest.count, 2);
        assert_eq!(digest.mean, vec![2.0, 2.0, 2.0]);
        assert_eq!(digest.std, vec![1.0, 0.0, 1.0]);
        // skipping the variance pass still refreshes the means and zeroes σ
        digest.recompute(&[&r1, &r2], &[&r1, &r2], false);
        assert_eq!(digest.mean, vec![2.0, 2.0, 2.0]);
        assert_eq!(digest.std, vec![0.0, 0.0, 0.0]);
    }
}
