//! Dissensus attack (He et al. 2022) — built for gossip/graph updates.
//!
//! Each Byzantine participant seen by victim i reports a model on the
//! *opposite side* of i from its honest neighborhood:
//! `mal = x_i − ε (x̄_received − x_i)`, so the victim's aggregation of
//! {honest pull, malicious pull} cancels toward zero progress and the
//! honest population is pushed apart (no consensus). Per-victim crafting —
//! each honest node receives a different malicious vector — exercises the
//! paper's "distinct updates to different honest nodes in the same
//! iteration" capability. The neighborhood direction comes from the rows
//! the victim actually pulled (falling back to the digest mean when it
//! pulled none), so the cost is O(|received|·d) per victim.

use super::{Attack, AttackContext};

#[derive(Clone, Copy, Debug)]
pub struct Dissensus {
    /// repulsion strength ε (He et al. tune per topology; 1.0 default)
    pub epsilon: f32,
}

impl Default for Dissensus {
    fn default() -> Self {
        Dissensus { epsilon: 1.0 }
    }
}

impl Attack for Dissensus {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]) {
        let d = ctx.victim_half.len();
        // consensus direction: mean of what the victim received from honest
        // peers (fall back to global honest mean when it pulled none)
        let mut dir = vec![0.0f32; d];
        if ctx.honest_received.is_empty() {
            for ((o, &mu), &v) in dir
                .iter_mut()
                .zip(ctx.digest.mean.iter())
                .zip(ctx.victim_half.iter())
            {
                *o = mu as f32 - v;
            }
        } else {
            let inv = 1.0 / ctx.honest_received.len() as f32;
            for h in ctx.honest_received {
                for ((o, &hj), &v) in dir.iter_mut().zip(h.iter()).zip(ctx.victim_half.iter()) {
                    *o += (hj - v) * inv;
                }
            }
        }
        let Some((first, rest)) = out.split_first_mut() else {
            return;
        };
        for ((o, &v), &dj) in first
            .iter_mut()
            .zip(ctx.victim_half.iter())
            .zip(dir.iter())
        {
            *o = v - self.epsilon * dj;
        }
        for row in rest {
            row.copy_from_slice(first);
        }
    }

    fn name(&self) -> &'static str {
        "dissensus"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;

    #[test]
    fn opposes_consensus_direction() {
        let f = Fixture::new(4);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let ctx = f.ctx(0, &refs[1..4], 7, 2);
        let mut out = vec![vec![0.0f32; 4]];
        Dissensus::default().craft(&ctx, &mut out);
        // (mal - victim) · (consensus - victim) < 0
        let mut ip = 0.0f64;
        for j in 0..4 {
            let cons: f32 =
                refs[1..4].iter().map(|h| h[j]).sum::<f32>() / 3.0 - f.honest[0][j];
            ip += ((out[0][j] - f.honest[0][j]) * cons) as f64;
        }
        assert!(ip < 0.0, "ip={ip}");
    }

    #[test]
    fn per_victim_distinct_updates() {
        // two different victims receive different malicious vectors
        let f = Fixture::new(4);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let mk = |victim: usize| {
            let ctx = f.ctx(victim, &refs[1..3], 7, 2);
            let mut out = vec![vec![0.0f32; 4]];
            Dissensus::default().craft(&ctx, &mut out);
            out.remove(0)
        };
        assert_ne!(mk(0), mk(4));
    }

    #[test]
    fn empty_received_falls_back_to_global_mean() {
        let f = Fixture::new(3);
        let ctx = f.ctx(0, &[], 7, 2);
        let mut out = vec![vec![0.0f32; 3]];
        Dissensus::default().craft(&ctx, &mut out);
        for j in 0..3 {
            let dir = f.mean32(j) - f.honest[0][j];
            assert!((out[0][j] - (f.honest[0][j] - dir)).abs() < 1e-5);
        }
    }
}
