//! Fall of Empires (Xie et al. 2020): inner-product manipulation.
//!
//! Instead of a full flip, FOE reports `mal = x̄_H^t − ε (x̄_H^{t+1/2} −
//! x̄_H^t)` with a *small* ε, so the malicious update has negative inner
//! product with the honest direction while keeping a small norm —
//! defeating norm-based filters that SF trips.

use super::{Attack, AttackContext};

#[derive(Clone, Copy, Debug)]
pub struct Foe {
    /// negative-multiple magnitude ε (Xie et al. use small values; 0.1)
    pub epsilon: f32,
}

impl Default for Foe {
    fn default() -> Self {
        Foe { epsilon: 0.1 }
    }
}

impl Attack for Foe {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]) {
        for row in out.iter_mut() {
            for (j, o) in row.iter_mut().enumerate() {
                let update = ctx.honest_mean[j] - ctx.honest_prev_mean[j];
                *o = ctx.honest_prev_mean[j] - self.epsilon * update;
            }
        }
    }

    fn name(&self) -> &'static str {
        "foe"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;
    use crate::util::vecmath;

    fn ctx<'a>(f: &'a Fixture, refs: &'a [&'a [f32]]) -> AttackContext<'a> {
        AttackContext {
            victim_half: &f.honest[0],
            victim_prev: &f.prev[0],
            honest_received: refs,
            honest_all: refs,
            honest_mean: &f.mean,
            honest_prev_mean: &f.prev_mean,
            n: 7,
            b: 2,
        }
    }

    #[test]
    fn smaller_deviation_than_sign_flip() {
        let f = Fixture::new(5);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let c = ctx(&f, &refs);
        let mut foe_out = vec![vec![0.0f32; 5]];
        let mut sf_out = vec![vec![0.0f32; 5]];
        Foe::default().craft(&c, &mut foe_out);
        super::super::SignFlip::default().craft(&c, &mut sf_out);
        let d_foe = vecmath::dist(&foe_out[0], &f.mean);
        let d_sf = vecmath::dist(&sf_out[0], &f.mean);
        assert!(d_foe < d_sf, "FOE should hide closer to the honest mean");
    }

    #[test]
    fn still_opposes_update_direction() {
        let f = Fixture::new(5);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let c = ctx(&f, &refs);
        let mut out = vec![vec![0.0f32; 5]];
        Foe::default().craft(&c, &mut out);
        let mut ip = 0.0f64;
        for j in 0..5 {
            ip += ((out[0][j] - f.prev_mean[j]) * (f.mean[j] - f.prev_mean[j])) as f64;
        }
        assert!(ip < 0.0);
    }
}
