//! Fall of Empires (Xie et al. 2020): inner-product manipulation.
//!
//! Instead of a full flip, FOE reports `mal = x̄_H^t − ε (x̄_H^{t+1/2} −
//! x̄_H^t)` with a *small* ε, so the malicious update has negative inner
//! product with the honest direction while keeping a small norm —
//! defeating norm-based filters that SF trips. Means come from the
//! per-round [`HonestDigest`] (O(d) per victim).

use super::{Attack, AttackContext};

#[derive(Clone, Copy, Debug)]
pub struct Foe {
    /// negative-multiple magnitude ε (Xie et al. use small values; 0.1)
    pub epsilon: f32,
}

impl Default for Foe {
    fn default() -> Self {
        Foe { epsilon: 0.1 }
    }
}

impl Attack for Foe {
    fn craft(&self, ctx: &AttackContext<'_>, out: &mut [Vec<f32>]) {
        let eps = self.epsilon as f64;
        let Some((first, rest)) = out.split_first_mut() else {
            return;
        };
        for ((o, &mu), &prev) in first
            .iter_mut()
            .zip(ctx.digest.mean.iter())
            .zip(ctx.digest.prev_mean.iter())
        {
            let update = mu - prev;
            *o = (prev - eps * update) as f32;
        }
        for row in rest {
            row.copy_from_slice(first);
        }
    }

    fn name(&self) -> &'static str {
        "foe"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;
    use crate::util::vecmath;

    #[test]
    fn smaller_deviation_than_sign_flip() {
        let f = Fixture::new(5);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let c = f.ctx(0, &refs, 7, 2);
        let mut foe_out = vec![vec![0.0f32; 5]];
        let mut sf_out = vec![vec![0.0f32; 5]];
        Foe::default().craft(&c, &mut foe_out);
        super::super::SignFlip::default().craft(&c, &mut sf_out);
        let mean32: Vec<f32> = (0..5).map(|j| f.mean32(j)).collect();
        let d_foe = vecmath::dist(&foe_out[0], &mean32);
        let d_sf = vecmath::dist(&sf_out[0], &mean32);
        assert!(d_foe < d_sf, "FOE should hide closer to the honest mean");
    }

    #[test]
    fn still_opposes_update_direction() {
        let f = Fixture::new(5);
        let refs: Vec<&[f32]> = f.honest.iter().map(|v| v.as_slice()).collect();
        let c = f.ctx(0, &refs, 7, 2);
        let mut out = vec![vec![0.0f32; 5]];
        Foe::default().craft(&c, &mut out);
        let mut ip = 0.0f64;
        for j in 0..5 {
            ip += (out[0][j] as f64 - f.digest.prev_mean[j])
                * (f.digest.mean[j] - f.digest.prev_mean[j]);
        }
        assert!(ip < 0.0);
    }
}
