//! Coordinate-wise trimmed mean (Yin et al. 2018): per coordinate, drop
//! the `b` largest and `b` smallest values and average the remaining
//! `m − 2b`. The paper composes this after NNM as its aggregation rule.
//!
//! # Hot-path shape
//!
//! Per coordinate we need the *sum of the middle m − 2b order
//! statistics*, not a full sort. The kernel:
//!
//! * gathers coordinates through a transpose tile ([`for_each_coord`]):
//!   rows are copied [`COORD_TILE`] coordinates at a time into an
//!   L1-resident staging block, so the big row reads are sequential and
//!   the per-coordinate gather strides only inside the hot tile;
//! * maps each f32 to an order-preserving u32 key ([`sort_key`]) —
//!   integer compares in the inner loops, and a *total* order identical
//!   to `f32::total_cmp`, so NaN/±Inf adversarial values land
//!   deterministically at the extremes (where trimming removes them)
//!   instead of corrupting the sort like the old raw-f32 compares;
//! * below [`SELECT_MIN_M`] inputs, binary-insertion sorts the keys (for
//!   tiny m this beats the general sorts' dispatch); at or above it,
//!   `select_nth_unstable` partitions off the b smallest and b largest
//!   in O(m) and only the surviving middle is sorted.
//!
//! Both paths sum the middle values **ascending**, so they are
//! bit-identical to each other (pinned by `rust/tests/agg_kernels.rs`)
//! and the crossover constant is a pure speed knob. Scratch (tile +
//! keys) lives in a thread-local reused across coordinates, calls, and
//! rounds.

use super::Aggregator;
use std::cell::RefCell;

#[derive(Clone, Copy, Debug)]
pub struct CwTm {
    pub b: usize,
}

impl CwTm {
    pub fn new(b: usize) -> Self {
        CwTm { b }
    }
}

/// Coordinates staged per transpose tile: 64 f32 = 256 B per row, so a
/// 64-row gather works a 16 KiB block — L1-resident while the stat
/// kernel strides through it.
const COORD_TILE: usize = 64;

/// Crossover between the insertion-sort and selection paths, in input
/// count m. Measured by `bench_aggregation`'s "trimmed stats crossover"
/// section (BENCH_aggregation.json `trimmed` rows): binary insertion on
/// integer keys wins for the small fan-ins the paper's geometries use
/// (m ≲ 24); the O(m) `select_nth_unstable` partition wins beyond.
/// Outputs are bit-identical on both sides, so this only moves time.
pub const SELECT_MIN_M: usize = 24;

/// f32 → u32 key whose unsigned order equals `f32::total_cmp`: flip the
/// sign bit for non-negatives, all bits for negatives.
#[inline]
pub(crate) fn sort_key(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of [`sort_key`].
#[inline]
pub(crate) fn key_val(k: u32) -> f32 {
    let b = if k & 0x8000_0000 != 0 {
        k ^ 0x8000_0000
    } else {
        !k
    };
    f32::from_bits(b)
}

/// In-place insertion sort over keys — for the tiny per-coordinate
/// buffers this beats the general-purpose sorts' dispatch overhead, and
/// integer compares keep the inner loop branch-cheap.
#[inline]
fn insertion_sort_keys(buf: &mut [u32]) {
    for i in 1..buf.len() {
        let v = buf[i];
        let mut j = i;
        while j > 0 && buf[j - 1] > v {
            buf[j] = buf[j - 1];
            j -= 1;
        }
        buf[j] = v;
    }
}

/// Ascending f64 sum of decoded keys — THE canonical accumulation order
/// for trimmed sums. Equal keys are identical f32 bits, so any stable
/// arrangement of ties yields the same sum: ascending key order pins the
/// result across sort/selection paths.
#[inline]
fn sum_ascending(keys: &[u32]) -> f64 {
    let mut acc = 0.0f64;
    for &k in keys {
        acc += key_val(k) as f64;
    }
    acc
}

/// Trimmed sum via full insertion sort (reference path, wins small m).
pub(crate) fn trimmed_sum_keys_sort(keys: &mut [u32], b: usize) -> f64 {
    insertion_sort_keys(keys);
    sum_ascending(&keys[b..keys.len() - b])
}

/// Trimmed sum via two `select_nth_unstable` partitions: the b smallest
/// and b largest are split off in O(m) and never sorted; only the
/// surviving middle is sorted (ascending) for the canonical sum.
pub(crate) fn trimmed_sum_keys_select(keys: &mut [u32], b: usize) -> f64 {
    let m = keys.len();
    if b > 0 {
        // keys[b] becomes the (b+1)-th smallest with the b smallest left
        // of it, then the upper cut pins the (m-b)-th smallest at m-b-1
        keys.select_nth_unstable(b);
        keys[b..].select_nth_unstable(m - 2 * b - 1);
    }
    let mid = &mut keys[b..m - b];
    mid.sort_unstable();
    sum_ascending(mid)
}

/// Crossover dispatch (see [`SELECT_MIN_M`]).
#[inline]
pub(crate) fn trimmed_sum_keys(keys: &mut [u32], b: usize) -> f64 {
    if keys.len() < SELECT_MIN_M {
        trimmed_sum_keys_sort(keys, b)
    } else {
        trimmed_sum_keys_select(keys, b)
    }
}

/// Median via full insertion sort (reference path, wins small m).
pub(crate) fn median_keys_sort(keys: &mut [u32]) -> f32 {
    let m = keys.len();
    insertion_sort_keys(keys);
    if m % 2 == 1 {
        key_val(keys[m / 2])
    } else {
        0.5 * (key_val(keys[m / 2 - 1]) + key_val(keys[m / 2]))
    }
}

/// Median via one `select_nth_unstable` partition; the lower middle of
/// an even count is the max of the left partition. Identical expression
/// order to the sort path, hence bit-identical.
pub(crate) fn median_keys_select(keys: &mut [u32]) -> f32 {
    let m = keys.len();
    let (lo_part, hi, _) = keys.select_nth_unstable(m / 2);
    let hi = *hi;
    if m % 2 == 1 {
        key_val(hi)
    } else {
        let lo = *lo_part.iter().max().expect("even m >= 2 has a left partition");
        0.5 * (key_val(lo) + key_val(hi))
    }
}

/// Crossover dispatch (see [`SELECT_MIN_M`]).
#[inline]
pub(crate) fn median_keys(keys: &mut [u32]) -> f32 {
    if keys.len() < SELECT_MIN_M {
        median_keys_sort(keys)
    } else {
        median_keys_select(keys)
    }
}

/// Bench/test surface for the two trimmed-sum paths over plain f32s.
#[doc(hidden)]
pub fn trimmed_sum_sort_path(vals: &[f32], b: usize) -> f64 {
    let mut keys: Vec<u32> = vals.iter().map(|&v| sort_key(v)).collect();
    trimmed_sum_keys_sort(&mut keys, b)
}

/// Bench/test surface for the selection trimmed-sum path.
#[doc(hidden)]
pub fn trimmed_sum_select_path(vals: &[f32], b: usize) -> f64 {
    let mut keys: Vec<u32> = vals.iter().map(|&v| sort_key(v)).collect();
    trimmed_sum_keys_select(&mut keys, b)
}

/// Bench/test surface for the sort median path.
#[doc(hidden)]
pub fn median_sort_path(vals: &[f32]) -> f32 {
    let mut keys: Vec<u32> = vals.iter().map(|&v| sort_key(v)).collect();
    median_keys_sort(&mut keys)
}

/// Bench/test surface for the selection median path.
#[doc(hidden)]
pub fn median_select_path(vals: &[f32]) -> f32 {
    let mut keys: Vec<u32> = vals.iter().map(|&v| sort_key(v)).collect();
    median_keys_select(&mut keys)
}

/// Per-thread staging for the coordinate-wise rules, retained across
/// calls and rounds by the persistent pool's workers.
#[derive(Default)]
struct CoordScratch {
    /// m × tile-width staging block (row-major)
    tile: Vec<f32>,
    /// one coordinate's m keys
    keys: Vec<u32>,
}

thread_local! {
    static COORD_SCRATCH: RefCell<CoordScratch> = RefCell::new(CoordScratch::default());
}

/// Drive `stat` over every coordinate: rows are staged tile-by-tile
/// (sequential reads of [`COORD_TILE`] coordinates per row into an
/// L1-resident block), each coordinate's column is lifted to total-order
/// keys, and `stat`'s result is written to `out[j]`.
pub(crate) fn for_each_coord(
    inputs: &[&[f32]],
    out: &mut [f32],
    mut stat: impl FnMut(&mut [u32]) -> f32,
) {
    let m = inputs.len();
    let d = out.len();
    let mut scratch = COORD_SCRATCH.with(|cell| cell.take());
    scratch.keys.clear();
    scratch.keys.resize(m, 0);
    // grow-only staging, sliced per tile — the gather below overwrites
    // every slot it reads, so no per-tile (or even per-call) zeroing
    if scratch.tile.len() < m * COORD_TILE {
        scratch.tile.resize(m * COORD_TILE, 0.0);
    }
    let mut j0 = 0usize;
    while j0 < d {
        let tw = COORD_TILE.min(d - j0);
        let tile = &mut scratch.tile[..m * tw];
        for (r, row) in inputs.iter().enumerate() {
            tile[r * tw..(r + 1) * tw].copy_from_slice(&row[j0..j0 + tw]);
        }
        for t in 0..tw {
            for (r, key) in scratch.keys.iter_mut().enumerate() {
                *key = sort_key(tile[r * tw + t]);
            }
            out[j0 + t] = stat(&mut scratch.keys);
        }
        j0 += tw;
    }
    COORD_SCRATCH.with(|cell| cell.replace(scratch));
}

impl Aggregator for CwTm {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let m = inputs.len();
        assert!(
            m > 2 * self.b,
            "CWTM needs m > 2b (m={m}, b={})",
            self.b
        );
        let b = self.b;
        let inv = 1.0f64 / (m - 2 * b) as f64;
        for_each_coord(inputs, out, |keys| (trimmed_sum_keys(keys, b) * inv) as f32);
    }

    fn name(&self) -> &'static str {
        "cwtm"
    }

    fn min_inputs(&self) -> usize {
        2 * self.b + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trims_extremes() {
        let rows = [
            vec![0.0f32],
            vec![1.0f32],
            vec![2.0f32],
            vec![1e9f32],
            vec![-1e9f32],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwTm::new(1).aggregate(&inputs, &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn b0_is_mean() {
        let rows = [vec![1.0f32, 4.0], vec![3.0f32, 8.0]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 2];
        CwTm::new(0).aggregate(&inputs, &mut out);
        assert_eq!(out, vec![2.0, 6.0]);
    }

    #[test]
    fn per_coordinate_independence() {
        // trimming happens per coordinate, not per row
        let rows = [
            vec![100.0f32, 0.0],
            vec![0.0f32, 100.0],
            vec![1.0f32, 1.0],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 2];
        CwTm::new(1).aggregate(&inputs, &mut out);
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_overtrim() {
        let rows = [vec![1.0f32], vec![2.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwTm::new(1).aggregate(&inputs, &mut out);
    }

    #[test]
    fn sort_key_orders_like_total_cmp() {
        let vals = [
            f32::NEG_INFINITY,
            -1e30,
            -1.0,
            -1e-42, // denormal
            -0.0,
            0.0,
            1e-42,
            1.0,
            1e30,
            f32::INFINITY,
            f32::NAN,
        ];
        for (i, &a) in vals.iter().enumerate() {
            for &b in &vals[i..] {
                assert_eq!(
                    sort_key(a).cmp(&sort_key(b)),
                    a.total_cmp(&b),
                    "key order diverged at ({a}, {b})"
                );
                assert_eq!(key_val(sort_key(a)).to_bits(), a.to_bits(), "roundtrip {a}");
            }
        }
    }

    #[test]
    fn selection_matches_sort_path_across_widths() {
        // both sides of the crossover compute identical bits
        let mut state = 0x2545F4914F6CDD1Du64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 40) as f32 / 256.0 - 32.0
        };
        for m in [3usize, 5, 8, 16, 23, 24, 25, 33, 64] {
            let vals: Vec<f32> = (0..m).map(|_| next()).collect();
            for b in 0..(m - 1) / 2 {
                let a = trimmed_sum_sort_path(&vals, b);
                let s = trimmed_sum_select_path(&vals, b);
                assert_eq!(a.to_bits(), s.to_bits(), "m={m} b={b}");
            }
            let ms = median_sort_path(&vals);
            let sl = median_select_path(&vals);
            assert_eq!(ms.to_bits(), sl.to_bits(), "median m={m}");
        }
    }

    #[test]
    fn nan_rows_are_trimmed_not_propagated() {
        // per coordinate: 5 finite + 2 non-finite with b=2 — the total
        // order sends NaN/Inf to the extremes, trimming removes them
        let rows = [
            vec![1.0f32],
            vec![2.0f32],
            vec![3.0f32],
            vec![4.0f32],
            vec![5.0f32],
            vec![f32::NAN],
            vec![f32::NEG_INFINITY],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwTm::new(2).aggregate(&inputs, &mut out);
        // -Inf and the 1.0 trim low; NaN and 5.0 trim high → mean(2,3,4)
        assert_eq!(out[0], 3.0);
    }
}
