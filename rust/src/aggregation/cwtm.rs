//! Coordinate-wise trimmed mean (Yin et al. 2018): per coordinate, drop
//! the `b` largest and `b` smallest values and average the remaining
//! `m − 2b`. The paper composes this after NNM as its aggregation rule.
//!
//! Hot-path note: per coordinate we need the *sum of the middle m−2b order
//! statistics*, not a full sort. For small m a binary-insertion buffer
//! beats comparison sorts; the scratch buffer is reused across coordinates
//! (no allocation in the loop).

use super::Aggregator;

#[derive(Clone, Copy, Debug)]
pub struct CwTm {
    pub b: usize,
}

impl CwTm {
    pub fn new(b: usize) -> Self {
        CwTm { b }
    }
}

/// In-place insertion sort — for the tiny per-coordinate buffers (m ≤ a
/// few dozen) this beats the general-purpose sort's dispatch overhead by
/// ~2x, and `total_cmp`-free f32 compares keep the inner loop branchless
/// enough for the optimizer.
#[inline]
pub(crate) fn insertion_sort(buf: &mut [f32]) {
    for i in 1..buf.len() {
        let v = buf[i];
        let mut j = i;
        while j > 0 && buf[j - 1] > v {
            buf[j] = buf[j - 1];
            j -= 1;
        }
        buf[j] = v;
    }
}

impl Aggregator for CwTm {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let m = inputs.len();
        assert!(
            m > 2 * self.b,
            "CWTM needs m > 2b (m={m}, b={})",
            self.b
        );
        let inv = 1.0f64 / (m - 2 * self.b) as f64;
        let mut buf: Vec<f32> = vec![0.0; m];
        for (j, o) in out.iter_mut().enumerate() {
            for (slot, row) in buf.iter_mut().zip(inputs) {
                *slot = row[j];
            }
            insertion_sort(&mut buf);
            let mut acc = 0.0f64;
            for &v in &buf[self.b..m - self.b] {
                acc += v as f64;
            }
            *o = (acc * inv) as f32;
        }
    }

    fn name(&self) -> &'static str {
        "cwtm"
    }

    fn min_inputs(&self) -> usize {
        2 * self.b + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trims_extremes() {
        let rows = [
            vec![0.0f32],
            vec![1.0f32],
            vec![2.0f32],
            vec![1e9f32],
            vec![-1e9f32],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwTm::new(1).aggregate(&inputs, &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn b0_is_mean() {
        let rows = [vec![1.0f32, 4.0], vec![3.0f32, 8.0]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 2];
        CwTm::new(0).aggregate(&inputs, &mut out);
        assert_eq!(out, vec![2.0, 6.0]);
    }

    #[test]
    fn per_coordinate_independence() {
        // trimming happens per coordinate, not per row
        let rows = [
            vec![100.0f32, 0.0],
            vec![0.0f32, 100.0],
            vec![1.0f32, 1.0],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 2];
        CwTm::new(1).aggregate(&inputs, &mut out);
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_overtrim() {
        let rows = [vec![1.0f32], vec![2.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwTm::new(1).aggregate(&inputs, &mut out);
    }
}
