//! Geometric median via Weiszfeld iterations (Chen et al. 2017 use it as a
//! robust aggregation primitive). Matches `python/compile/kernels/ref.py`
//! exactly: fixed iteration count, epsilon-guarded denominators,
//! initialized at the coordinate mean.
//!
//! Rides the shared fast-path kernels: per-row distances use the blocked
//! [`vecmath::dist`] reduction, the mean init reuses [`vecmath::mean_of`]'s
//! thread-local staging, and the f64 iterate buffer below lives in a
//! thread-local retained across calls — the Weiszfeld loop allocates
//! nothing in steady state.

use super::Aggregator;
use crate::util::vecmath;
use std::cell::RefCell;

thread_local! {
    /// d-length f64 iterate, moved out of the cell per call (repo-wide
    /// take/replace pattern).
    static NEXT: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

#[derive(Clone, Copy, Debug)]
pub struct GeoMedian {
    pub iters: usize,
    pub eps: f64,
}

impl Default for GeoMedian {
    fn default() -> Self {
        GeoMedian {
            iters: 100,
            eps: 1e-8,
        }
    }
}

impl Aggregator for GeoMedian {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        assert!(!inputs.is_empty());
        let d = out.len();
        // init: coordinate mean
        vecmath::mean_of(inputs, out);
        let mut next = NEXT.with(|cell| cell.take());
        next.clear();
        next.resize(d, 0.0);
        for _ in 0..self.iters {
            next.fill(0.0);
            let mut wsum = 0.0f64;
            for row in inputs {
                let w = 1.0 / vecmath::dist(row, out).max(self.eps);
                wsum += w;
                for (nj, &xj) in next.iter_mut().zip(row.iter()) {
                    *nj += w * xj as f64;
                }
            }
            for (o, nj) in out.iter_mut().zip(next.iter()) {
                *o = (*nj / wsum) as f32;
            }
        }
        NEXT.with(|cell| cell.replace(next));
    }

    fn name(&self) -> &'static str {
        "geomedian"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn as_rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn majority_point_wins_on_line() {
        let data = vec![vec![0.0f32], vec![0.0], vec![0.0], vec![10.0]];
        let mut out = vec![0.0f32; 1];
        GeoMedian::default().aggregate(&as_rows(&data), &mut out);
        assert!(out[0].abs() < 0.5, "gm={}", out[0]);
    }

    #[test]
    fn translation_equivariance() {
        let base = vec![
            vec![1.0f32, 2.0],
            vec![3.0, -1.0],
            vec![0.0, 0.5],
            vec![2.0, 2.0],
        ];
        let shifted: Vec<Vec<f32>> = base
            .iter()
            .map(|r| r.iter().map(|x| x + 5.0).collect())
            .collect();
        let gm = GeoMedian::default();
        let mut a = vec![0.0f32; 2];
        let mut b = vec![0.0f32; 2];
        gm.aggregate(&as_rows(&base), &mut a);
        gm.aggregate(&as_rows(&shifted), &mut b);
        for j in 0..2 {
            assert!((a[j] + 5.0 - b[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn resists_large_outlier_better_than_mean() {
        let data = vec![
            vec![0.0f32, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![1e6, 1e6],
        ];
        let mut gm = vec![0.0f32; 2];
        GeoMedian::default().aggregate(&as_rows(&data), &mut gm);
        assert!(vecmath::norm(&gm) < 10.0, "gm={gm:?}");
    }
}
