//! Fixed-graph robust gossip baselines (paper Appendix C.2).
//!
//! These operate on a node's graph neighborhood with Metropolis weights
//! rather than on a pulled sample:
//!
//! * [`NaiveGossip`]   — plain weighted gossip averaging (non-robust).
//! * [`ClippedGossip`] — He et al. 2022, the *adaptive/practical* clipping
//!   threshold variant the RPEL paper benchmarks (the theoretical τ of the
//!   original needs attacker identities — impossible to implement; the
//!   practical rule clips the `b_local` furthest updates to the radius of
//!   the (deg − b_local)-th nearest).
//! * [`CsPlus`]        — Gaucher et al. 2025: clip the **2·b_local**
//!   largest updates to the radius of the (deg − 2b)-th nearest.
//! * [`Gts`]           — NNA (Farhadkhani et al. 2023) adapted to sparse
//!   graphs as implemented by Gaucher et al.: drop the b furthest
//!   neighbors, average the rest with self.
//! * [`Rtc`]           — Remove-Then-Clip (Yang & Ghaderi 2024): remove the
//!   b furthest, then clip the survivors to the median kept distance.
//!
//! Remark C.2 of the paper: `b_local` is set to b̂ under random attacker
//! placement (what these experiments use) and to b when placement is
//! adversarial.

use crate::util::vecmath;

/// A gossip update rule on one node's neighborhood.
///
/// `neighbors` carries `(model, W_ij)` pairs with Metropolis weights; the
/// self-weight is `1 − Σ W_ij` (guaranteed ≥ 0 by construction).
///
/// `Send + Sync`: one rule instance is shared across the parallel round
/// engine's workers (all implementations here are stateless).
pub trait GossipAggregator: Send + Sync {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]);
    fn name(&self) -> &'static str;
}

/// Named gossip rule selection for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GossipRuleKind {
    Naive,
    ClippedGossip,
    CsPlus,
    Gts,
    Rtc,
}

impl GossipRuleKind {
    pub fn parse(s: &str) -> Option<GossipRuleKind> {
        Some(match s {
            "gossip" | "naive" => GossipRuleKind::Naive,
            "clipped_gossip" | "clippedgossip" => GossipRuleKind::ClippedGossip,
            "cs_plus" | "cs+" | "csplus" => GossipRuleKind::CsPlus,
            "gts" | "nna" => GossipRuleKind::Gts,
            "rtc" => GossipRuleKind::Rtc,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            GossipRuleKind::Naive => "gossip",
            GossipRuleKind::ClippedGossip => "clipped_gossip",
            GossipRuleKind::CsPlus => "cs_plus",
            GossipRuleKind::Gts => "gts",
            GossipRuleKind::Rtc => "rtc",
        }
    }

    pub fn build(&self, b_local: usize) -> Box<dyn GossipAggregator> {
        match self {
            GossipRuleKind::Naive => Box::new(NaiveGossip),
            GossipRuleKind::ClippedGossip => Box::new(ClippedGossip { b_local }),
            GossipRuleKind::CsPlus => Box::new(CsPlus { b_local }),
            GossipRuleKind::Gts => Box::new(Gts { b_local }),
            GossipRuleKind::Rtc => Box::new(Rtc { b_local }),
        }
    }
}

/// Distances from `own` to each neighbor, ascending `(dist, index)`.
fn sorted_dists(own: &[f32], neighbors: &[(&[f32], f64)]) -> Vec<(f64, usize)> {
    let mut d: Vec<(f64, usize)> = neighbors
        .iter()
        .enumerate()
        .map(|(i, (x, _))| (vecmath::dist(own, x), i))
        .collect();
    d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    d
}

/// Gossip step with per-neighbor clipping radius:
/// `out = own + Σ_j W_ij · clip_{τ_j}(x_j − own)`.
fn clipped_gossip_step(
    own: &[f32],
    neighbors: &[(&[f32], f64)],
    tau: impl Fn(usize) -> f64,
    out: &mut [f32],
) {
    out.copy_from_slice(own);
    for (i, (x, w)) in neighbors.iter().enumerate() {
        let d = vecmath::dist(own, x);
        let t = tau(i);
        let scale = if d > t && d > 0.0 { t / d } else { 1.0 };
        let f = (*w * scale) as f32;
        for (o, (xj, oj)) in out.iter_mut().zip(x.iter().zip(own.iter())) {
            *o += f * (xj - oj);
        }
    }
}

/// Plain (non-robust) Metropolis gossip averaging.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveGossip;

impl GossipAggregator for NaiveGossip {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]) {
        let wsum: f64 = neighbors.iter().map(|(_, w)| *w).sum();
        let self_w = (1.0 - wsum) as f32;
        for (o, &x) in out.iter_mut().zip(own.iter()) {
            *o = self_w * x;
        }
        for (x, w) in neighbors {
            vecmath::axpy(out, *w as f32, x);
        }
    }

    fn name(&self) -> &'static str {
        "gossip"
    }
}

/// He et al. 2022 with the practical adaptive threshold.
#[derive(Clone, Copy, Debug)]
pub struct ClippedGossip {
    pub b_local: usize,
}

impl GossipAggregator for ClippedGossip {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]) {
        let deg = neighbors.len();
        let dists = sorted_dists(own, neighbors);
        // radius of the (deg − b_local)-th nearest neighbor; if every
        // neighbor could be Byzantine, clip everything to 0 (stay put).
        let tau = if deg > self.b_local {
            dists[deg - self.b_local - 1].0
        } else {
            0.0
        };
        clipped_gossip_step(own, neighbors, |_| tau, out);
    }

    fn name(&self) -> &'static str {
        "clipped_gossip"
    }
}

/// Gaucher et al. 2025: clip the 2b largest updates.
#[derive(Clone, Copy, Debug)]
pub struct CsPlus {
    pub b_local: usize,
}

impl GossipAggregator for CsPlus {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]) {
        let deg = neighbors.len();
        let dists = sorted_dists(own, neighbors);
        let keep = deg.saturating_sub(2 * self.b_local);
        let tau = if keep > 0 { dists[keep - 1].0 } else { 0.0 };
        clipped_gossip_step(own, neighbors, |_| tau, out);
    }

    fn name(&self) -> &'static str {
        "cs_plus"
    }
}

/// NNA on sparse graphs (GTS): drop the b furthest neighbors, average the
/// survivors together with self (uniform weights over the kept set — the
/// NNA mixing step).
#[derive(Clone, Copy, Debug)]
pub struct Gts {
    pub b_local: usize,
}

impl GossipAggregator for Gts {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]) {
        let deg = neighbors.len();
        let keep = deg.saturating_sub(self.b_local);
        let dists = sorted_dists(own, neighbors);
        out.copy_from_slice(own);
        for &(_, i) in &dists[..keep] {
            vecmath::axpy(out, 1.0, neighbors[i].0);
        }
        vecmath::scale(out, 1.0 / (keep + 1) as f32);
    }

    fn name(&self) -> &'static str {
        "gts"
    }
}

/// Remove-Then-Clip (Yang & Ghaderi 2024): remove the b furthest
/// neighbors, clip the survivors to the median surviving distance, gossip
/// over the kept set with renormalized weights.
#[derive(Clone, Copy, Debug)]
pub struct Rtc {
    pub b_local: usize,
}

impl GossipAggregator for Rtc {
    fn aggregate(&self, own: &[f32], neighbors: &[(&[f32], f64)], out: &mut [f32]) {
        let deg = neighbors.len();
        let keep_n = deg.saturating_sub(self.b_local);
        let dists = sorted_dists(own, neighbors);
        if keep_n == 0 {
            out.copy_from_slice(own);
            return;
        }
        let kept = &dists[..keep_n];
        // implementable threshold: median distance among survivors
        let tau = kept[keep_n / 2].0;
        let kept_idx: Vec<usize> = kept.iter().map(|&(_, i)| i).collect();
        let subset: Vec<(&[f32], f64)> = kept_idx
            .iter()
            .map(|&i| (neighbors[i].0, neighbors[i].1))
            .collect();
        clipped_gossip_step(own, &subset, |_| tau, out);
    }

    fn name(&self) -> &'static str {
        "rtc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Gen};
    use crate::util::rng::Rng;

    fn nb<'a>(rows: &'a [Vec<f32>], w: f64) -> Vec<(&'a [f32], f64)> {
        rows.iter().map(|r| (r.as_slice(), w)).collect()
    }

    const ALL_KINDS: [GossipRuleKind; 5] = [
        GossipRuleKind::Naive,
        GossipRuleKind::ClippedGossip,
        GossipRuleKind::CsPlus,
        GossipRuleKind::Gts,
        GossipRuleKind::Rtc,
    ];

    /// Random neighborhood with valid Metropolis-style weights
    /// (uniform w = 1/(deg+1), so Σw ≤ 1 and the self-weight is ≥ 0).
    fn random_neighborhood(rng: &mut Rng) -> (Vec<f32>, Vec<Vec<f32>>, f64) {
        let deg = 2 + rng.index(6);
        let d = 1 + rng.index(8);
        let own: Vec<f32> = (0..d).map(|_| rng.gaussian32(0.0, 3.0)).collect();
        let rows: Vec<Vec<f32>> = (0..deg)
            .map(|_| (0..d).map(|_| rng.gaussian32(0.0, 3.0)).collect())
            .collect();
        let w = 1.0 / (deg as f64 + 1.0);
        (own, rows, w)
    }

    /// Every gossip rule's output is invariant under a permutation of the
    /// neighbor list (up to f32 summation-order noise): nothing may depend
    /// on the order models arrive in.
    #[test]
    fn prop_all_rules_invariant_under_neighbor_permutation() {
        for (idx, kind) in ALL_KINDS.into_iter().enumerate() {
            let tag = idx as u64;
            forall(60, 0x6055 + tag, Gen::usize_in(0..=100_000), |&seed| {
                let mut rng = Rng::new(seed as u64);
                let (own, rows, w) = random_neighborhood(&mut rng);
                let neigh = nb(&rows, w);
                let mut perm: Vec<usize> = (0..rows.len()).collect();
                rng.shuffle(&mut perm);
                let permuted: Vec<(&[f32], f64)> =
                    perm.iter().map(|&i| neigh[i]).collect();
                let rule = kind.build(1);
                let mut a = vec![0.0f32; own.len()];
                let mut p = vec![0.0f32; own.len()];
                rule.aggregate(&own, &neigh, &mut a);
                rule.aggregate(&own, &permuted, &mut p);
                a.iter().zip(&p).all(|(x, y)| (x - y).abs() <= 1e-4)
            });
        }
    }

    /// With `b_local = 0` and honest-only inputs, every rule degenerates
    /// to a convex combination: each output coordinate stays inside the
    /// min/max envelope of {self} ∪ neighbors.
    #[test]
    fn prop_b0_output_inside_coordinate_envelope() {
        for (idx, kind) in ALL_KINDS.into_iter().enumerate() {
            let tag = idx as u64;
            forall(60, 0xE47 + tag, Gen::usize_in(0..=100_000), |&seed| {
                let mut rng = Rng::new(seed as u64);
                let (own, rows, w) = random_neighborhood(&mut rng);
                let rule = kind.build(0);
                let mut out = vec![0.0f32; own.len()];
                rule.aggregate(&own, &nb(&rows, w), &mut out);
                (0..own.len()).all(|j| {
                    let mut lo = own[j];
                    let mut hi = own[j];
                    for r in &rows {
                        lo = lo.min(r[j]);
                        hi = hi.max(r[j]);
                    }
                    out[j] >= lo - 1e-3 && out[j] <= hi + 1e-3
                })
            });
        }
    }

    #[test]
    fn naive_gossip_is_weighted_average() {
        let own = vec![0.0f32, 0.0];
        let rows = vec![vec![4.0f32, 8.0]];
        let mut out = vec![0.0f32; 2];
        NaiveGossip.aggregate(&own, &nb(&rows, 0.25), &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn naive_gossip_unanimity() {
        let own = vec![2.0f32];
        let rows = vec![vec![2.0f32], vec![2.0f32]];
        let mut out = vec![0.0f32; 1];
        NaiveGossip.aggregate(&own, &nb(&rows, 0.3), &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn clipped_gossip_limits_outlier_pull() {
        let own = vec![0.0f32];
        let rows = vec![vec![0.1f32], vec![0.2f32], vec![1e9f32]];
        let mut out = vec![0.0f32; 1];
        ClippedGossip { b_local: 1 }.aggregate(&own, &nb(&rows, 0.2), &mut out);
        // outlier clipped to tau = 0.2, max pull = 0.2*(0.1+0.2+0.2)
        assert!(out[0] <= 0.2, "out={}", out[0]);
    }

    #[test]
    fn clipped_gossip_all_byzantine_neighbors_freezes() {
        let own = vec![1.0f32];
        let rows = vec![vec![100.0f32]];
        let mut out = vec![0.0f32; 1];
        ClippedGossip { b_local: 1 }.aggregate(&own, &nb(&rows, 0.5), &mut out);
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn cs_plus_clips_twice_as_many() {
        let own = vec![0.0f32];
        // 5 neighbors, b=1: CS+ clips the 2 furthest to the 3rd distance
        let rows = vec![vec![0.1f32], vec![0.2], vec![0.3], vec![50.0], vec![60.0]];
        let mut out = vec![0.0f32; 1];
        CsPlus { b_local: 1 }.aggregate(&own, &nb(&rows, 0.1), &mut out);
        // tau = 0.3: worst case pull 0.1*(0.1+0.2+0.3+0.3+0.3) = 0.12
        assert!(out[0] <= 0.12 + 1e-6, "out={}", out[0]);
    }

    #[test]
    fn gts_drops_furthest() {
        let own = vec![0.0f32];
        let rows = vec![vec![1.0f32], vec![2.0f32], vec![1000.0f32]];
        let mut out = vec![0.0f32; 1];
        Gts { b_local: 1 }.aggregate(&own, &nb(&rows, 0.2), &mut out);
        // kept: self, 1.0, 2.0 -> uniform mean = 1.0
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gts_all_removed_keeps_self() {
        let own = vec![3.0f32];
        let rows = vec![vec![9.0f32]];
        let mut out = vec![0.0f32; 1];
        Gts { b_local: 1 }.aggregate(&own, &nb(&rows, 0.5), &mut out);
        assert_eq!(out[0], 3.0);
    }

    #[test]
    fn rtc_removes_then_clips() {
        let own = vec![0.0f32];
        let rows = vec![vec![0.1f32], vec![0.2], vec![0.4], vec![1e6]];
        let mut out = vec![0.0f32; 1];
        Rtc { b_local: 1 }.aggregate(&own, &nb(&rows, 0.2), &mut out);
        // the 1e6 neighbor removed entirely; survivors pulled mildly
        assert!(out[0] < 0.2, "out={}", out[0]);
    }

    #[test]
    fn rule_kind_parse() {
        assert_eq!(GossipRuleKind::parse("cs+"), Some(GossipRuleKind::CsPlus));
        assert_eq!(
            GossipRuleKind::parse("clipped_gossip"),
            Some(GossipRuleKind::ClippedGossip)
        );
        assert_eq!(GossipRuleKind::parse("nope"), None);
        for k in [
            GossipRuleKind::Naive,
            GossipRuleKind::ClippedGossip,
            GossipRuleKind::CsPlus,
            GossipRuleKind::Gts,
            GossipRuleKind::Rtc,
        ] {
            assert_eq!(GossipRuleKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn all_rules_noop_on_identical_models() {
        let own = vec![1.0f32, -1.0];
        let rows = vec![vec![1.0f32, -1.0], vec![1.0, -1.0], vec![1.0, -1.0]];
        for kind in [
            GossipRuleKind::Naive,
            GossipRuleKind::ClippedGossip,
            GossipRuleKind::CsPlus,
            GossipRuleKind::Gts,
            GossipRuleKind::Rtc,
        ] {
            let rule = kind.build(1);
            let mut out = vec![0.0f32; 2];
            rule.aggregate(&own, &nb(&rows, 0.2), &mut out);
            assert!(
                (out[0] - 1.0).abs() < 1e-6 && (out[1] + 1.0).abs() < 1e-6,
                "{} failed unanimity: {out:?}",
                rule.name()
            );
        }
    }
}
