//! NNM — Nearest-Neighbor Mixing pre-aggregation (Allouah et al. 2023).
//!
//! Each input vector is replaced by the average of its `m − b` nearest
//! inputs (L2, including itself); a base rule is then applied to the mixed
//! vectors. Allouah et al. show NNM∘{CWTM, Krum, CWMed, GM} achieves
//! κ = O(b/m), which the paper leans on for Corollary 5.7.
//!
//! Tie-breaking matches the Pallas/jnp stable argsort: equal distances
//! resolve by index order. The mixing loop reuses a flat scratch matrix —
//! no per-round allocation when driven through [`NnmScratch`].

use super::{pairwise_sqdist, Aggregator};

#[derive(Debug)]
pub struct Nnm<A: Aggregator> {
    pub b: usize,
    pub base: A,
}

impl<A: Aggregator> Nnm<A> {
    pub fn new(b: usize, base: A) -> Self {
        Nnm { b, base }
    }

    /// Compute the mixed matrix into `mixed` (m rows of d, row-major).
    pub fn mix_into(&self, inputs: &[&[f32]], mixed: &mut Vec<f32>) {
        let m = inputs.len();
        let d = inputs[0].len();
        let k = m - self.b;
        assert!(k >= 1, "NNM needs m - b >= 1 (m={m}, b={})", self.b);
        let dist = pairwise_sqdist(inputs);
        mixed.clear();
        mixed.resize(m * d, 0.0);
        let mut order: Vec<usize> = Vec::with_capacity(m);
        let inv = 1.0 / k as f32;
        for i in 0..m {
            order.clear();
            order.extend(0..m);
            // stable sort by distance, ties by index (order is already
            // index-ascending, and sort_by is stable)
            order.sort_by(|&a, &b| dist[i * m + a].partial_cmp(&dist[i * m + b]).unwrap());
            let row = &mut mixed[i * d..(i + 1) * d];
            for &j in &order[..k] {
                crate::util::vecmath::axpy(row, 1.0, inputs[j]);
            }
            crate::util::vecmath::scale(row, inv);
        }
    }
}

impl<A: Aggregator> Aggregator for Nnm<A> {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        // per-thread mixing buffer: the m·d matrix would otherwise be a
        // fresh megabyte-scale allocation on every aggregation (once per
        // honest node per round, the coordinator's hottest call), and a
        // shared `&self` buffer would either lock or contend under the
        // parallel round engine. The buffer is moved out of the cell for
        // the duration of the call, so a (hypothetical) nested NNM would
        // degrade to an allocation instead of a borrow panic.
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<f32>> =
                std::cell::RefCell::new(Vec::new());
        }
        let m = inputs.len();
        let d = out.len();
        let mut mixed = SCRATCH.with(|cell| cell.take());
        self.mix_into(inputs, &mut mixed);
        let rows: Vec<&[f32]> = (0..m).map(|i| &mixed[i * d..(i + 1) * d]).collect();
        self.base.aggregate(&rows, out);
        drop(rows);
        SCRATCH.with(|cell| cell.replace(mixed));
    }

    fn name(&self) -> &'static str {
        // static str limitation: report the composite family name
        "nnm"
    }

    fn min_inputs(&self) -> usize {
        (self.b + 1).max(self.base.min_inputs())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CwTm, Mean};
    use super::*;

    fn as_rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn b0_mix_is_global_mean_everywhere() {
        let data = vec![vec![0.0f32, 2.0], vec![2.0, 4.0], vec![4.0, 0.0]];
        let nnm = Nnm::new(0, Mean);
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        for i in 0..3 {
            assert_eq!(&mixed[i * 2..i * 2 + 2], &[2.0, 2.0]);
        }
    }

    #[test]
    fn mixing_shrinks_spread() {
        // Lemma-5.2 flavor: NNM reduces the variance among honest vectors
        let data = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![50.0], // outlier
        ];
        let nnm = Nnm::new(1, Mean);
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        // honest rows (first 4) mixed values stay near the honest cluster
        for i in 0..4 {
            assert!(mixed[i] < 10.0, "row {i} = {}", mixed[i]);
        }
    }

    #[test]
    fn self_always_included() {
        // the nearest neighbor of any vector is itself (distance 0)
        let data = vec![vec![0.0f32], vec![100.0], vec![200.0]];
        let nnm = Nnm::new(2, Mean); // k = 1: each row mixes only itself
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        assert_eq!(mixed, vec![0.0, 100.0, 200.0]);
    }

    #[test]
    fn composite_with_cwtm_resists_attack() {
        // 2 Byzantine at huge magnitude among 7: NNM∘CWTM output must stay
        // within the honest hull
        let mut data: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32 * 0.1]).collect();
        data.push(vec![1e8]);
        data.push(vec![-1e8]);
        let rule = Nnm::new(2, CwTm::new(2));
        let mut out = vec![0.0f32; 1];
        rule.aggregate(&as_rows(&data), &mut out);
        assert!((0.0..=0.4).contains(&out[0]), "out={}", out[0]);
    }

    #[test]
    fn tie_break_by_index_matches_oracle_contract() {
        // two equidistant neighbors: lower index wins
        let data = vec![vec![0.0f32], vec![1.0], vec![-1.0], vec![5.0]];
        let nnm = Nnm::new(2, Mean); // k = 2: self + one of {1, 2} for row 0
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        // row 0 mixes self(0.0) and index-1 (1.0) -> 0.5
        assert!((mixed[0] - 0.5).abs() < 1e-6, "mixed0={}", mixed[0]);
    }
}
