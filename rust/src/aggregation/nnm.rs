//! NNM — Nearest-Neighbor Mixing pre-aggregation (Allouah et al. 2023).
//!
//! Each input vector is replaced by the average of its `m − b` nearest
//! inputs (L2, including itself); a base rule is then applied to the mixed
//! vectors. Allouah et al. show NNM∘{CWTM, Krum, CWMed, GM} achieves
//! κ = O(b/m), which the paper leans on for Corollary 5.7.
//!
//! Tie-breaking matches the Pallas/jnp stable argsort: equal distances
//! resolve by index order; non-finite distances (NaN/±Inf adversarial
//! rows) rank as +∞ via [`super::rank_cmp`] — farthest, never a panic.
//!
//! This is the round engine's hottest rule, so the whole call is
//! allocation-free in steady state: one thread-local [`NnmScratch`]
//! holds the mixed matrix, the neighbor ordering, the pairwise matrix
//! and its Gram buffers, and the recycled row-view allocation for the
//! base-rule call. Driven through
//! [`Aggregator::aggregate_with_ctx`], the honest↔honest entries of the
//! pairwise matrix are served from the round [`super::DistCache`].

use super::{pairwise_sqdist_into, Aggregator, PairScratch, RowCtx};
use std::cell::RefCell;

#[derive(Debug)]
pub struct Nnm<A: Aggregator> {
    pub b: usize,
    pub base: A,
}

/// Per-thread working state for one NNM aggregation, retained across
/// victims and rounds by the persistent pool's workers.
#[derive(Default)]
struct NnmScratch {
    /// m·d mixed matrix (row-major)
    mixed: Vec<f32>,
    /// neighbor ordering, reused across the m mixing rows
    order: Vec<usize>,
    /// m·m pairwise squared-distance matrix
    dist: Vec<f64>,
    /// Gram-kernel buffers for the pairwise fill
    pairs: PairScratch,
    /// recycled allocation for the base rule's row views (emptied
    /// before storage, so the 'static lifetime is never inhabited)
    views: Vec<&'static [f32]>,
}

thread_local! {
    /// The scratch is moved out of the cell for the duration of the
    /// call, so a (hypothetical) nested NNM would degrade to fresh
    /// allocations instead of a borrow panic.
    static SCRATCH: RefCell<NnmScratch> = RefCell::new(NnmScratch::default());
}

/// Reuse an emptied row-view allocation under a fresh element lifetime:
/// clear, disassemble, reassemble with len 0. Sound because no element
/// ever crosses the lifetime boundary — only the raw allocation does,
/// and `&'a [f32]` and `&'static [f32]` have identical layout.
fn recycled_views<'a>(views: Vec<&'static [f32]>) -> Vec<&'a [f32]> {
    let mut views = std::mem::ManuallyDrop::new(views);
    views.clear();
    let (ptr, cap) = (views.as_mut_ptr(), views.capacity());
    // SAFETY: ptr/cap come from a live Vec whose ownership we just took
    // (ManuallyDrop suppresses its drop); len 0 means no element is read.
    unsafe { Vec::from_raw_parts(ptr.cast::<&'a [f32]>(), 0, cap) }
}

/// Store a row-view allocation back (inverse of [`recycled_views`]).
fn stored_views(views: Vec<&[f32]>) -> Vec<&'static [f32]> {
    let mut views = std::mem::ManuallyDrop::new(views);
    views.clear();
    let (ptr, cap) = (views.as_mut_ptr(), views.capacity());
    // SAFETY: as above — the vec is emptied before its parts are reused.
    unsafe { Vec::from_raw_parts(ptr.cast::<&'static [f32]>(), 0, cap) }
}

impl<A: Aggregator> Nnm<A> {
    pub fn new(b: usize, base: A) -> Self {
        Nnm { b, base }
    }

    /// Compute the mixed matrix into `mixed` (m rows of d, row-major).
    pub fn mix_into(&self, inputs: &[&[f32]], mixed: &mut Vec<f32>) {
        let mut scratch = SCRATCH.with(|cell| cell.take());
        self.mix_with(inputs, None, mixed, &mut scratch);
        SCRATCH.with(|cell| cell.replace(scratch));
    }

    /// [`mix_into`](Self::mix_into) against explicit scratch, routing the
    /// pairwise matrix through the round cache when `rows` carries one.
    fn mix_with(
        &self,
        inputs: &[&[f32]],
        rows: Option<&RowCtx<'_>>,
        mixed: &mut Vec<f32>,
        scratch: &mut NnmScratch,
    ) {
        let m = inputs.len();
        let d = inputs[0].len();
        let k = m - self.b;
        assert!(k >= 1, "NNM needs m - b >= 1 (m={m}, b={})", self.b);
        pairwise_sqdist_into(inputs, rows, &mut scratch.pairs, &mut scratch.dist);
        mixed.clear();
        mixed.resize(m * d, 0.0);
        let order = &mut scratch.order;
        let dist = &scratch.dist;
        let inv = 1.0 / k as f32;
        for i in 0..m {
            order.clear();
            order.extend(0..m);
            // stable sort by distance, ties by index (order is already
            // index-ascending, and sort_by is stable); non-finite
            // distances rank last
            order.sort_by(|&a, &b| super::rank_cmp(dist[i * m + a], dist[i * m + b]));
            let row = &mut mixed[i * d..(i + 1) * d];
            for &j in &order[..k] {
                crate::util::vecmath::axpy(row, 1.0, inputs[j]);
            }
            crate::util::vecmath::scale(row, inv);
        }
    }

    fn aggregate_impl(&self, inputs: &[&[f32]], rows: Option<&RowCtx<'_>>, out: &mut [f32]) {
        let m = inputs.len();
        let d = out.len();
        let mut scratch = SCRATCH.with(|cell| cell.take());
        let mut mixed = std::mem::take(&mut scratch.mixed);
        self.mix_with(inputs, rows, &mut mixed, &mut scratch);
        // mixed rows are per-victim blends — no identities to hand down,
        // so the base rule runs without a row context
        let mut views = recycled_views(std::mem::take(&mut scratch.views));
        views.extend((0..m).map(|i| &mixed[i * d..(i + 1) * d]));
        self.base.aggregate(&views, out);
        scratch.views = stored_views(views);
        scratch.mixed = mixed;
        SCRATCH.with(|cell| cell.replace(scratch));
    }
}

impl<A: Aggregator> Aggregator for Nnm<A> {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        self.aggregate_impl(inputs, None, out);
    }

    fn aggregate_with_ctx(&self, inputs: &[&[f32]], rows: &RowCtx<'_>, out: &mut [f32]) {
        self.aggregate_impl(inputs, Some(rows), out);
    }

    fn name(&self) -> &'static str {
        // static str limitation: report the composite family name
        "nnm"
    }

    fn min_inputs(&self) -> usize {
        (self.b + 1).max(self.base.min_inputs())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CwTm, DistCache, Mean};
    use super::*;

    fn as_rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn b0_mix_is_global_mean_everywhere() {
        let data = vec![vec![0.0f32, 2.0], vec![2.0, 4.0], vec![4.0, 0.0]];
        let nnm = Nnm::new(0, Mean);
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        for i in 0..3 {
            assert_eq!(&mixed[i * 2..i * 2 + 2], &[2.0, 2.0]);
        }
    }

    #[test]
    fn mixing_shrinks_spread() {
        // Lemma-5.2 flavor: NNM reduces the variance among honest vectors
        let data = vec![
            vec![0.0f32],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![50.0], // outlier
        ];
        let nnm = Nnm::new(1, Mean);
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        // honest rows (first 4) mixed values stay near the honest cluster
        for i in 0..4 {
            assert!(mixed[i] < 10.0, "row {i} = {}", mixed[i]);
        }
    }

    #[test]
    fn self_always_included() {
        // the nearest neighbor of any vector is itself (distance 0)
        let data = vec![vec![0.0f32], vec![100.0], vec![200.0]];
        let nnm = Nnm::new(2, Mean); // k = 1: each row mixes only itself
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        assert_eq!(mixed, vec![0.0, 100.0, 200.0]);
    }

    #[test]
    fn composite_with_cwtm_resists_attack() {
        // 2 Byzantine at huge magnitude among 7: NNM∘CWTM output must stay
        // within the honest hull
        let mut data: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32 * 0.1]).collect();
        data.push(vec![1e8]);
        data.push(vec![-1e8]);
        let rule = Nnm::new(2, CwTm::new(2));
        let mut out = vec![0.0f32; 1];
        rule.aggregate(&as_rows(&data), &mut out);
        assert!((0.0..=0.4).contains(&out[0]), "out={}", out[0]);
    }

    #[test]
    fn tie_break_by_index_matches_oracle_contract() {
        // two equidistant neighbors: lower index wins
        let data = vec![vec![0.0f32], vec![1.0], vec![-1.0], vec![5.0]];
        let nnm = Nnm::new(2, Mean); // k = 2: self + one of {1, 2} for row 0
        let mut mixed = Vec::new();
        nnm.mix_into(&as_rows(&data), &mut mixed);
        // row 0 mixes self(0.0) and index-1 (1.0) -> 0.5
        assert!((mixed[0] - 0.5).abs() < 1e-6, "mixed0={}", mixed[0]);
    }

    #[test]
    fn cached_aggregation_is_byte_identical() {
        // the cache-on path must reproduce the plain path bit-for-bit,
        // cold and warm
        let data: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..33).map(|j| ((i * 33 + j) as f32).sin() * 50.0).collect())
            .collect();
        let inputs = as_rows(&data);
        let rule = Nnm::new(2, CwTm::new(2));
        let mut plain = vec![0.0f32; 33];
        rule.aggregate(&inputs, &mut plain);
        let ids: Vec<Option<u32>> = (0..7).map(|i| Some(i as u32)).collect();
        let cache = DistCache::new();
        let ctx = RowCtx { ids: &ids, cache: Some(&cache) };
        for pass in ["cold", "warm"] {
            let mut out = vec![0.0f32; 33];
            rule.aggregate_with_ctx(&inputs, &ctx, &mut out);
            let pb: Vec<u32> = plain.iter().map(|x| x.to_bits()).collect();
            let ob: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
            assert_eq!(pb, ob, "{pass} cache pass diverged");
        }
        assert_eq!(cache.dist_entries(), 7 * 6 / 2);
    }

    #[test]
    fn non_finite_rows_neither_panic_nor_poison() {
        // NaN / ±Inf are legal adversarial payloads: the old
        // partial_cmp().unwrap() ranking panicked here
        let data = vec![
            vec![0.0f32, 1.0],
            vec![0.1, 1.1],
            vec![0.2, 0.9],
            vec![0.15, 1.05],
            vec![0.05, 0.95],
            vec![f32::NAN, f32::NAN],
            vec![f32::INFINITY, f32::NEG_INFINITY],
        ];
        let rule = Nnm::new(2, CwTm::new(2));
        let mut out = vec![0.0f32; 2];
        rule.aggregate(&as_rows(&data), &mut out);
        assert!(out.iter().all(|v| v.is_finite()), "out={out:?}");
        // honest hull: coordinates of the 5 honest rows
        assert!((0.0..=0.2).contains(&out[0]), "out={out:?}");
        assert!((0.9..=1.1).contains(&out[1]), "out={out:?}");
    }
}
