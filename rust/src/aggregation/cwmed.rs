//! Coordinate-wise median (Yin et al. 2018).
//!
//! Shares [`super::cwtm`]'s fast path: transpose-tiled coordinate
//! gather, total-order integer keys (NaN/±Inf land at the extremes
//! deterministically), and a `select_nth_unstable` median above the
//! measured crossover — bit-identical to the sort-based reference path.

use super::cwtm::{for_each_coord, median_keys};
use super::Aggregator;

#[derive(Clone, Copy, Debug, Default)]
pub struct CwMed;

impl Aggregator for CwMed {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        assert!(!inputs.is_empty());
        for_each_coord(inputs, out, median_keys);
    }

    fn name(&self) -> &'static str {
        "cwmed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn odd_median() {
        let rows = [vec![3.0f32], vec![1.0f32], vec![2.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 2.0);
    }

    #[test]
    fn even_median_interpolates() {
        let rows = [vec![1.0f32], vec![2.0f32], vec![10.0f32], vec![20.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 6.0);
    }

    #[test]
    fn immune_to_minority_outliers() {
        let rows = [vec![0.0f32], vec![0.5f32], vec![1.0f32], vec![1e9f32], vec![1e9f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn non_finite_minority_cannot_move_the_median_off_the_hull() {
        let rows = [
            vec![1.0f32],
            vec![2.0f32],
            vec![3.0f32],
            vec![f32::NAN],
            vec![f32::INFINITY],
        ];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        // total order: 1, 2, 3, +Inf, NaN → median 3 (hull edge), no panic
        assert_eq!(out[0], 3.0);
    }
}
