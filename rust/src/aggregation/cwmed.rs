//! Coordinate-wise median (Yin et al. 2018).

use super::Aggregator;

#[derive(Clone, Copy, Debug, Default)]
pub struct CwMed;

impl Aggregator for CwMed {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let m = inputs.len();
        assert!(m > 0);
        let mut buf: Vec<f32> = vec![0.0; m];
        for (j, o) in out.iter_mut().enumerate() {
            for (slot, row) in buf.iter_mut().zip(inputs) {
                *slot = row[j];
            }
            super::cwtm::insertion_sort(&mut buf);
            *o = if m % 2 == 1 {
                buf[m / 2]
            } else {
                0.5 * (buf[m / 2 - 1] + buf[m / 2])
            };
        }
    }

    fn name(&self) -> &'static str {
        "cwmed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn odd_median() {
        let rows = [vec![3.0f32], vec![1.0f32], vec![2.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 2.0);
    }

    #[test]
    fn even_median_interpolates() {
        let rows = [vec![1.0f32], vec![2.0f32], vec![10.0f32], vec![20.0f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 6.0);
    }

    #[test]
    fn immune_to_minority_outliers() {
        let rows = [vec![0.0f32], vec![0.5f32], vec![1.0f32], vec![1e9f32], vec![1e9f32]];
        let inputs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 1];
        CwMed.aggregate(&inputs, &mut out);
        assert_eq!(out[0], 1.0);
    }
}
