//! Plain averaging — the non-robust gossip baseline every robust figure
//! compares against (it collapses under any of the paper's attacks).

use super::Aggregator;

#[derive(Clone, Copy, Debug, Default)]
pub struct Mean;

impl Aggregator for Mean {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        assert!(!inputs.is_empty());
        let inv = 1.0f64 / inputs.len() as f64;
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for row in inputs {
                acc += row[j] as f64;
            }
            *o = (acc * inv) as f32;
        }
    }

    fn name(&self) -> &'static str {
        "mean"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages() {
        let a = vec![0.0f32, 2.0];
        let b = vec![2.0f32, 4.0];
        let mut out = vec![0.0f32; 2];
        Mean.aggregate(&[&a, &b], &mut out);
        assert_eq!(out, vec![1.0, 3.0]);
    }

    #[test]
    fn single_input_identity() {
        let a = vec![5.0f32, -1.0];
        let mut out = vec![0.0f32; 2];
        Mean.aggregate(&[&a], &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn outlier_drags_mean() {
        // documents WHY mean is the non-robust baseline
        let honest = vec![0.0f32];
        let byz = vec![1e9f32];
        let mut out = vec![0.0f32; 1];
        Mean.aggregate(&[&honest, &honest, &byz], &mut out);
        assert!(out[0] > 1e8);
    }
}
