//! Krum (Blanchard et al. 2017): select the input whose summed squared
//! distance to its m − b − 2 nearest peers (excluding itself) is smallest.
//!
//! The pairwise matrix rides the same Gram-blocked kernel and round
//! [`super::DistCache`] as NNM; neighbor ranking uses the total-order
//! [`super::rank_cmp`] so non-finite adversarial rows rank farthest (and
//! their own all-NaN scores can never win the argmin) instead of
//! panicking the old `partial_cmp().unwrap()` sort.

use super::{pairwise_sqdist_into, Aggregator, PairScratch, RowCtx};
use std::cell::RefCell;

#[derive(Clone, Copy, Debug)]
pub struct Krum {
    pub b: usize,
}

/// Per-thread buffers reused across victims and rounds.
#[derive(Default)]
struct KrumScratch {
    dist: Vec<f64>,
    pairs: PairScratch,
    neigh: Vec<f64>,
}

thread_local! {
    static SCRATCH: RefCell<KrumScratch> = RefCell::new(KrumScratch::default());
}

impl Krum {
    pub fn new(b: usize) -> Self {
        Krum { b }
    }

    /// Index of the Krum-selected input.
    pub fn select(&self, inputs: &[&[f32]]) -> usize {
        self.select_with(inputs, None)
    }

    fn select_with(&self, inputs: &[&[f32]], rows: Option<&RowCtx<'_>>) -> usize {
        let m = inputs.len();
        let k = m
            .checked_sub(self.b + 2)
            .filter(|&k| k >= 1)
            .unwrap_or_else(|| panic!("Krum needs m - b - 2 >= 1 (m={m}, b={})", self.b));
        let mut scratch = SCRATCH.with(|cell| cell.take());
        pairwise_sqdist_into(inputs, rows, &mut scratch.pairs, &mut scratch.dist);
        let dist = &scratch.dist;
        let neigh = &mut scratch.neigh;
        let mut best = (f64::INFINITY, 0usize);
        for i in 0..m {
            neigh.clear();
            for j in 0..m {
                if j != i {
                    neigh.push(dist[i * m + j]);
                }
            }
            neigh.sort_unstable_by(|a, b| super::rank_cmp(*a, *b));
            // ascending sum of the k nearest — a non-finite score (all
            // neighbors poisoned) compares false against best and is
            // simply never selected
            let score: f64 = neigh[..k].iter().sum();
            if score < best.0 {
                best = (score, i);
            }
        }
        SCRATCH.with(|cell| cell.replace(scratch));
        best.1
    }
}

impl Aggregator for Krum {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let idx = self.select_with(inputs, None);
        out.copy_from_slice(inputs[idx]);
    }

    fn aggregate_with_ctx(&self, inputs: &[&[f32]], rows: &RowCtx<'_>, out: &mut [f32]) {
        let idx = self.select_with(inputs, Some(rows));
        out.copy_from_slice(inputs[idx]);
    }

    fn name(&self) -> &'static str {
        "krum"
    }

    fn min_inputs(&self) -> usize {
        self.b + 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn as_rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn returns_an_input() {
        let data = vec![
            vec![0.0f32, 0.0],
            vec![0.1, 0.1],
            vec![0.2, -0.1],
            vec![-0.1, 0.2],
            vec![9.0, 9.0],
        ];
        let mut out = vec![0.0f32; 2];
        Krum::new(1).aggregate(&as_rows(&data), &mut out);
        assert!(data.iter().any(|r| r.as_slice() == out.as_slice()));
    }

    #[test]
    fn rejects_isolated_outlier() {
        let data = vec![
            vec![0.0f32],
            vec![0.1f32],
            vec![0.2f32],
            vec![0.15f32],
            vec![1000.0f32],
        ];
        let idx = Krum::new(1).select(&as_rows(&data));
        assert_ne!(idx, 4);
    }

    #[test]
    fn picks_densest_point() {
        let data = vec![
            vec![0.0f32],
            vec![0.01f32],
            vec![0.02f32],
            vec![5.0f32],
            vec![6.0f32],
        ];
        let idx = Krum::new(1).select(&as_rows(&data));
        assert!(idx <= 2, "selected {idx}");
    }

    #[test]
    #[should_panic]
    fn panics_when_too_few_inputs() {
        let data = vec![vec![0.0f32], vec![1.0f32]];
        Krum::new(1).select(&as_rows(&data));
    }

    #[test]
    fn non_finite_rows_never_win_selection() {
        // the old partial_cmp().unwrap() panicked on the NaN distances;
        // now the poisoned rows rank farthest and an honest row wins
        let data = vec![
            vec![0.0f32],
            vec![0.1f32],
            vec![0.2f32],
            vec![0.15f32],
            vec![f32::NAN],
            vec![f32::INFINITY],
        ];
        let idx = Krum::new(2).select(&as_rows(&data));
        assert!(idx <= 3, "selected poisoned row {idx}");
        let mut out = vec![0.0f32; 1];
        Krum::new(2).aggregate(&as_rows(&data), &mut out);
        assert!(out[0].is_finite());
    }

    #[test]
    fn cached_selection_matches_plain() {
        use super::super::DistCache;
        let data: Vec<Vec<f32>> = (0..6)
            .map(|i| (0..17).map(|j| ((i * 17 + j) as f32 * 0.7).cos()).collect())
            .collect();
        let inputs = as_rows(&data);
        let rule = Krum::new(1);
        let plain = rule.select(&inputs);
        let ids: Vec<Option<u32>> = (0..6).map(|i| Some(i as u32)).collect();
        let cache = DistCache::new();
        let ctx = RowCtx { ids: &ids, cache: Some(&cache) };
        let mut out_plain = vec![0.0f32; 17];
        let mut out_cached = vec![0.0f32; 17];
        rule.aggregate(&inputs, &mut out_plain);
        rule.aggregate_with_ctx(&inputs, &ctx, &mut out_cached); // cold
        assert_eq!(out_plain, out_cached);
        rule.aggregate_with_ctx(&inputs, &ctx, &mut out_cached); // warm
        assert_eq!(out_plain, out_cached);
        assert_eq!(rule.select_with(&inputs, Some(&ctx)), plain);
    }
}
