//! Krum (Blanchard et al. 2017): select the input whose summed squared
//! distance to its m − b − 2 nearest peers (excluding itself) is smallest.

use super::{pairwise_sqdist, Aggregator};

#[derive(Clone, Copy, Debug)]
pub struct Krum {
    pub b: usize,
}

impl Krum {
    pub fn new(b: usize) -> Self {
        Krum { b }
    }

    /// Index of the Krum-selected input.
    pub fn select(&self, inputs: &[&[f32]]) -> usize {
        let m = inputs.len();
        let k = m
            .checked_sub(self.b + 2)
            .filter(|&k| k >= 1)
            .unwrap_or_else(|| panic!("Krum needs m - b - 2 >= 1 (m={m}, b={})", self.b));
        let dist = pairwise_sqdist(inputs);
        let mut best = (f64::INFINITY, 0usize);
        let mut neigh: Vec<f64> = Vec::with_capacity(m - 1);
        for i in 0..m {
            neigh.clear();
            for j in 0..m {
                if j != i {
                    neigh.push(dist[i * m + j]);
                }
            }
            neigh.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let score: f64 = neigh[..k].iter().sum();
            if score < best.0 {
                best = (score, i);
            }
        }
        best.1
    }
}

impl Aggregator for Krum {
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let idx = self.select(inputs);
        out.copy_from_slice(inputs[idx]);
    }

    fn name(&self) -> &'static str {
        "krum"
    }

    fn min_inputs(&self) -> usize {
        self.b + 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn as_rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn returns_an_input() {
        let data = vec![
            vec![0.0f32, 0.0],
            vec![0.1, 0.1],
            vec![0.2, -0.1],
            vec![-0.1, 0.2],
            vec![9.0, 9.0],
        ];
        let mut out = vec![0.0f32; 2];
        Krum::new(1).aggregate(&as_rows(&data), &mut out);
        assert!(data.iter().any(|r| r.as_slice() == out.as_slice()));
    }

    #[test]
    fn rejects_isolated_outlier() {
        let data = vec![
            vec![0.0f32],
            vec![0.1f32],
            vec![0.2f32],
            vec![0.15f32],
            vec![1000.0f32],
        ];
        let idx = Krum::new(1).select(&as_rows(&data));
        assert_ne!(idx, 4);
    }

    #[test]
    fn picks_densest_point() {
        let data = vec![
            vec![0.0f32],
            vec![0.01f32],
            vec![0.02f32],
            vec![5.0f32],
            vec![6.0f32],
        ];
        let idx = Krum::new(1).select(&as_rows(&data));
        assert!(idx <= 2, "selected {idx}");
    }

    #[test]
    #[should_panic]
    fn panics_when_too_few_inputs() {
        let data = vec![vec![0.0f32], vec![1.0f32]];
        Krum::new(1).select(&as_rows(&data));
    }
}
