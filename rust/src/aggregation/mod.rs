//! Robust aggregation rules — Layer-3-native implementations of every rule
//! the paper uses or compares against, plus the HLO/Pallas-backed path
//! (see [`crate::runtime`]) for the headline RPEL rule.
//!
//! Two families:
//!
//! * **Epidemic (pull) rules** implement [`Aggregator`]: given the node's
//!   own half-step model (first row) and the s pulled models, produce the
//!   new model. These are `(s, b̂, κ)`-robust rules in the sense of
//!   Definition 5.1: Mean (non-robust baseline), CWTM, CWMed, Krum,
//!   Geometric Median, NNM∘{any of the above} — the paper's choice is
//!   NNM∘CWTM (§6.1).
//!
//! * **Fixed-graph gossip rules** implement [`GossipAggregator`]: given the
//!   node's model, its neighbors' models and gossip weights, produce the
//!   new model. ClippedGossip (He et al. 2022), CS+ (Gaucher et al. 2025),
//!   GTS (NNA adapted to sparse graphs) and RTC (Yang & Ghaderi 2024).
//!
//! # The aggregation fast path
//!
//! Per round, every honest victim runs its rule over s+1 rows, so the
//! engine's dominant cost is h·(s+1)²·d/2 pairwise distances (NNM, Krum)
//! plus h·d per-coordinate order statistics (CWTM/CWMed). Three layers
//! attack this:
//!
//! 1. **Round-level distance memoization** ([`DistCache`]). The honest
//!    half-steps are *published once per round and shared by every victim
//!    that pulls them*, so the squared distance between two honest rows is
//!    a pure function of the round — the coordinator (and each
//!    `shard-worker`) threads one per-round cache through
//!    `coordinator::shard::AggCtx` into
//!    [`Aggregator::aggregate_with_ctx`], and each honest↔honest pair is
//!    computed once per address space per round instead of once per
//!    victim that co-pulls it. See [`DistCache`] for the exact protocol
//!    — in particular what must stay **per-victim** (any pair touching a
//!    crafted Byzantine row or the victim's own unpublished data).
//!
//! 2. **Gram-blocked pairwise kernel** ([`pairwise_sqdist`]). Distances
//!    come from precomputed row sq-norms plus a tile-blocked
//!    `‖a‖² + ‖b‖² − 2·a·b` inner-product sweep
//!    ([`crate::util::vecmath::dot_tile`], 4-wide unrolled f64
//!    accumulators): each [`vecmath::GRAM_TILE`] column block is swept
//!    across the whole pending pair list while the rows' tiles are hot
//!    in L2, instead of streaming full d-length rows once per pair.
//!
//! 3. **Selection-based coordinate stats** (see [`cwtm`]): per-coordinate
//!    trimmed sums and medians via `select_nth_unstable` over
//!    total-order keys above a measured crossover, with transpose-tiled
//!    gathers so row reads are sequential.
//!
//! # FP policy: grid invariance, not seed identity
//!
//! The blocked kernels change f64 summation order relative to the old
//! serial loops, so results differ (≤ 1e-10 relative, pinned by
//! `rust/tests/agg_kernels.rs`) from pre-fast-path seeds. The binding
//! contract is the one `rust/tests/determinism.rs` enforces: every
//! reduction is a pure function of its inputs with a fixed evaluation
//! order, so results are **bit-identical across the whole (transport ×
//! procs × shards × threads) grid — and with the distance cache on or
//! off**. Cache hits return exactly the bits a miss would compute
//! (same kernel, same tile order), which is what makes the memoization
//! bit-safe.

pub mod cwmed;
pub mod cwtm;
pub mod geomedian;
pub mod gossip;
pub mod krum;
pub mod mean;
pub mod nnm;

pub use cwmed::CwMed;
pub use cwtm::CwTm;
pub use geomedian::GeoMedian;
pub use gossip::{ClippedGossip, CsPlus, GossipAggregator, Gts, NaiveGossip, Rtc};
pub use krum::Krum;
pub use mean::Mean;
pub use nnm::Nnm;

use crate::util::vecmath;
#[allow(clippy::disallowed_types)]
use std::collections::HashMap; // lint: hash-order-exempt (Memo alias below)
use std::sync::RwLock;

/// Lookup-only hash memo used by [`DistCache`]: reads are keyed `get`s
/// and `clear` drops everything, so the seeded iteration order of
/// `HashMap` is never observed and cannot leak into results (the
/// bit-safety argument is on [`DistCache`]).
#[allow(clippy::disallowed_types)]
type Memo<K> = HashMap<K, f64>; // lint: hash-order-exempt (order never observed)

/// Aggregation-fast-path performance counters (process-wide, relaxed
/// atomics — a ledger, not a synchronization point). `bench_aggregation`
/// and `rust/tests/agg_counters.rs` use them to prove the distance cache
/// performs strictly fewer row-pair evaluations than the naive
/// victims × (s+1)² bound; they are NOT deterministic under concurrent
/// runs in one process, so counter-reading tests live in their own
/// test binary.
pub mod perf {
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIST_PAIR_EVALS: AtomicU64 = AtomicU64::new(0);

    /// Row-pair squared-distance evaluations actually computed by the
    /// aggregation kernels since the last reset (cache hits excluded).
    pub fn dist_pair_evals() -> u64 {
        DIST_PAIR_EVALS.load(Ordering::Relaxed)
    }

    /// Reset the row-pair evaluation counter to zero.
    pub fn reset_dist_pair_evals() {
        DIST_PAIR_EVALS.store(0, Ordering::Relaxed);
    }

    pub(crate) fn record_dist_pair_evals(n: u64) {
        if n > 0 {
            DIST_PAIR_EVALS.fetch_add(n, Ordering::Relaxed);
        }
    }
}

/// Lock stripes for the round distance cache: enough that h victims on a
/// full worker pool rarely collide, few enough that `clear()` stays cheap.
const CACHE_STRIPES: usize = 64;

/// Cancellation guard for the Gram-identity distance: results below this
/// fraction of the norm scale `‖a‖² + ‖b‖²` are dominated by the
/// identity's ~d·ε·scale rounding error (d up to 10⁶ → ~2e-10 relative
/// to scale; 1e-6 leaves four orders of margin), so such pairs are
/// recomputed with the direct subtract-square kernel instead. This keeps
/// neighbor rankings exact for near-identical rows — the converged /
/// adversarially-mimicking regime — at the cost of one extra O(d) pass
/// for only those pairs.
const GRAM_GUARD: f64 = 1e-6;

/// Round-scoped memo of honest↔honest squared distances (and row
/// sq-norms), shared by every victim aggregation in one address space.
///
/// # What is cacheable, and why it is bit-safe
///
/// A row is cacheable iff it is one of the round's *published* honest
/// half-steps — identified by its stable honest index, the key both the
/// coordinator and every worker derive identically. Those rows are frozen
/// for the round (the synchronous model: phase 4 reads the immutable
/// phase-1 table), so `‖x_a − x_b‖²` is a pure function of `(round, a,
/// b)`. Both the cached and the uncached path evaluate the identical
/// Gram-blocked kernel ([`vecmath::dot_tile`] tiles in ascending order
/// over `norm_sq(a) + norm_sq(b) − 2·a·b`), so a hit returns exactly the
/// bits a miss would compute — cache-on vs cache-off runs are
/// byte-identical (pinned by `rust/tests/agg_kernels.rs`).
///
/// # What must stay per-victim
///
/// Crafted Byzantine rows are functions of the *victim* (ALIE/FOE etc.
/// condition on the victim's half-step and previous model), so any pair
/// touching one is computed fresh per victim and never inserted — such
/// rows carry no id (`None` in [`RowCtx::ids`]). The cache is cleared at
/// the start of every round's aggregation phase: half-steps change each
/// round, and honest indices would otherwise alias stale rows.
pub struct DistCache {
    /// pair key `(lo << 32) | hi` over honest indices → ‖x_lo − x_hi‖²
    dist: Vec<RwLock<Memo<u64>>>,
    /// honest index → ‖x_i‖² (the Gram kernel's other shared factor)
    norm: Vec<RwLock<Memo<u32>>>,
}

impl DistCache {
    pub fn new() -> DistCache {
        DistCache {
            dist: (0..CACHE_STRIPES).map(|_| RwLock::new(Memo::new())).collect(),
            norm: (0..CACHE_STRIPES).map(|_| RwLock::new(Memo::new())).collect(),
        }
    }

    /// Drop every entry (start of a new round); keeps stripe capacity.
    pub fn clear(&mut self) {
        for stripe in &mut self.dist {
            stripe.get_mut().unwrap().clear();
        }
        for stripe in &mut self.norm {
            stripe.get_mut().unwrap().clear();
        }
    }

    #[inline]
    fn stripe(key: u64) -> usize {
        // Fibonacci multiplicative hash, top bits select the stripe
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 58) as usize % CACHE_STRIPES
    }

    #[inline]
    fn pair_key(a: u32, b: u32) -> u64 {
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        ((lo as u64) << 32) | hi as u64
    }

    /// Cached squared distance between published rows `a` and `b`.
    pub fn get(&self, a: u32, b: u32) -> Option<f64> {
        let key = Self::pair_key(a, b);
        self.dist[Self::stripe(key)].read().unwrap().get(&key).copied()
    }

    fn put(&self, a: u32, b: u32, v: f64) {
        let key = Self::pair_key(a, b);
        self.dist[Self::stripe(key)].write().unwrap().insert(key, v);
    }

    /// Cached sq-norm of published row `id`, computing (and memoizing)
    /// it on miss. Bit-safe for the same reason distances are: `norm_sq`
    /// is a pure function of the frozen row.
    fn norm_get_or(&self, id: u32, row: &[f32]) -> f64 {
        let stripe = Self::stripe(id as u64);
        if let Some(&v) = self.norm[stripe].read().unwrap().get(&id) {
            return v;
        }
        let v = vecmath::norm_sq(row);
        self.norm[stripe].write().unwrap().insert(id, v);
        v
    }

    /// Number of memoized pair distances (tests/diagnostics).
    pub fn dist_entries(&self) -> usize {
        self.dist.iter().map(|s| s.read().unwrap().len()).sum()
    }
}

impl Default for DistCache {
    fn default() -> Self {
        DistCache::new()
    }
}

/// Row-identity context for [`Aggregator::aggregate_with_ctx`]: which
/// input rows are the round's shared published half-steps (keyed by
/// honest index) and the round cache to memoize their pair distances in.
#[derive(Clone, Copy)]
pub struct RowCtx<'a> {
    /// Parallel to `inputs`: `Some(honest_index)` for a published
    /// half-step row, `None` for a per-victim row (crafted Byzantine
    /// payloads). Distances between two identified rows are served from /
    /// inserted into `cache`; anything else is computed fresh.
    pub ids: &'a [Option<u32>],
    /// The round-scoped memo (`None` disables memoization — the result
    /// is byte-identical either way).
    pub cache: Option<&'a DistCache>,
}

/// A robust aggregation rule over m = s+1 vectors (Definition 5.1 family).
///
/// `Send + Sync` with `&self` aggregation is a hard requirement: one rule
/// instance is shared by every worker of the parallel round engine, so
/// implementations keep per-call state on the stack, in thread-local
/// scratch, or behind a lock.
pub trait Aggregator: Send + Sync {
    /// Aggregate `inputs` (row 0 = own half-step model) into `out`.
    /// All rows have equal length d = out.len().
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]);

    /// [`aggregate`](Self::aggregate) with row identities for the round
    /// distance cache. Distance-free rules ignore the context (the
    /// default); NNM and Krum route their pairwise matrices through
    /// [`DistCache`]. The output is byte-identical to `aggregate` —
    /// callers opt in purely for speed.
    fn aggregate_with_ctx(&self, inputs: &[&[f32]], rows: &RowCtx<'_>, out: &mut [f32]) {
        let _ = rows;
        self.aggregate(inputs, out);
    }

    /// Human-readable rule name (figures/benches).
    fn name(&self) -> &'static str;

    /// Smallest input count the rule is defined for (CWTM needs 2b+1,
    /// Krum b+3, …). The coordinator keeps the node's own model when a
    /// round delivers fewer rows (possible in push mode / under DoS).
    fn min_inputs(&self) -> usize {
        1
    }
}

/// Total-order comparator for *ranking* squared distances (NNM neighbor
/// sort, Krum score sort). Non-finite distances — NaN/±Inf rows are
/// legal adversarial payloads, and the Gram identity turns them into
/// NaN/−Inf — all rank as +∞, i.e. "farthest", so a poisoned row can
/// never panic the sort (the old `partial_cmp().unwrap()`) or sneak into
/// a neighborhood ahead of a finite row. Ties keep index order wherever
/// a stable sort is used.
#[inline]
pub(crate) fn rank_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    let key = |x: f64| if x.is_finite() { x } else { f64::INFINITY };
    key(a).total_cmp(&key(b))
}

/// Named rule selection for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuleKind {
    /// Plain average — the non-robust gossip baseline.
    Mean,
    /// Coordinate-wise trimmed mean with trim radius b̂.
    CwTm,
    /// Coordinate-wise median.
    CwMed,
    /// Krum selection.
    Krum,
    /// Geometric median (Weiszfeld).
    GeoMedian,
    /// NNM pre-aggregation, then CWTM — the paper's rule.
    NnmCwtm,
    /// NNM then coordinate-wise median.
    NnmCwMed,
    /// NNM then Krum.
    NnmKrum,
}

impl RuleKind {
    pub fn parse(s: &str) -> Option<RuleKind> {
        Some(match s {
            "mean" => RuleKind::Mean,
            "cwtm" => RuleKind::CwTm,
            "cwmed" => RuleKind::CwMed,
            "krum" => RuleKind::Krum,
            "geomedian" | "gm" => RuleKind::GeoMedian,
            "nnm_cwtm" | "nnm-cwtm" => RuleKind::NnmCwtm,
            "nnm_cwmed" | "nnm-cwmed" => RuleKind::NnmCwMed,
            "nnm_krum" | "nnm-krum" => RuleKind::NnmKrum,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Mean => "mean",
            RuleKind::CwTm => "cwtm",
            RuleKind::CwMed => "cwmed",
            RuleKind::Krum => "krum",
            RuleKind::GeoMedian => "geomedian",
            RuleKind::NnmCwtm => "nnm_cwtm",
            RuleKind::NnmCwMed => "nnm_cwmed",
            RuleKind::NnmKrum => "nnm_krum",
        }
    }

    /// Build the rule for trim/selection radius `bhat`.
    pub fn build(&self, bhat: usize) -> Box<dyn Aggregator> {
        match self {
            RuleKind::Mean => Box::new(Mean),
            RuleKind::CwTm => Box::new(CwTm::new(bhat)),
            RuleKind::CwMed => Box::new(CwMed),
            RuleKind::Krum => Box::new(Krum::new(bhat)),
            RuleKind::GeoMedian => Box::new(GeoMedian::default()),
            RuleKind::NnmCwtm => Box::new(Nnm::new(bhat, CwTm::new(bhat))),
            RuleKind::NnmCwMed => Box::new(Nnm::new(bhat, CwMed)),
            RuleKind::NnmKrum => Box::new(Nnm::new(bhat, Krum::new(bhat))),
        }
    }
}

/// Reusable buffers for [`pairwise_sqdist_into`] — per-thread, retained
/// across victims and rounds by NNM/Krum's thread-local scratch.
#[derive(Default)]
pub(crate) struct PairScratch {
    norms: Vec<f64>,
    have_norm: Vec<bool>,
    /// (i, j) row-index pairs still needing evaluation this call
    pending: Vec<(u32, u32)>,
    /// per-pending-pair dot-product accumulator
    acc: Vec<f64>,
}

/// Pairwise squared-distance matrix of the input rows (f64 — exactness
/// matters for neighbor rankings under adversarial magnitudes, which is
/// what the [`GRAM_GUARD`] fallback preserves for near-identical rows).
///
/// Convenience wrapper over [`pairwise_sqdist_into`] with no cache and
/// fresh scratch — benches and tests; the round engine goes through
/// [`Aggregator::aggregate_with_ctx`].
pub fn pairwise_sqdist(inputs: &[&[f32]]) -> Vec<f64> {
    let mut out = Vec::new();
    pairwise_sqdist_into(inputs, None, &mut PairScratch::default(), &mut out);
    out
}

/// Fill `out` (m×m, row-major, zero diagonal) with pairwise squared
/// distances via the Gram identity `‖a‖² + ‖b‖² − 2·a·b`:
///
/// 1. resolve cached pairs (both rows identified in `rows` and present
///    in the round cache) — no row data is touched for these;
/// 2. memoized sq-norms for every row a pending pair needs;
/// 3. one tile-blocked sweep: each [`vecmath::GRAM_TILE`] column block
///    is applied to the whole pending list ([`vecmath::dot_tile`]),
///    so row tiles stay hot in cache across pairs and the per-pair sum
///    order (ascending blocks) is identical to a lone
///    [`vecmath::dot`] — which is what makes cache hits bit-equal to
///    misses.
///
/// Pairs whose Gram result falls under the [`GRAM_GUARD`] cancellation
/// threshold are recomputed with the direct subtract-square kernel, so
/// accuracy stays relative to the distance even for near-identical rows.
///
/// Newly computed distances between two identified rows are inserted
/// into the cache; pairs touching an unidentified (per-victim) row are
/// never cached. Each computed pair bumps [`perf::dist_pair_evals`].
pub(crate) fn pairwise_sqdist_into(
    inputs: &[&[f32]],
    rows: Option<&RowCtx<'_>>,
    scratch: &mut PairScratch,
    out: &mut Vec<f64>,
) {
    let m = inputs.len();
    let d = inputs.first().map_or(0, |r| r.len());
    out.clear();
    out.resize(m * m, 0.0);
    let cache = rows.and_then(|r| r.cache);
    let ids: &[Option<u32>] = rows.map_or(&[], |r| r.ids);
    debug_assert!(ids.is_empty() || ids.len() == m);
    let id_of = |i: usize| ids.get(i).copied().flatten();

    scratch.pending.clear();
    for i in 0..m {
        for j in (i + 1)..m {
            if let (Some(cache), Some(a), Some(b)) = (cache, id_of(i), id_of(j)) {
                if let Some(v) = cache.get(a, b) {
                    out[i * m + j] = v;
                    out[j * m + i] = v;
                    continue;
                }
            }
            scratch.pending.push((i as u32, j as u32));
        }
    }
    if scratch.pending.is_empty() {
        return;
    }

    // sq-norms for exactly the rows the pending pairs touch (a fully
    // warm cache skips even this); identified rows hit the norm memo
    scratch.norms.clear();
    scratch.norms.resize(m, 0.0);
    scratch.have_norm.clear();
    scratch.have_norm.resize(m, false);
    for &(i, j) in &scratch.pending {
        for idx in [i as usize, j as usize] {
            if !scratch.have_norm[idx] {
                scratch.norms[idx] = match (cache, id_of(idx)) {
                    (Some(cache), Some(id)) => cache.norm_get_or(id, inputs[idx]),
                    _ => vecmath::norm_sq(inputs[idx]),
                };
                scratch.have_norm[idx] = true;
            }
        }
    }

    // tile-blocked Gram sweep over the pending list
    scratch.acc.clear();
    scratch.acc.resize(scratch.pending.len(), 0.0);
    let mut col = 0usize;
    while col < d {
        let end = (col + vecmath::GRAM_TILE).min(d);
        for (acc, &(i, j)) in scratch.acc.iter_mut().zip(&scratch.pending) {
            let (a, b) = (inputs[i as usize], inputs[j as usize]);
            *acc += vecmath::dot_tile(&a[col..end], &b[col..end]);
        }
        col = end;
    }

    for (acc, &(i, j)) in scratch.acc.iter().zip(&scratch.pending) {
        let (i, j) = (i as usize, j as usize);
        let scale = scratch.norms[i] + scratch.norms[j];
        let raw = scale - 2.0 * acc;
        // Cancellation guard: the Gram identity's absolute error is
        // ~d·ε·scale, so when the result lands below GRAM_GUARD·scale
        // (near-identical rows — converged honest half-steps, or mimic
        // rows placed ε-close — exactly where neighbor rankings need
        // exactness) the digits are noise and the sign can even go
        // negative. Those pairs are recomputed with the direct
        // subtract-square kernel, whose error is relative to the
        // *distance* itself. The predicate is a pure function of the
        // rows and sits at the single compute site, so cached and fresh
        // values stay identical; a NaN `raw` fails the comparison and
        // passes through (non-finite rows must keep ranking farthest).
        let v = if raw < GRAM_GUARD * scale {
            vecmath::dist_sq(inputs[i], inputs[j])
        } else {
            raw
        };
        out[i * m + j] = v;
        out[j * m + i] = v;
        if let (Some(cache), Some(a), Some(b)) = (cache, id_of(i), id_of(j)) {
            cache.put(a, b, v);
        }
    }
    perf::record_dist_pair_evals(scratch.pending.len() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn rulekind_parse_roundtrip() {
        for kind in [
            RuleKind::Mean,
            RuleKind::CwTm,
            RuleKind::CwMed,
            RuleKind::Krum,
            RuleKind::GeoMedian,
            RuleKind::NnmCwtm,
            RuleKind::NnmCwMed,
            RuleKind::NnmKrum,
        ] {
            assert_eq!(RuleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(RuleKind::parse("bogus"), None);
    }

    #[test]
    fn pairwise_matrix_properties() {
        let data = vec![vec![0.0, 0.0], vec![3.0, 4.0], vec![0.0, 1.0]];
        let d = pairwise_sqdist(&rows(&data));
        assert_eq!(d[0 * 3 + 1], 25.0);
        assert_eq!(d[1 * 3 + 0], 25.0);
        assert_eq!(d[0 * 3 + 0], 0.0);
        assert_eq!(d[0 * 3 + 2], 1.0);
    }

    #[test]
    fn dist_cache_round_trip_is_bit_identical() {
        // warm hits must return exactly the bits the cold computation
        // produced — the property that makes the memo bit-safe
        let data: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                (0..257)
                    .map(|j| ((i * 257 + j) as f32 * 0.37).sin() * 1e3)
                    .collect()
            })
            .collect();
        let inputs = rows(&data);
        let ids: Vec<Option<u32>> = (0..6).map(|i| Some(i as u32)).collect();
        let cache = DistCache::new();
        let plain = pairwise_sqdist(&inputs);
        let ctx = RowCtx { ids: &ids, cache: Some(&cache) };
        let mut scratch = PairScratch::default();
        let mut cold = Vec::new();
        pairwise_sqdist_into(&inputs, Some(&ctx), &mut scratch, &mut cold);
        assert_eq!(cache.dist_entries(), 6 * 5 / 2);
        let mut warm = Vec::new();
        pairwise_sqdist_into(&inputs, Some(&ctx), &mut scratch, &mut warm);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&plain), bits(&cold), "cold cache vs no cache");
        assert_eq!(bits(&cold), bits(&warm), "warm hits vs cold misses");
    }

    #[test]
    fn per_victim_rows_are_never_cached() {
        let data = vec![
            vec![1.0f32, 2.0, 3.0],
            vec![4.0f32, 5.0, 6.0],
            vec![7.0f32, 8.0, 9.0],
        ];
        let inputs = rows(&data);
        // row 2 is a crafted (per-victim) row: no id
        let ids = vec![Some(0u32), Some(1u32), None];
        let cache = DistCache::new();
        let ctx = RowCtx { ids: &ids, cache: Some(&cache) };
        let mut out = Vec::new();
        pairwise_sqdist_into(&inputs, Some(&ctx), &mut PairScratch::default(), &mut out);
        // only the (0, 1) honest pair is memoized
        assert_eq!(cache.dist_entries(), 1);
        assert!(cache.get(0, 1).is_some());
    }

    #[test]
    fn rank_cmp_sends_poison_to_the_back() {
        use std::cmp::Ordering;
        assert_eq!(rank_cmp(1.0, 2.0), Ordering::Less);
        assert_eq!(rank_cmp(f64::NAN, 1.0), Ordering::Greater);
        assert_eq!(rank_cmp(1.0, f64::NEG_INFINITY), Ordering::Less);
        assert_eq!(rank_cmp(f64::NAN, f64::INFINITY), Ordering::Equal);
    }

    #[test]
    fn all_rules_unanimity() {
        // R(x, x, ..., x) = x for every rule (agreement property)
        let x = vec![1.5f32, -2.0, 0.25, 7.0];
        let data: Vec<Vec<f32>> = (0..7).map(|_| x.clone()).collect();
        let inputs = rows(&data);
        for kind in [
            RuleKind::Mean,
            RuleKind::CwTm,
            RuleKind::CwMed,
            RuleKind::Krum,
            RuleKind::GeoMedian,
            RuleKind::NnmCwtm,
            RuleKind::NnmCwMed,
            RuleKind::NnmKrum,
        ] {
            let rule = kind.build(2);
            let mut out = vec![0.0f32; 4];
            rule.aggregate(&inputs, &mut out);
            for (a, b) in out.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "{} failed unanimity", rule.name());
            }
        }
    }

    #[test]
    fn robust_rules_bounded_by_input_range() {
        // output coordinates stay within [min, max] of inputs for the
        // coordinate-wise and NNM rules
        let data = vec![
            vec![0.0f32, 10.0],
            vec![1.0, 11.0],
            vec![2.0, 12.0],
            vec![100.0, -100.0], // outlier
            vec![1.5, 10.5],
        ];
        let inputs = rows(&data);
        for kind in [RuleKind::CwTm, RuleKind::CwMed, RuleKind::NnmCwtm] {
            let rule = kind.build(1);
            let mut out = vec![0.0f32; 2];
            rule.aggregate(&inputs, &mut out);
            assert!(out[0] >= 0.0 && out[0] <= 100.0, "{}", rule.name());
            assert!(out[1] >= -100.0 && out[1] <= 12.0, "{}", rule.name());
        }
    }
}
