//! Robust aggregation rules — Layer-3-native implementations of every rule
//! the paper uses or compares against, plus the HLO/Pallas-backed path
//! (see [`crate::runtime`]) for the headline RPEL rule.
//!
//! Two families:
//!
//! * **Epidemic (pull) rules** implement [`Aggregator`]: given the node's
//!   own half-step model (first row) and the s pulled models, produce the
//!   new model. These are `(s, b̂, κ)`-robust rules in the sense of
//!   Definition 5.1: Mean (non-robust baseline), CWTM, CWMed, Krum,
//!   Geometric Median, NNM∘{any of the above} — the paper's choice is
//!   NNM∘CWTM (§6.1).
//!
//! * **Fixed-graph gossip rules** implement [`GossipAggregator`]: given the
//!   node's model, its neighbors' models and gossip weights, produce the
//!   new model. ClippedGossip (He et al. 2022), CS+ (Gaucher et al. 2025),
//!   GTS (NNA adapted to sparse graphs) and RTC (Yang & Ghaderi 2024).

pub mod cwmed;
pub mod cwtm;
pub mod geomedian;
pub mod gossip;
pub mod krum;
pub mod mean;
pub mod nnm;

pub use cwmed::CwMed;
pub use cwtm::CwTm;
pub use geomedian::GeoMedian;
pub use gossip::{ClippedGossip, CsPlus, GossipAggregator, Gts, NaiveGossip, Rtc};
pub use krum::Krum;
pub use mean::Mean;
pub use nnm::Nnm;

use crate::util::vecmath;

/// A robust aggregation rule over m = s+1 vectors (Definition 5.1 family).
///
/// `Send + Sync` with `&self` aggregation is a hard requirement: one rule
/// instance is shared by every worker of the parallel round engine, so
/// implementations keep per-call state on the stack (or behind a lock).
pub trait Aggregator: Send + Sync {
    /// Aggregate `inputs` (row 0 = own half-step model) into `out`.
    /// All rows have equal length d = out.len().
    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]);

    /// Human-readable rule name (figures/benches).
    fn name(&self) -> &'static str;

    /// Smallest input count the rule is defined for (CWTM needs 2b+1,
    /// Krum b+3, …). The coordinator keeps the node's own model when a
    /// round delivers fewer rows (possible in push mode / under DoS).
    fn min_inputs(&self) -> usize {
        1
    }
}

/// Named rule selection for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuleKind {
    /// Plain average — the non-robust gossip baseline.
    Mean,
    /// Coordinate-wise trimmed mean with trim radius b̂.
    CwTm,
    /// Coordinate-wise median.
    CwMed,
    /// Krum selection.
    Krum,
    /// Geometric median (Weiszfeld).
    GeoMedian,
    /// NNM pre-aggregation, then CWTM — the paper's rule.
    NnmCwtm,
    /// NNM then coordinate-wise median.
    NnmCwMed,
    /// NNM then Krum.
    NnmKrum,
}

impl RuleKind {
    pub fn parse(s: &str) -> Option<RuleKind> {
        Some(match s {
            "mean" => RuleKind::Mean,
            "cwtm" => RuleKind::CwTm,
            "cwmed" => RuleKind::CwMed,
            "krum" => RuleKind::Krum,
            "geomedian" | "gm" => RuleKind::GeoMedian,
            "nnm_cwtm" | "nnm-cwtm" => RuleKind::NnmCwtm,
            "nnm_cwmed" | "nnm-cwmed" => RuleKind::NnmCwMed,
            "nnm_krum" | "nnm-krum" => RuleKind::NnmKrum,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Mean => "mean",
            RuleKind::CwTm => "cwtm",
            RuleKind::CwMed => "cwmed",
            RuleKind::Krum => "krum",
            RuleKind::GeoMedian => "geomedian",
            RuleKind::NnmCwtm => "nnm_cwtm",
            RuleKind::NnmCwMed => "nnm_cwmed",
            RuleKind::NnmKrum => "nnm_krum",
        }
    }

    /// Build the rule for trim/selection radius `bhat`.
    pub fn build(&self, bhat: usize) -> Box<dyn Aggregator> {
        match self {
            RuleKind::Mean => Box::new(Mean),
            RuleKind::CwTm => Box::new(CwTm::new(bhat)),
            RuleKind::CwMed => Box::new(CwMed),
            RuleKind::Krum => Box::new(Krum::new(bhat)),
            RuleKind::GeoMedian => Box::new(GeoMedian::default()),
            RuleKind::NnmCwtm => Box::new(Nnm::new(bhat, CwTm::new(bhat))),
            RuleKind::NnmCwMed => Box::new(Nnm::new(bhat, CwMed)),
            RuleKind::NnmKrum => Box::new(Nnm::new(bhat, Krum::new(bhat))),
        }
    }
}

/// Pairwise squared-distance matrix of the input rows (f64, exactness
/// matters for neighbor rankings under adversarial magnitudes).
pub fn pairwise_sqdist(inputs: &[&[f32]]) -> Vec<f64> {
    let m = inputs.len();
    let mut d = vec![0.0f64; m * m];
    for i in 0..m {
        for j in (i + 1)..m {
            let v = vecmath::dist_sq(inputs[i], inputs[j]);
            d[i * m + j] = v;
            d[j * m + i] = v;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(data: &[Vec<f32>]) -> Vec<&[f32]> {
        data.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn rulekind_parse_roundtrip() {
        for kind in [
            RuleKind::Mean,
            RuleKind::CwTm,
            RuleKind::CwMed,
            RuleKind::Krum,
            RuleKind::GeoMedian,
            RuleKind::NnmCwtm,
            RuleKind::NnmCwMed,
            RuleKind::NnmKrum,
        ] {
            assert_eq!(RuleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(RuleKind::parse("bogus"), None);
    }

    #[test]
    fn pairwise_matrix_properties() {
        let data = vec![vec![0.0, 0.0], vec![3.0, 4.0], vec![0.0, 1.0]];
        let d = pairwise_sqdist(&rows(&data));
        assert_eq!(d[0 * 3 + 1], 25.0);
        assert_eq!(d[1 * 3 + 0], 25.0);
        assert_eq!(d[0 * 3 + 0], 0.0);
        assert_eq!(d[0 * 3 + 2], 1.0);
    }

    #[test]
    fn all_rules_unanimity() {
        // R(x, x, ..., x) = x for every rule (agreement property)
        let x = vec![1.5f32, -2.0, 0.25, 7.0];
        let data: Vec<Vec<f32>> = (0..7).map(|_| x.clone()).collect();
        let inputs = rows(&data);
        for kind in [
            RuleKind::Mean,
            RuleKind::CwTm,
            RuleKind::CwMed,
            RuleKind::Krum,
            RuleKind::GeoMedian,
            RuleKind::NnmCwtm,
            RuleKind::NnmCwMed,
            RuleKind::NnmKrum,
        ] {
            let rule = kind.build(2);
            let mut out = vec![0.0f32; 4];
            rule.aggregate(&inputs, &mut out);
            for (a, b) in out.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "{} failed unanimity", rule.name());
            }
        }
    }

    #[test]
    fn robust_rules_bounded_by_input_range() {
        // output coordinates stay within [min, max] of inputs for the
        // coordinate-wise and NNM rules
        let data = vec![
            vec![0.0f32, 10.0],
            vec![1.0, 11.0],
            vec![2.0, 12.0],
            vec![100.0, -100.0], // outlier
            vec![1.5, 10.5],
        ];
        let inputs = rows(&data);
        for kind in [RuleKind::CwTm, RuleKind::CwMed, RuleKind::NnmCwtm] {
            let rule = kind.build(1);
            let mut out = vec![0.0f32; 2];
            rule.aggregate(&inputs, &mut out);
            assert!(out[0] >= 0.0 && out[0] <= 100.0, "{}", rule.name());
            assert!(out[1] >= -100.0 && out[1] <= 12.0, "{}", rule.name());
        }
    }
}
