//! Deterministic PRNG substrate: SplitMix64 seeding + Xoshiro256++ stream,
//! with the distribution samplers the coordinator needs (uniform ranges,
//! Gaussian, Gamma/Dirichlet, shuffles, subset sampling).
//!
//! Every stochastic component in the system (data generation, Dirichlet
//! partitioning, epidemic peer sampling, attack noise, graph generation)
//! derives its stream from a single experiment seed, so entire training
//! runs are bit-reproducible — a requirement for the paper's multi-seed
//! confidence intervals.
//!
//! Two derivation mechanisms coexist:
//!
//! * [`Rng::fork`] — sequential child streams for one-shot construction
//!   work (adversary placement, data partitioning, graph generation),
//!   where a fixed derivation order is natural.
//! * [`Rng::stream`] — **counter-based** streams keyed by
//!   `(seed, round, node, tag)` for everything on the round path. A
//!   stream's draws depend only on its key, never on how many draws any
//!   other stream made, so per-node work can be scheduled in any order —
//!   or on any number of worker threads — and still produce bit-identical
//!   results. Tags live in [`stream_tag`].

/// Purpose tags for [`Rng::stream`] keys, so different uses of randomness
/// for the same `(seed, round, node)` never alias.
pub mod stream_tag {
    /// Epidemic pull sampling: the round's `S_i^t` draw.
    pub const PULL: u64 = 0x50;
    /// Push-mode recipient scatter for one honest sender.
    pub const PUSH: u64 = 0x51;
    /// Per-victim attack randomness (reserved; current attacks are
    /// deterministic functions of the honest state).
    pub const ATTACK: u64 = 0x52;
    /// Fault-injection schedule of the chaos test harness
    /// ([`crate::testkit::chaos`]): split-read and short-write sizes are
    /// a pure function of `(seed, op_index, 0, CHAOS)`, so every chaotic
    /// failure reproduces from its seed.
    pub const CHAOS: u64 = 0x53;
    /// Virtual-clock compute latency of one node in one round
    /// ([`crate::util::vclock`]): the straggler distribution draws its
    /// uniform from `(seed, round, node, LATENCY)`, so the asynchronous
    /// round schedule is a pure function of the experiment seed.
    pub const LATENCY: u64 = 0x54;
    /// Churn schedule ([`crate::util::vclock`]): the per-round
    /// crash/rejoin coin of one node is the first draw of
    /// `(seed, round, node, CHURN)`.
    pub const CHURN: u64 = 0x55;
    /// Partial-participation coin ([`crate::coordinator::vnode`]): a node
    /// is active in a round iff the first `f64` of
    /// `(seed, round, node, PARTICIPATE)` lands below the configured
    /// participation fraction. Keyed by the **global** node id, so the
    /// coordinator, every shard backend, and every worker process derive
    /// the same active set independently.
    pub const PARTICIPATE: u64 = 0x56;
}

/// Xoshiro256++ PRNG (Blackman & Vigna), seeded through SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream tagged by `tag`.
    ///
    /// Uses the SplitMix64 avalanche over (next_u64, tag) so forked streams
    /// are decorrelated from the parent and from each other.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Counter-based stream keyed by `(seed, round, node, tag)`.
    ///
    /// Unlike [`Rng::fork`], this is a pure function of its key: it holds
    /// no relationship to any other stream's position, which is what makes
    /// the round engine's randomness independent of execution order and
    /// thread count. Every key component is absorbed through a full
    /// SplitMix64 avalanche before the state words are drawn, so all four
    /// state words depend on all four key components.
    pub fn stream(seed: u64, round: u64, node: u64, tag: u64) -> Rng {
        let mut sm = seed;
        sm = splitmix64(&mut sm) ^ round.wrapping_mul(0xA076_1D64_78BD_642F);
        sm = splitmix64(&mut sm) ^ node.wrapping_mul(0xE703_7ED1_A0B4_28DB);
        sm = splitmix64(&mut sm) ^ tag.wrapping_mul(0x8EBC_6AF0_9C88_C6E3);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached second draw discarded for
    /// simplicity; the coordinator is not gaussian-throughput-bound).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Gaussian with given mean and standard deviation, as f32.
    #[inline]
    pub fn gaussian32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `k` categories.
    pub fn dirichlet_sym(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to a one-hot draw
            let mut out = vec![0.0; k];
            out[self.index(k)] = 1.0;
            return out;
        }
        for x in &mut v {
            *x /= sum;
        }
        v
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices uniformly from [0, n) — Floyd's
    /// algorithm, O(k) expected. Result order is randomized.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k > n / 2 {
            // dense case: partial Fisher–Yates over the full index range
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.index(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            return idx;
        }
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        self.shuffle(&mut chosen);
        chosen
    }

    /// Sample `k` distinct indices from [0, n) excluding `skip`.
    pub fn sample_distinct_excluding(&mut self, n: usize, k: usize, skip: usize) -> Vec<usize> {
        assert!(skip < n && k <= n - 1);
        let mut v = self.sample_distinct(n - 1, k);
        for x in &mut v {
            if *x >= skip {
                *x += 1;
            }
        }
        v
    }

    /// One hypergeometric draw HG(total, marked, draws): the number of
    /// marked items in a uniform sample of `draws` items without
    /// replacement. Exact sequential method, O(draws).
    pub fn hypergeometric(&mut self, total: u64, marked: u64, draws: u64) -> u64 {
        debug_assert!(marked <= total && draws <= total);
        let mut rem_total = total;
        let mut rem_marked = marked;
        let mut hits = 0;
        for _ in 0..draws {
            if rem_marked == 0 {
                break;
            }
            if self.f64() * rem_total as f64 > (rem_total - rem_marked) as f64 {
                hits += 1;
                rem_marked -= 1;
            }
            rem_total -= 1;
        }
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn stream_is_pure_function_of_key() {
        let a: Vec<u64> = {
            let mut r = Rng::stream(7, 3, 11, stream_tag::PULL);
            (0..32).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::stream(7, 3, 11, stream_tag::PULL);
            (0..32).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn stream_distinguishes_every_key_component() {
        let base = Rng::stream(1, 2, 3, 4).next_u64();
        assert_ne!(base, Rng::stream(9, 2, 3, 4).next_u64());
        assert_ne!(base, Rng::stream(1, 9, 3, 4).next_u64());
        assert_ne!(base, Rng::stream(1, 2, 9, 4).next_u64());
        assert_ne!(base, Rng::stream(1, 2, 3, 9).next_u64());
    }

    #[test]
    fn stream_outputs_roughly_uniform_across_nodes() {
        // first draw of each per-node stream within one round must look
        // uniform — the property the parallel engine's sampling rests on
        let mut counts = [0u32; 8];
        for node in 0..80_000u64 {
            let x = Rng::stream(42, 17, node, stream_tag::PULL).next_u64();
            counts[(x >> 61) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for &alpha in &[0.1, 1.0, 10.0] {
            let v = r.dirichlet_sym(alpha, 12);
            assert_eq!(v.len(), 12);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_effect() {
        // low alpha -> concentrated (high max); high alpha -> flat
        let mut r = Rng::new(7);
        let trials = 300;
        let avg_max = |r: &mut Rng, alpha: f64| -> f64 {
            (0..trials)
                .map(|_| {
                    r.dirichlet_sym(alpha, 10)
                        .into_iter()
                        .fold(0.0f64, f64::max)
                })
                .sum::<f64>()
                / trials as f64
        };
        let lo = avg_max(&mut r, 0.1);
        let hi = avg_max(&mut r, 100.0);
        assert!(lo > 0.5 && hi < 0.2, "lo={lo} hi={hi}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(8);
        for &(n, k) in &[(10usize, 3usize), (100, 99), (5, 5), (1000, 1), (16, 8)] {
            let v = r.sample_distinct(n, k);
            assert_eq!(v.len(), k);
            let mut sorted = v.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {v:?}");
            assert!(v.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn sample_distinct_uniform_inclusion() {
        // each index should appear with probability k/n
        let mut r = Rng::new(9);
        let (n, k, trials) = (20usize, 5usize, 40_000usize);
        let mut counts = vec![0u32; n];
        for _ in 0..trials {
            for i in r.sample_distinct(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 0.08 * expect,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn sample_excluding_never_returns_skip() {
        let mut r = Rng::new(10);
        for _ in 0..500 {
            let v = r.sample_distinct_excluding(12, 6, 4);
            assert!(!v.contains(&4));
            assert!(v.iter().all(|&x| x < 12));
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 6);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn hypergeometric_support_and_mean() {
        let mut r = Rng::new(12);
        let (total, marked, draws) = (99u64, 10u64, 15u64);
        let n = 30_000;
        let mut sum = 0u64;
        for _ in 0..n {
            let x = r.hypergeometric(total, marked, draws);
            assert!(x <= marked.min(draws));
            sum += x;
        }
        let mean = sum as f64 / n as f64;
        let expect = draws as f64 * marked as f64 / total as f64; // ≈ 1.515
        assert!((mean - expect).abs() < 0.05, "mean={mean} expect={expect}");
    }

    #[test]
    fn hypergeometric_edge_cases() {
        let mut r = Rng::new(13);
        assert_eq!(r.hypergeometric(10, 0, 5), 0);
        assert_eq!(r.hypergeometric(10, 10, 5), 5);
        assert_eq!(r.hypergeometric(10, 4, 10), 4);
        assert_eq!(r.hypergeometric(10, 4, 0), 0);
    }
}
