//! Descriptive statistics for metrics and figure series: means, standard
//! deviations, percentiles, and the confidence intervals the paper draws
//! around its Figure 3 simulation curves.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0.0 for < 2 samples).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile with linear interpolation, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Min / max helpers that ignore NaN-free invariants (assert on empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Normal-approximation confidence interval half-width at ~95%
/// (1.96 σ/√n) — what the paper's Figure 3 error bars use over 5 sims.
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Summary bundle used by the bench harness and figure reports.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: min(xs),
        p50: percentile(xs, 50.0),
        p95: percentile(xs, 95.0),
        max: max(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.13808993).abs() < 1e-6);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(mean(&[3.0]), 3.0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn ci_narrows_with_samples() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b: Vec<f64> = a.iter().cycle().take(64).copied().collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
