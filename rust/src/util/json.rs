//! Minimal JSON parser + writer (serde is not in the offline crate set).
//!
//! Parses the artifact `manifest.json` and the oracle fixture files emitted
//! by `python/compile/aot.py`, and serializes metrics/results. Supports the
//! full JSON grammar except unicode surrogate-pair escapes beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Decode an array of numbers into f32s.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    /// Decode an array of numbers into i64s.
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|x| x as i64).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte position. (Manual `Display`/`Error` impls —
/// `thiserror` is not in the offline crate set.)
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("d").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo ∘ wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∘ wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b"},"z":null}"#;
        let v = parse(doc).unwrap();
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn f32_vec_decoding() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn serializer_escapes() {
        let v = Json::Str("a\"b\n\t\\".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }
}
