//! Deterministic virtual clock for asynchronous rounds.
//!
//! Real fleets are heterogeneous and flaky; wall clocks are not
//! reproducible. This module models both straggling and churn on a
//! **virtual** clock whose every tick is a pure function of the
//! experiment seed: node `i`'s compute latency in round `t` is drawn from
//! a configurable straggler distribution via
//! `Rng::stream(seed, t, i, LATENCY)`, its crash/rejoin coin from
//! `Rng::stream(seed, t, i, CHURN)`. The coordinator closes the round at
//! the virtual time the configured quorum of non-down honest nodes has
//! arrived (optionally capped by a virtual deadline), and every node that
//! missed the cut is *stale*: its published row is served under the
//! bounded-staleness policy below instead of its fresh half-step.
//!
//! ## Staleness policy (the modeled knob)
//!
//! For an honest node with staleness `st` in the current round
//! (`st = round − last round its snapshot arrived`, saturated at
//! `max_staleness + 1`):
//!
//! * `st == 0` — fresh: its half-step row is served unchanged and
//!   recorded as the node's *carried* snapshot.
//! * `1 ≤ st ≤ max_staleness` (carried snapshot available) — the carried
//!   row is integrated, aged per [`StalePolicyKind`]:
//!   - `Carry`: served verbatim (a late snapshot is still a snapshot);
//!   - `Decay`: served as `params + λ^st · (carried − params)` — the
//!     stale direction shrinks toward the node's committed params with
//!     one factor of `λ` per round of age. `λ^st` is computed by
//!     repeated `f64` multiplication (never `powi`) and applied in
//!     `f32`, so the served bits are a pure function of
//!     `(policy, λ, st, carried, params)`.
//! * otherwise (too stale, or no snapshot ever arrived) — the node's
//!   committed params are served: peers see its frozen model, never a
//!   dropped row, so receive sets, routing tables and message budgets
//!   are untouched by asynchrony.
//!
//! A node that is not fresh also does not *commit*: its aggregation
//! result is discarded and its params/ledgers stay at the pre-round
//! state, exactly as if the round closed without it. Because staleness
//! is modeled (bit-exact serve transform) rather than measured (FP
//! noise), a fixed async config is bit-identical across the whole
//! transport × procs × shards × threads grid, and the neutral config
//! (`quorum = h`, `max_staleness = 0`, no churn, constant latency)
//! reproduces synchronous runs bit-for-bit.
//!
//! ## Churn
//!
//! With `crash_prob > 0`, each round every honest node draws one uniform
//! from its CHURN stream; a node that is currently up crashes when the
//! draw falls below `crash_prob` and stays down for `down_rounds`
//! rounds. A partition window (`part_from ≤ round < part_to`) forces the
//! first `part_nodes` honest nodes down for its duration. Down nodes are
//! modeled as infinite-latency stragglers: they never make the quorum,
//! their rows age like any straggler's, and on rejoin they are simply
//! fresh again — no special-cased protocol state.

use crate::util::rng::{stream_tag, Rng};

/// Per-round compute-latency distribution of the virtual clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StragglerKind {
    /// Every node takes `base_latency` exactly (no draws).
    Constant,
    /// With probability `slow_prob` a node takes `slow_latency`,
    /// otherwise `base_latency` — the classic slow-node model.
    TwoPoint,
    /// `base_latency · exp(σ · Φ⁻¹(u))` by inverse-CDF sampling — a
    /// lognormal latency with log-scale σ.
    LogNormal,
}

impl StragglerKind {
    pub fn parse(s: &str) -> Option<StragglerKind> {
        match s {
            "constant" => Some(StragglerKind::Constant),
            "two_point" | "twopoint" => Some(StragglerKind::TwoPoint),
            "lognormal" | "log_normal" => Some(StragglerKind::LogNormal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StragglerKind::Constant => "constant",
            StragglerKind::TwoPoint => "two_point",
            StragglerKind::LogNormal => "lognormal",
        }
    }
}

/// How a stale-but-within-bound carried snapshot is integrated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalePolicyKind {
    /// Serve the carried snapshot verbatim.
    Carry,
    /// Shrink the carried direction toward committed params by one
    /// factor of `stale_decay` per round of age.
    Decay,
}

impl StalePolicyKind {
    pub fn parse(s: &str) -> Option<StalePolicyKind> {
        match s {
            "carry" => Some(StalePolicyKind::Carry),
            "decay" => Some(StalePolicyKind::Decay),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StalePolicyKind::Carry => "carry",
            StalePolicyKind::Decay => "decay",
        }
    }
}

/// Asynchronous-round knobs (the `[async]` TOML section). The all-default
/// value means "synchronous": [`AsyncCfg::is_enabled`] is false and the
/// round engine takes its classic lockstep path.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncCfg {
    /// Honest snapshots required to close a round; 0 means "all honest"
    /// (and, with every other knob at default, asynchrony off).
    pub quorum: usize,
    /// Virtual-time cap on the round close; 0 disables the cap. When the
    /// quorum has not arrived by the deadline the round closes anyway
    /// with fewer fresh nodes.
    pub deadline: f64,
    /// Rounds a late snapshot may age before peers fall back to the
    /// node's committed params.
    pub max_staleness: usize,
    /// Integration rule for stale-but-within-bound snapshots.
    pub stale_policy: StalePolicyKind,
    /// λ for [`StalePolicyKind::Decay`].
    pub stale_decay: f64,
    /// Latency distribution.
    pub straggler: StragglerKind,
    /// Baseline per-round compute latency (virtual units).
    pub base_latency: f64,
    /// TwoPoint: probability of a slow round.
    pub slow_prob: f64,
    /// TwoPoint: latency of a slow round.
    pub slow_latency: f64,
    /// LogNormal: log-scale σ.
    pub sigma: f64,
    /// Per-round crash probability of an up node; 0 disables churn.
    pub crash_prob: f64,
    /// Rounds a crashed node stays down before rejoining.
    pub down_rounds: usize,
    /// Partition window: rounds `[part_from, part_to)` force the first
    /// `part_nodes` honest nodes down.
    pub part_from: usize,
    pub part_to: usize,
    pub part_nodes: usize,
}

impl Default for AsyncCfg {
    fn default() -> Self {
        AsyncCfg {
            quorum: 0,
            deadline: 0.0,
            max_staleness: 0,
            stale_policy: StalePolicyKind::Carry,
            stale_decay: 0.5,
            straggler: StragglerKind::Constant,
            base_latency: 1.0,
            slow_prob: 0.1,
            slow_latency: 4.0,
            sigma: 0.5,
            crash_prob: 0.0,
            down_rounds: 2,
            part_from: 0,
            part_to: 0,
            part_nodes: 0,
        }
    }
}

impl AsyncCfg {
    /// Whether any knob moves the engine off the synchronous path. Note
    /// `quorum = h` counts as enabled: the async machinery runs (and is
    /// pinned bit-identical to the synchronous engine).
    pub fn is_enabled(&self) -> bool {
        self.quorum != 0
            || self.deadline > 0.0
            || self.max_staleness != 0
            || self.crash_prob > 0.0
            || self.part_to > self.part_from
            || self.straggler != StragglerKind::Constant
    }

    /// Range/finiteness validation (the experiment-level `quorum ≤ h`
    /// check lives in `ExperimentConfig::validate`, which knows h).
    pub fn validate(&self) -> Result<(), String> {
        if !self.deadline.is_finite() || self.deadline < 0.0 {
            return Err(format!("async.deadline must be finite and >= 0, got {}", self.deadline));
        }
        if !self.stale_decay.is_finite() || !(0.0..=1.0).contains(&self.stale_decay) {
            return Err(format!("async.stale_decay must be in [0,1], got {}", self.stale_decay));
        }
        if !self.base_latency.is_finite() || self.base_latency <= 0.0 {
            return Err(format!("async.base_latency must be finite and > 0, got {}", self.base_latency));
        }
        if !self.slow_prob.is_finite() || !(0.0..=1.0).contains(&self.slow_prob) {
            return Err(format!("async.slow_prob must be in [0,1], got {}", self.slow_prob));
        }
        if !self.slow_latency.is_finite() || self.slow_latency <= 0.0 {
            return Err(format!("async.slow_latency must be finite and > 0, got {}", self.slow_latency));
        }
        if !self.sigma.is_finite() || self.sigma < 0.0 {
            return Err(format!("async.sigma must be finite and >= 0, got {}", self.sigma));
        }
        if !self.crash_prob.is_finite() || !(0.0..=1.0).contains(&self.crash_prob) {
            return Err(format!("async.crash_prob must be in [0,1], got {}", self.crash_prob));
        }
        if self.crash_prob > 0.0 && self.down_rounds == 0 {
            return Err("async.down_rounds must be >= 1 when crash_prob > 0".into());
        }
        Ok(())
    }
}

/// One round's resolved schedule: who arrived, who is down, how stale
/// every honest node's served row is, and the virtual close time.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundSchedule {
    pub round: u64,
    /// Virtual time the round closed (0.0 when no node could arrive).
    pub close: f64,
    /// Per honest node: snapshot arrived by the close.
    pub fresh: Vec<bool>,
    /// Per honest node: crashed or partitioned away this round.
    pub down: Vec<bool>,
    /// Per honest node: rounds since its snapshot last arrived,
    /// saturated at `max_staleness + 1` (0 = fresh; the saturation value
    /// = params fallback). This is exactly the slice shipped to shard
    /// workers and the staleness-histogram bucket index.
    pub stale: Vec<u32>,
}

impl RoundSchedule {
    /// Number of fresh honest nodes (the participation ledger entry).
    pub fn participation(&self) -> u32 {
        self.stale.iter().filter(|&&s| s == 0).count() as u32
    }
}

/// Draw one node's compute latency for one round — a pure function of
/// the key, exposed for the independent-recomputation tests.
pub fn sample_latency(cfg: &AsyncCfg, seed: u64, round: u64, node: u64) -> f64 {
    match cfg.straggler {
        StragglerKind::Constant => cfg.base_latency,
        StragglerKind::TwoPoint => {
            let u = Rng::stream(seed, round, node, stream_tag::LATENCY).f64();
            if u < cfg.slow_prob {
                cfg.slow_latency
            } else {
                cfg.base_latency
            }
        }
        StragglerKind::LogNormal => {
            // f64() ∈ [0,1); clamp away from 0 (Φ⁻¹ rejects the boundary)
            let u = Rng::stream(seed, round, node, stream_tag::LATENCY)
                .f64()
                .max(1e-12);
            cfg.base_latency * (cfg.sigma * crate::util::special::inverse_normal_cdf(u)).exp()
        }
    }
}

/// λ^stale by repeated multiplication: `powi` is not guaranteed to be
/// correctly rounded, a plain product of f64s is — the served bits must
/// be reproducible everywhere.
pub fn decay_weight(lambda: f64, stale: u32) -> f32 {
    let mut w = 1.0f64;
    for _ in 0..stale {
        w *= lambda;
    }
    w as f32
}

/// Apply the staleness policy to one honest node's published row, in
/// place. `half` enters as the node's current half-step and leaves as
/// the row its peers will actually see; `carried` is the node's last
/// fresh snapshot (refreshed here when `stale == 0`); `params` are its
/// committed params (the too-stale fallback). Shared verbatim by the
/// in-process trainer and the shard-worker processes so both serve
/// bit-identical rows.
pub fn serve_row(
    cfg: &AsyncCfg,
    stale: u32,
    half: &mut Vec<f32>,
    carried: &mut Option<Vec<f32>>,
    params: &[f32],
) {
    if stale == 0 {
        match carried {
            Some(c) => c.copy_from_slice(half),
            None => *carried = Some(half.clone()),
        }
        return;
    }
    match carried {
        Some(c) if (stale as usize) <= cfg.max_staleness => match cfg.stale_policy {
            StalePolicyKind::Carry => half.copy_from_slice(c),
            StalePolicyKind::Decay => {
                let wf = decay_weight(cfg.stale_decay, stale);
                for ((h, &cv), &p) in half.iter_mut().zip(c.iter()).zip(params) {
                    *h = p + wf * (cv - p);
                }
            }
        },
        _ => half.copy_from_slice(params),
    }
}

/// The virtual clock itself: owns the churn state (`down_until`) and the
/// arrival history (`last_fresh`), and resolves one [`RoundSchedule`]
/// per round. Lives on the coordinator only — workers receive their
/// stale slice over the wire.
#[derive(Clone, Debug)]
pub struct VClock {
    cfg: AsyncCfg,
    seed: u64,
    h: usize,
    /// First round index at which the node is up again (exclusive bound
    /// of its down window); 0 = never crashed.
    down_until: Vec<u64>,
    /// Last round the node's snapshot arrived; 0 = never (rounds are
    /// 1-based).
    last_fresh: Vec<u64>,
}

impl VClock {
    pub fn new(cfg: &AsyncCfg, seed: u64, h: usize) -> VClock {
        VClock {
            cfg: cfg.clone(),
            seed,
            h,
            down_until: vec![0; h],
            last_fresh: vec![0; h],
        }
    }

    /// Resolve round `round` (1-based, strictly increasing across calls).
    pub fn advance(&mut self, round: u64) -> RoundSchedule {
        let h = self.h;
        let cfg = &self.cfg;
        // churn coins: one CHURN draw per node per round; an up node
        // crashes when its coin lands below crash_prob
        if cfg.crash_prob > 0.0 {
            for i in 0..h {
                let u = Rng::stream(self.seed, round, i as u64, stream_tag::CHURN).f64();
                if u < cfg.crash_prob && round >= self.down_until[i] {
                    self.down_until[i] = round + cfg.down_rounds as u64;
                }
            }
        }
        let in_partition = (round as usize) >= cfg.part_from && (round as usize) < cfg.part_to;
        let down: Vec<bool> = (0..h)
            .map(|i| round < self.down_until[i] || (in_partition && i < cfg.part_nodes))
            .collect();
        let lat: Vec<f64> = (0..h)
            .map(|i| {
                if down[i] {
                    f64::INFINITY
                } else {
                    sample_latency(cfg, self.seed, round, i as u64)
                }
            })
            .collect();
        // close at the quorum-th arrival among non-down nodes, capped by
        // the deadline when one is set
        let mut alive: Vec<f64> = lat.iter().copied().filter(|l| l.is_finite()).collect();
        alive.sort_unstable_by(f64::total_cmp);
        let q = if cfg.quorum == 0 { h } else { cfg.quorum };
        let q_eff = q.min(alive.len());
        let mut close = if q_eff == 0 { 0.0 } else { alive[q_eff - 1] };
        if cfg.deadline > 0.0 {
            close = close.min(cfg.deadline);
        }
        let fresh: Vec<bool> = (0..h).map(|i| !down[i] && lat[i] <= close).collect();
        let cap = cfg.max_staleness as u64 + 1;
        let stale: Vec<u32> = (0..h)
            .map(|i| {
                if fresh[i] {
                    self.last_fresh[i] = round;
                    0
                } else {
                    (round - self.last_fresh[i]).min(cap) as u32
                }
            })
            .collect();
        RoundSchedule {
            round,
            close,
            fresh,
            down,
            stale,
        }
    }

    /// Snapshot the mutable clock state (`down_until`, `last_fresh`) for
    /// checkpointing or round-retry rollback. Everything else about the
    /// clock is a pure function of `(cfg, seed, round)`, so this pair is
    /// the complete durable state: `restore`-ing it into a fresh clock
    /// built from the same config resumes bit-identically.
    pub fn state(&self) -> (Vec<u64>, Vec<u64>) {
        (self.down_until.clone(), self.last_fresh.clone())
    }

    /// Restore a state captured by [`VClock::state`]. Errors if the
    /// vector lengths do not match this clock's honest count (a resume
    /// against a different world).
    pub fn restore(&mut self, down_until: Vec<u64>, last_fresh: Vec<u64>) -> Result<(), String> {
        if down_until.len() != self.h || last_fresh.len() != self.h {
            return Err(format!(
                "vclock state for {} node(s) cannot restore into a clock of {}",
                down_until.len().max(last_fresh.len()),
                self.h
            ));
        }
        self.down_until = down_until;
        self.last_fresh = last_fresh;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AsyncCfg {
        AsyncCfg::default()
    }

    #[test]
    fn default_is_disabled_and_valid() {
        let c = cfg();
        assert!(!c.is_enabled());
        c.validate().unwrap();
    }

    #[test]
    fn any_moved_knob_enables() {
        for f in [
            (|c: &mut AsyncCfg| c.quorum = 10) as fn(&mut AsyncCfg),
            |c| c.deadline = 2.0,
            |c| c.max_staleness = 1,
            |c| c.crash_prob = 0.1,
            |c| c.part_to = 3,
            |c| c.straggler = StragglerKind::TwoPoint,
        ] {
            let mut c = cfg();
            f(&mut c);
            assert!(c.is_enabled(), "{c:?}");
        }
    }

    #[test]
    fn validate_rejects_bad_ranges() {
        for f in [
            (|c: &mut AsyncCfg| c.deadline = -1.0) as fn(&mut AsyncCfg),
            |c| c.deadline = f64::NAN,
            |c| c.stale_decay = 1.5,
            |c| c.base_latency = 0.0,
            |c| c.slow_prob = -0.1,
            |c| c.slow_latency = f64::INFINITY,
            |c| c.sigma = -1.0,
            |c| c.crash_prob = 2.0,
            |c| {
                c.crash_prob = 0.5;
                c.down_rounds = 0;
            },
        ] {
            let mut c = cfg();
            f(&mut c);
            assert!(c.validate().is_err(), "{c:?}");
        }
    }

    #[test]
    fn neutral_schedule_everyone_fresh() {
        // quorum = h, constant latency: the synchronous-equivalent config
        let mut c = cfg();
        c.quorum = 8;
        let mut vc = VClock::new(&c, 7, 8);
        for round in 1..=5u64 {
            let s = vc.advance(round);
            assert_eq!(s.close, 1.0);
            assert!(s.fresh.iter().all(|&f| f));
            assert!(s.down.iter().all(|&d| !d));
            assert!(s.stale.iter().all(|&st| st == 0));
            assert_eq!(s.participation(), 8);
        }
    }

    #[test]
    fn schedules_are_reproducible() {
        let mut c = cfg();
        c.quorum = 5;
        c.max_staleness = 2;
        c.straggler = StragglerKind::TwoPoint;
        c.slow_prob = 0.3;
        c.crash_prob = 0.1;
        let run = |seed| {
            let mut vc = VClock::new(&c, seed, 9);
            (1..=20u64).map(|r| vc.advance(r)).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12));
    }

    #[test]
    fn state_restore_resumes_bit_identically() {
        let mut c = cfg();
        c.quorum = 5;
        c.max_staleness = 2;
        c.straggler = StragglerKind::TwoPoint;
        c.slow_prob = 0.3;
        c.crash_prob = 0.1;
        let mut straight = VClock::new(&c, 7, 9);
        let _first: Vec<RoundSchedule> = (1..=10u64).map(|r| straight.advance(r)).collect();
        // fork a fresh clock at round 10 from the captured state: the
        // remaining schedule must match the straight-through run exactly
        let (down, fresh) = straight.state();
        let mut resumed = VClock::new(&c, 7, 9);
        resumed.restore(down, fresh).unwrap();
        let tail_a: Vec<RoundSchedule> = (11..=20u64).map(|r| straight.advance(r)).collect();
        let tail_b: Vec<RoundSchedule> = (11..=20u64).map(|r| resumed.advance(r)).collect();
        assert_eq!(tail_a, tail_b);
        // a wrong-world restore is a named error, not silent corruption
        let err = resumed.restore(vec![0; 4], vec![0; 4]).unwrap_err();
        assert!(err.contains("cannot restore into a clock of 9"), "{err}");
    }

    #[test]
    fn two_point_quorum_close_picks_qth_latency() {
        let mut c = cfg();
        c.quorum = 6;
        c.max_staleness = 1;
        c.straggler = StragglerKind::TwoPoint;
        c.slow_prob = 0.4;
        let mut vc = VClock::new(&c, 3, 10);
        let mut saw_partial = false;
        for round in 1..=30u64 {
            let s = vc.advance(round);
            let slow = (0..10)
                .filter(|&i| sample_latency(&c, 3, round, i) == c.slow_latency)
                .count();
            if slow <= 10 - c.quorum {
                // quorum reachable on fast nodes: close = base, only
                // fast nodes fresh
                assert_eq!(s.close, c.base_latency, "round {round}");
                assert_eq!(s.participation() as usize, 10 - slow);
                if slow > 0 {
                    saw_partial = true;
                }
            } else {
                // the quorum-th arrival is a slow node: everyone makes it
                assert_eq!(s.close, c.slow_latency, "round {round}");
                assert_eq!(s.participation(), 10);
            }
        }
        assert!(saw_partial, "slow_prob=0.4 over 30 rounds must straggle");
    }

    #[test]
    fn deadline_caps_close() {
        let mut c = cfg();
        c.quorum = 4;
        c.deadline = 2.0;
        c.max_staleness = 1;
        c.straggler = StragglerKind::TwoPoint;
        c.slow_prob = 1.0; // every node slow (latency 4 > deadline 2)
        let mut vc = VClock::new(&c, 1, 4);
        let s = vc.advance(1);
        assert_eq!(s.close, 2.0);
        assert_eq!(s.participation(), 0);
        assert!(s.stale.iter().all(|&st| st == 1));
    }

    #[test]
    fn staleness_ages_and_saturates() {
        let mut c = cfg();
        c.quorum = 1;
        c.max_staleness = 2;
        c.part_from = 1;
        c.part_to = 5;
        c.part_nodes = 1; // node 0 down rounds 1..4
        let mut vc = VClock::new(&c, 5, 3);
        let stales: Vec<u32> = (1..=6u64).map(|r| vc.advance(r).stale[0]).collect();
        // never fresh before round 5: ages 1,2,3 then saturates at
        // max_staleness+1 = 3; fresh again from round 5
        assert_eq!(stales, vec![1, 2, 3, 3, 0, 0]);
    }

    #[test]
    fn churn_crashes_and_rejoins() {
        let mut c = cfg();
        c.quorum = 2;
        c.max_staleness = 1;
        c.crash_prob = 0.25;
        c.down_rounds = 2;
        let mut vc = VClock::new(&c, 42, 6);
        let mut crashed = 0u32;
        let mut rejoined = 0u32;
        let mut prev_down = vec![false; 6];
        for round in 1..=60u64 {
            let s = vc.advance(round);
            for i in 0..6 {
                if s.down[i] && !prev_down[i] {
                    crashed += 1;
                }
                if !s.down[i] && prev_down[i] {
                    rejoined += 1;
                }
            }
            prev_down = s.down;
        }
        assert!(crashed > 10, "crash_prob=0.25 over 60 rounds: {crashed}");
        assert!(rejoined > 10, "down_rounds=2 must rejoin: {rejoined}");
    }

    #[test]
    fn all_down_closes_at_zero_with_nobody_fresh() {
        let mut c = cfg();
        c.quorum = 2;
        c.max_staleness = 1;
        c.part_from = 1;
        c.part_to = 2;
        c.part_nodes = 4;
        let mut vc = VClock::new(&c, 9, 4);
        let s = vc.advance(1);
        assert_eq!(s.close, 0.0);
        assert_eq!(s.participation(), 0);
        assert!(s.down.iter().all(|&d| d));
    }

    #[test]
    fn decay_weight_is_repeated_multiplication() {
        assert_eq!(decay_weight(0.5, 0), 1.0);
        assert_eq!(decay_weight(0.5, 1), 0.5);
        assert_eq!(decay_weight(0.5, 3), 0.125);
        let mut w = 1.0f64;
        for _ in 0..7 {
            w *= 0.3;
        }
        assert_eq!(decay_weight(0.3, 7), w as f32);
    }

    #[test]
    fn serve_row_policies() {
        let mut c = cfg();
        c.max_staleness = 2;
        let params = vec![1.0f32, 1.0];

        // fresh: row untouched, carried refreshed
        let mut half = vec![3.0f32, 5.0];
        let mut carried = None;
        serve_row(&c, 0, &mut half, &mut carried, &params);
        assert_eq!(half, vec![3.0, 5.0]);
        assert_eq!(carried.as_deref(), Some(&[3.0f32, 5.0][..]));

        // stale within bound, Carry: carried served verbatim
        let mut half = vec![9.0f32, 9.0];
        serve_row(&c, 1, &mut half, &mut carried, &params);
        assert_eq!(half, vec![3.0, 5.0]);

        // stale within bound, Decay: params + λ^st (carried − params)
        c.stale_policy = StalePolicyKind::Decay;
        c.stale_decay = 0.5;
        let mut half = vec![9.0f32, 9.0];
        serve_row(&c, 2, &mut half, &mut carried, &params);
        assert_eq!(half, vec![1.0 + 0.25 * 2.0, 1.0 + 0.25 * 4.0]);

        // beyond max_staleness: committed params served
        let mut half = vec![9.0f32, 9.0];
        serve_row(&c, 3, &mut half, &mut carried, &params);
        assert_eq!(half, params);

        // no carried snapshot yet: params even within the bound
        let mut half = vec![9.0f32, 9.0];
        let mut none = None;
        serve_row(&c, 1, &mut half, &mut none, &params);
        assert_eq!(half, params);
    }

    #[test]
    fn lognormal_latency_is_positive_and_spread() {
        let mut c = cfg();
        c.straggler = StragglerKind::LogNormal;
        c.sigma = 0.5;
        let draws: Vec<f64> = (0..200)
            .map(|r| sample_latency(&c, 77, r, 0))
            .collect();
        assert!(draws.iter().all(|&l| l > 0.0 && l.is_finite()));
        let above = draws.iter().filter(|&&l| l > c.base_latency).count();
        // median of the lognormal is base_latency: both sides populated
        assert!(above > 50 && above < 150, "above-median count {above}");
    }

    #[test]
    fn parse_names_round_trip() {
        for k in [
            StragglerKind::Constant,
            StragglerKind::TwoPoint,
            StragglerKind::LogNormal,
        ] {
            assert_eq!(StragglerKind::parse(k.name()), Some(k));
        }
        for p in [StalePolicyKind::Carry, StalePolicyKind::Decay] {
            assert_eq!(StalePolicyKind::parse(p.name()), Some(p));
        }
        assert_eq!(StragglerKind::parse("bogus"), None);
        assert_eq!(StalePolicyKind::parse("bogus"), None);
    }
}
