//! Low-level substrates: PRNG, vector math, statistics, special functions,
//! JSON codec.
//!
//! The offline crate set contains neither `rand` nor `serde`, so these are
//! first-class, fully tested implementations rather than shims (DESIGN.md
//! §10).

pub mod json;
pub mod pool;
pub mod rng;
pub mod special;
pub mod stats;
pub mod vclock;
pub mod vecmath;
