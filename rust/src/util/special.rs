//! Special functions: log-gamma, log-binomial, KL divergence of Bernoulli
//! pairs — the analytic substrate behind the hypergeometric machinery
//! (paper §4.2, Lemma A.4: tail bound `P(b_i^t ≥ b̂) ≤ exp(−s·D(b̂/s, b/(n−1)))`).

/// Natural log of the Gamma function (Lanczos approximation, g=7, n=9).
/// Accurate to ~1e-13 relative error for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln(n choose k) via log-gamma; exact-ish for huge n (n = 100 000 in the
/// paper's Figure 3 scalability simulations).
pub fn ln_binom(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Bernoulli KL divergence D(α ‖ β) = α ln(α/β) + (1−α) ln((1−α)/(1−β)),
/// the exponent in the paper's Equation (7).
pub fn kl_bernoulli(alpha: f64, beta: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&alpha) && (0.0..=1.0).contains(&beta));
    let term = |p: f64, q: f64| -> f64 {
        if p == 0.0 {
            0.0
        } else if q == 0.0 {
            f64::INFINITY
        } else {
            p * (p / q).ln()
        }
    };
    term(alpha, beta) + term(1.0 - alpha, 1.0 - beta)
}

/// Inverse standard-normal CDF Φ⁻¹ (Acklam's rational approximation,
/// |ε| < 1.15e-9) — used by the ALIE attack's z_max computation.
pub fn inverse_normal_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p must be in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Standard-normal CDF Φ via the Abramowitz & Stegun 7.1.26 erf
/// approximation (|ε| ≤ 1.5e-7) — the analytic reference the straggler
/// sampler's KS test compares the inverse-CDF lognormal draws against.
pub fn normal_cdf(x: f64) -> f64 {
    // erf on t = |x|/sqrt(2), then fold the sign back in
    let z = x.abs() / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.327_591_1 * z);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erf = 1.0 - poly * (-z * z).exp();
    if x >= 0.0 {
        0.5 * (1.0 + erf)
    } else {
        0.5 * (1.0 - erf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_integer_factorials() {
        // Gamma(n) = (n-1)!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, &f) in facts.iter().enumerate() {
            let got = ln_gamma(n as f64 + 1.0);
            assert!((got - (f as f64).ln()).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Gamma(1/2) = sqrt(pi)
        let want = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - want).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_large_stirling() {
        // compare to Stirling series at x = 1e6
        let x: f64 = 1e6;
        let stirling = (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln()
            + 1.0 / (12.0 * x);
        assert!((ln_gamma(x) - stirling).abs() < 1e-6);
    }

    #[test]
    fn ln_binom_small_exact() {
        assert!((ln_binom(5, 2) - 10f64.ln()).abs() < 1e-10);
        assert!((ln_binom(10, 5) - 252f64.ln()).abs() < 1e-10);
        assert_eq!(ln_binom(3, 5), f64::NEG_INFINITY);
        assert!((ln_binom(7, 0)).abs() < 1e-12);
        assert!((ln_binom(7, 7)).abs() < 1e-12);
    }

    #[test]
    fn ln_binom_symmetry() {
        for k in 0..=20 {
            assert!((ln_binom(20, k) - ln_binom(20, 20 - k)).abs() < 1e-9);
        }
    }

    #[test]
    fn kl_properties() {
        assert_eq!(kl_bernoulli(0.3, 0.3), 0.0);
        assert!(kl_bernoulli(0.5, 0.1) > 0.0);
        assert_eq!(kl_bernoulli(0.5, 0.0), f64::INFINITY);
        // known value: D(0.5||0.25) = 0.5 ln2 + 0.5 ln(2/3)
        let want = 0.5 * 2f64.ln() + 0.5 * (2.0f64 / 3.0).ln();
        assert!((kl_bernoulli(0.5, 0.25) - want).abs() < 1e-12);
    }

    #[test]
    fn kl_monotone_in_gap() {
        let mut prev = 0.0;
        for i in 1..9 {
            let beta = 0.5 - 0.05 * i as f64;
            let d = kl_bernoulli(0.5, beta);
            assert!(d > prev);
            prev = d;
        }
    }

    #[test]
    fn inverse_normal_known_values() {
        assert!(inverse_normal_cdf(0.5).abs() < 1e-9);
        assert!((inverse_normal_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inverse_normal_cdf(0.025) + 1.959964).abs() < 1e-4);
        assert!((inverse_normal_cdf(0.8413447) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn inverse_normal_symmetry_and_tails() {
        for p in [0.001, 0.01, 0.2, 0.4] {
            let a = inverse_normal_cdf(p);
            let b = inverse_normal_cdf(1.0 - p);
            assert!((a + b).abs() < 1e-7, "p={p}");
            assert!(a < 0.0);
        }
        assert!(inverse_normal_cdf(1e-10) < -6.0);
    }

    #[test]
    #[should_panic]
    fn inverse_normal_rejects_boundary() {
        inverse_normal_cdf(0.0);
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.0) - 0.841_344_746).abs() < 1.5e-7);
        assert!((normal_cdf(-1.0) - 0.158_655_254).abs() < 1.5e-7);
        assert!((normal_cdf(1.959_964) - 0.975).abs() < 1e-6);
        assert!(normal_cdf(8.0) > 1.0 - 1e-9);
        assert!(normal_cdf(-8.0) < 1e-9);
    }

    #[test]
    fn normal_cdf_inverts_acklam() {
        // Φ(Φ⁻¹(p)) ≈ p across the body and both tails, within the
        // combined error budget of the two approximations
        for p in [0.001, 0.02425, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 0.999] {
            let back = normal_cdf(inverse_normal_cdf(p));
            assert!((back - p).abs() < 1e-6, "p={p} back={back}");
        }
    }

    #[test]
    fn normal_cdf_monotone_and_symmetric() {
        let mut prev = 0.0;
        for i in -40..=40 {
            let x = i as f64 / 10.0;
            let c = normal_cdf(x);
            assert!(c >= prev, "x={x}");
            assert!((c + normal_cdf(-x) - 1.0).abs() < 1e-9, "x={x}");
            prev = c;
        }
    }
}
