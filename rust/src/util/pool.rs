//! Persistent worker-pool execution for the round engine (no crate
//! dependencies — the offline crate set has neither rayon nor crossbeam).
//!
//! [`WorkerPool`] owns `threads − 1` long-lived worker threads, each fed
//! through its own channel; the dispatching thread acts as worker 0, so a
//! pool of `threads` delivers `threads`-way parallelism without ever
//! blocking idle. The previous engine re-spawned scoped threads for every
//! phase of every round (2–3 × threads spawns per round), and per-thread
//! scratch — gradient buffers, attack crafting rows — died with them;
//! with long-lived workers, `thread_local!` scratch survives across
//! rounds, which is exactly how the compute engine and the crafting path
//! reuse their buffers. `threads = 1` spawns nothing and runs inline (the
//! exact legacy serial path).
//!
//! Work items are split into contiguous chunks, one per worker. Because
//! every per-item closure receives the item's **global index**, and all
//! round-path randomness is counter-keyed by node id
//! ([`crate::util::rng::Rng::stream`]), results are bit-identical for
//! every thread count.
//!
//! Dispatch hands each worker a *lifetime-erased* pointer to a chunk
//! runner that lives on the dispatcher's stack. This is sound because the
//! dispatcher never returns (or unwinds) past the frame that owns the
//! runners until every worker has acknowledged completion — a drop guard
//! drains the acknowledgement channel even if the dispatcher's own chunk
//! panics.

use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

/// Resolve a configured thread count: `0` means "use all available
/// parallelism", anything else is taken literally.
pub fn resolve_threads(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// One chunk of work shipped to a worker: a type-erased pointer to a
/// `FnMut() -> Result<()>` chunk runner on the dispatcher's stack, plus
/// the shim that knows its concrete type.
struct Job {
    data: *mut (),
    call: unsafe fn(*mut ()) -> Result<()>,
    /// chunk index, echoed back on the completion channel (chunk 0 runs
    /// on the dispatcher itself and never becomes a `Job`)
    idx: usize,
}

// SAFETY: `data` points to a closure whose type was `Send` when the job
// was built (see `make_job`), and the dispatcher keeps that closure alive
// and unaliased until this job's completion message has been received.
unsafe impl Send for Job {}

unsafe fn call_shim<G: FnMut() -> Result<()>>(data: *mut ()) -> Result<()> {
    (*(data as *mut G))()
}

fn make_job<G: FnMut() -> Result<()> + Send>(task: &mut G, idx: usize) -> Job {
    Job {
        data: task as *mut G as *mut (),
        call: call_shim::<G>,
        idx,
    }
}

/// Completion message from a worker.
enum Done {
    Ok,
    Err(usize, anyhow::Error),
    Panic(Box<dyn std::any::Any + Send>),
}

struct WorkerHandle {
    tx: Sender<Job>,
    join: JoinHandle<()>,
}

fn worker_loop(jobs: Receiver<Job>, done: Sender<Done>) {
    while let Ok(job) = jobs.recv() {
        // catch_unwind keeps the worker alive (and the completion protocol
        // intact) when a chunk runner panics; the payload is re-thrown on
        // the dispatcher.
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: the dispatcher guarantees the pointee outlives this
            // call (it blocks on our completion message).
            unsafe { (job.call)(job.data) }
        }));
        let msg = match result {
            Ok(Ok(())) => Done::Ok,
            Ok(Err(e)) => Done::Err(job.idx, e),
            Err(payload) => Done::Panic(payload),
        };
        if done.send(msg).is_err() {
            break;
        }
    }
}

/// Drains outstanding completion acknowledgements. Runs in `Drop` so the
/// dispatcher can never unwind past the chunk runners while a worker
/// still holds a pointer into them.
struct Drain<'a> {
    rx: &'a Receiver<Done>,
    pending: usize,
    first_err: Option<(usize, anyhow::Error)>,
    panic: Option<Box<dyn std::any::Any + Send>>,
    disconnected: bool,
}

impl Drain<'_> {
    fn recv_all(&mut self) {
        while self.pending > 0 {
            match self.rx.recv() {
                Ok(Done::Ok) => {}
                Ok(Done::Err(idx, e)) => {
                    let lower = match &self.first_err {
                        None => true,
                        Some((i, _)) => idx < *i,
                    };
                    if lower {
                        self.first_err = Some((idx, e));
                    }
                }
                Ok(Done::Panic(p)) => {
                    if self.panic.is_none() {
                        self.panic = Some(p);
                    }
                }
                Err(_) => {
                    // all workers gone mid-dispatch: nothing left to wait
                    // for, and no pointers can still be in use
                    self.disconnected = true;
                    break;
                }
            }
            self.pending -= 1;
        }
    }
}

impl Drop for Drain<'_> {
    fn drop(&mut self) {
        self.recv_all();
    }
}

/// A persistent, std-only thread pool: `threads − 1` long-lived workers
/// plus the dispatching thread itself. Construction is the only time
/// threads are spawned; every [`WorkerPool::try_for_each`] after that is
/// two channel operations per worker.
pub struct WorkerPool {
    threads: usize,
    workers: Vec<WorkerHandle>,
    /// exclusive access for a dispatch in progress (`&self` dispatch API;
    /// the pool is driven from one coordinator thread, the lock is a
    /// correctness backstop, never contended)
    done_rx: Mutex<Receiver<Done>>,
}

impl WorkerPool {
    /// Build a pool for a configured thread count (`0` = all cores).
    pub fn new(configured: usize) -> WorkerPool {
        let threads = resolve_threads(configured);
        let (done_tx, done_rx) = channel();
        let mut workers = Vec::with_capacity(threads.saturating_sub(1));
        for _ in 1..threads {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let join = std::thread::spawn(move || worker_loop(rx, done));
            workers.push(WorkerHandle { tx, join });
        }
        drop(done_tx); // workers hold clones; the channel closes when they exit
        WorkerPool {
            threads,
            workers,
            done_rx: Mutex::new(done_rx),
        }
    }

    /// Resolved worker count (dispatcher included), ≥ 1.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, item)` over every item, on up to `threads` workers
    /// (the calling thread runs the first chunk itself).
    ///
    /// Returns the first error produced (by ascending chunk, not by
    /// time). Worker panics propagate to the caller.
    pub fn try_for_each<T, F>(&self, items: &mut [T], f: F) -> Result<()>
    where
        T: Send,
        F: Fn(usize, &mut T) -> Result<()> + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Ok(());
        }
        let parts = (self.workers.len() + 1).min(n);
        if parts == 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item)?;
            }
            return Ok(());
        }
        let chunk = n.div_ceil(parts);
        let f = &f;
        // one chunk runner per part — all the same concrete closure type,
        // so no boxing is needed and addresses are stable in the Vec
        let mut tasks: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(c, chunk_items)| {
                let base = c * chunk;
                move || -> Result<()> {
                    for (off, item) in chunk_items.iter_mut().enumerate() {
                        f(base + off, item)?;
                    }
                    Ok(())
                }
            })
            .collect();

        let done_rx = self
            .done_rx
            .lock()
            .unwrap_or_else(|poison| poison.into_inner());
        // declared after `tasks`: drops (and therefore drains) before the
        // chunk runners are torn down, even on unwind
        let mut drain = Drain {
            rx: &done_rx,
            pending: 0,
            first_err: None,
            panic: None,
            disconnected: false,
        };

        let mut task_iter = tasks.iter_mut();
        let own_chunk = task_iter.next().expect("parts >= 2 implies >= 1 chunk");
        for (w, task) in task_iter.enumerate() {
            if self.workers[w].tx.send(make_job(task, w + 1)).is_err() {
                // worker thread is gone (it can only exit by panicking
                // outside a job, which cannot happen, or at shutdown);
                // run the chunk inline rather than losing it
                task()?;
                continue;
            }
            drain.pending += 1;
        }
        let own_result = own_chunk();
        drain.recv_all();
        // fully drained: pending == 0, so dropping the guard is a no-op
        let first_err = drain.first_err.take();
        let panic = drain.panic.take();
        let disconnected = drain.disconnected;
        drop(drain);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
        if disconnected {
            return Err(anyhow!("worker pool: completion channel disconnected"));
        }
        match (own_result, first_err) {
            (Err(e), _) => Err(e), // chunk 0 is the lowest index
            (Ok(()), Some((_, e))) => Err(e),
            (Ok(()), None) => Ok(()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let workers = std::mem::take(&mut self.workers);
        let mut joins = Vec::with_capacity(workers.len());
        for w in workers {
            drop(w.tx); // closes the job channel; the worker's recv() errors and it exits
            joins.push(w.join);
        }
        for j in joins {
            let _ = j.join();
        }
    }
}

/// The pre-pool dispatch strategy: spawn scoped threads for this one call
/// and join them before returning. Retained **only** as the baseline for
/// `bench_round`'s dispatch-overhead comparison (persistent pool vs
/// spawn-per-phase) — the round engine itself always goes through
/// [`WorkerPool`].
pub fn scoped_try_for_each<T, F>(items: &mut [T], threads: usize, f: F) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut T) -> Result<()> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(());
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item)?;
        }
        return Ok(());
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let mut first_err = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for (c, chunk_items) in items.chunks_mut(chunk).enumerate() {
            let base = c * chunk;
            handles.push(scope.spawn(move || -> Result<()> {
                for (off, item) in chunk_items.iter_mut().enumerate() {
                    f(base + off, item)?;
                }
                Ok(())
            }));
        }
        for handle in handles {
            match handle.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
    });
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;
    use std::cell::Cell;

    #[test]
    fn resolve_threads_passthrough_and_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn indices_are_global_for_every_thread_count() {
        for threads in [1usize, 2, 3, 7, 64] {
            let pool = WorkerPool::new(threads);
            let mut items = vec![0usize; 37];
            pool.try_for_each(&mut items, |i, slot| {
                *slot = i * i;
                Ok(())
            })
            .unwrap();
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_oversubscribed_inputs_are_fine() {
        let pool = WorkerPool::new(8);
        let mut empty: Vec<usize> = Vec::new();
        pool.try_for_each(&mut empty, |_, _| Ok(())).unwrap();
        let mut one = vec![0usize];
        pool.try_for_each(&mut one, |_, slot| {
            *slot = 9;
            Ok(())
        })
        .unwrap();
        assert_eq!(one[0], 9);
    }

    #[test]
    fn first_error_by_index_wins() {
        let pool = WorkerPool::new(4);
        let mut items = vec![0u8; 20];
        let err = pool
            .try_for_each(&mut items, |i, _| {
                if i >= 5 {
                    Err(anyhow!("boom at {i}"))
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "boom at 5");
    }

    #[test]
    fn pool_survives_repeated_dispatches() {
        // the property the persistent design exists for: many rounds of
        // dispatch against the same threads, no respawn, no leaks
        let pool = WorkerPool::new(4);
        let mut items = vec![0u64; 64];
        for round in 0..200u64 {
            pool.try_for_each(&mut items, |i, slot| {
                *slot += round + i as u64;
                Ok(())
            })
            .unwrap();
        }
        let expect0: u64 = (0..200).sum();
        assert_eq!(items[0], expect0);
        assert_eq!(items[1], expect0 + 200);
    }

    #[test]
    fn thread_local_scratch_survives_across_dispatches() {
        thread_local! {
            static CALLS: Cell<usize> = const { Cell::new(0) };
        }
        let pool = WorkerPool::new(3);
        let mut items = vec![0usize; 12];
        for _ in 0..5 {
            pool.try_for_each(&mut items, |_, slot| {
                CALLS.with(|c| c.set(c.get() + 1));
                *slot = CALLS.with(|c| c.get());
                Ok(())
            })
            .unwrap();
        }
        // with persistent workers, per-thread counters keep growing across
        // dispatches instead of restarting at 0 each time
        assert!(items.iter().any(|&v| v > 12), "{items:?}");
    }

    #[test]
    fn worker_panic_propagates_and_pool_stays_usable() {
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut items = vec![0usize; 16];
            let _ = pool.try_for_each(&mut items, |i, _| {
                if i == 13 {
                    panic!("boom");
                }
                Ok(())
            });
        }));
        assert!(result.is_err(), "panic must propagate");
        // workers caught the panic and kept running: the pool still works
        let mut items = vec![0usize; 16];
        pool.try_for_each(&mut items, |i, slot| {
            *slot = i + 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(items[15], 16);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<usize> = (0..1000).collect();
        let run = |threads: usize| -> usize {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0usize; data.len()];
            let data = &data;
            let mut jobs: Vec<&mut usize> = out.iter_mut().collect();
            pool.try_for_each(&mut jobs, |i, slot| {
                **slot = data[i] * 3 + 1;
                Ok(())
            })
            .unwrap();
            out.iter().sum()
        };
        assert_eq!(run(1), run(4));
        assert_eq!(run(1), run(13));
    }

    #[test]
    fn scoped_baseline_matches_pool() {
        let mut a = vec![0usize; 37];
        let mut b = vec![0usize; 37];
        scoped_try_for_each(&mut a, 4, |i, slot| {
            *slot = i * 7;
            Ok(())
        })
        .unwrap();
        WorkerPool::new(4)
            .try_for_each(&mut b, |i, slot| {
                *slot = i * 7;
                Ok(())
            })
            .unwrap();
        assert_eq!(a, b);
    }
}
