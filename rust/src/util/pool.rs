//! Scoped-thread data-parallel execution for the round engine (no crate
//! dependencies — the offline crate set has neither rayon nor crossbeam).
//!
//! Work items are split into contiguous chunks, one per worker, and driven
//! by `std::thread::scope`. Because every per-item closure receives the
//! item's **global index**, and all round-path randomness is counter-keyed
//! by node id ([`crate::util::rng::Rng::stream`]), results are bit-identical
//! for every thread count — `threads = 1` runs inline with zero scheduling
//! overhead (the exact legacy serial path).

use anyhow::Result;

/// Resolve a configured thread count: `0` means "use all available
/// parallelism", anything else is taken literally.
pub fn resolve_threads(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Run `f(index, item)` over every item, on up to `threads` workers.
///
/// Returns the first error produced (by ascending chunk, not by time).
/// Worker panics propagate to the caller.
pub fn try_for_each<T, F>(items: &mut [T], threads: usize, f: F) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut T) -> Result<()> + Sync,
{
    try_for_each_with(items, threads, || (), |i, item, _| f(i, item))
}

/// Like [`try_for_each`], with one `init()`-produced scratch value per
/// worker — the pattern for reusable per-thread buffers on the hot path.
pub fn try_for_each_with<T, S, I, F>(
    items: &mut [T],
    threads: usize,
    init: I,
    f: F,
) -> Result<()>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut T, &mut S) -> Result<()> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(());
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        let mut scratch = init();
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item, &mut scratch)?;
        }
        return Ok(());
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let init = &init;
    let mut first_err = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for (c, chunk_items) in items.chunks_mut(chunk).enumerate() {
            let base = c * chunk;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut scratch = init();
                for (off, item) in chunk_items.iter_mut().enumerate() {
                    f(base + off, item, &mut scratch)?;
                }
                Ok(())
            }));
        }
        for handle in handles {
            match handle.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
    });
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_threads_passthrough_and_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn indices_are_global_for_every_thread_count() {
        for threads in [1usize, 2, 3, 7, 64] {
            let mut items = vec![0usize; 37];
            try_for_each(&mut items, threads, |i, slot| {
                *slot = i * i;
                Ok(())
            })
            .unwrap();
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_oversubscribed_inputs_are_fine() {
        let mut empty: Vec<usize> = Vec::new();
        try_for_each(&mut empty, 8, |_, _| Ok(())).unwrap();
        let mut one = vec![0usize];
        try_for_each(&mut one, 8, |_, slot| {
            *slot = 9;
            Ok(())
        })
        .unwrap();
        assert_eq!(one[0], 9);
    }

    #[test]
    fn first_error_by_index_wins() {
        let mut items = vec![0u8; 20];
        let err = try_for_each(&mut items, 4, |i, _| {
            if i >= 5 {
                Err(anyhow!("boom at {i}"))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert_eq!(err.to_string(), "boom at 5");
    }

    #[test]
    fn per_worker_scratch_is_isolated() {
        // each worker's scratch counts only its own chunk
        let inits = AtomicUsize::new(0);
        let mut items = vec![0usize; 16];
        try_for_each_with(
            &mut items,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize
            },
            |_, slot, local| {
                *local += 1;
                *slot = *local;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(inits.load(Ordering::SeqCst), 4);
        // chunks of 4: within each chunk the scratch counter restarts
        assert_eq!(items, vec![1, 2, 3, 4, 1, 2, 3, 4, 1, 2, 3, 4, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<usize> = (0..1000).collect();
        let run = |threads: usize| -> usize {
            let mut out = vec![0usize; data.len()];
            let data = &data;
            let mut jobs: Vec<&mut usize> = out.iter_mut().collect();
            try_for_each(&mut jobs, threads, |i, slot| {
                **slot = data[i] * 3 + 1;
                Ok(())
            })
            .unwrap();
            out.iter().sum()
        };
        assert_eq!(run(1), run(4));
        assert_eq!(run(1), run(13));
    }
}
