//! Flat-vector math over `&[f32]` — the model-parameter workhorse.
//!
//! Every honest node's model is a flat `Vec<f32>` of length `d` (the same
//! layout the AOT artifacts use), so the coordinator's hot loop is built
//! from these primitives. Reductions accumulate in f64: with d up to ~10⁶
//! and adversarial magnitudes in play, f32 accumulation loses digits that
//! the robustness logic (distance rankings!) actually needs.
//!
//! # Kernel shape and the FP policy
//!
//! The reductions ([`dot`], [`norm_sq`], [`dist_sq`]) run 4-wide: four
//! independent f64 accumulators over lock-step chunks of 4, combined as
//! `(a0+a1)+(a2+a3)` per [`GRAM_TILE`]-sized tile, tiles summed in
//! ascending order. The unrolled accumulators break the serial f64
//! dependency chain so the optimizer can vectorize; the fixed tile/chunk
//! order keeps every reduction a *pure function of its inputs* — the same
//! everywhere it is evaluated.
//!
//! This **changes the summation order** relative to the old serial loops,
//! so results are not bit-identical with pre-fast-path seeds. The
//! determinism contract is *grid invariance*, not seed archaeology:
//! identical bits across (transport × procs × shards × threads), which
//! `rust/tests/determinism.rs` pins, and ≤ 1e-10 relative drift against
//! the naive serial oracle, which `rust/tests/agg_kernels.rs` pins.

/// y += a * x
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x (copy)
#[inline]
pub fn assign(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

/// Element-wise in-place scale: x *= a
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for xi in x {
        *xi *= a;
    }
}

/// f32 elements per reduction tile: 2048 f32 = 8 KiB per row slice, so a
/// 32-row Gram pass (see `aggregation::pairwise_sqdist`) keeps all its
/// row tiles L2-resident while it sweeps the pair list.
pub const GRAM_TILE: usize = 2048;

/// Dot product of one tile (callers slice rows into [`GRAM_TILE`]-sized
/// pieces) with four independent f64 accumulators. This is the summation
/// order every Gram-style distance in the codebase must share: the
/// round-level distance cache stores values computed by one call site
/// and serves them to another, so the kernel must be a pure function of
/// the two slices — same tile split, same chunk order, same final
/// `(a0+a1)+(a2+a3)` combine.
#[inline]
pub fn dot_tile(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        acc[0] += (xs[0] as f64) * (ys[0] as f64);
        acc[1] += (xs[1] as f64) * (ys[1] as f64);
        acc[2] += (xs[2] as f64) * (ys[2] as f64);
        acc[3] += (xs[3] as f64) * (ys[3] as f64);
    }
    for (k, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[k] += (*x as f64) * (*y as f64);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// Dot product with f64 accumulation: [`dot_tile`] over ascending
/// [`GRAM_TILE`] tiles.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i < a.len() {
        let end = (i + GRAM_TILE).min(a.len());
        acc += dot_tile(&a[i..end], &b[i..end]);
        i = end;
    }
    acc
}

/// Squared L2 norm (f64 accumulation). Defined as `dot(x, x)` so a
/// cached norm and a freshly computed one are always the same bits.
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// One tile of the direct squared-distance reduction (4-wide unrolled,
/// same shape as [`dot_tile`]).
#[inline]
fn dist_sq_tile(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        let d0 = (xs[0] as f64) - (ys[0] as f64);
        let d1 = (xs[1] as f64) - (ys[1] as f64);
        let d2 = (xs[2] as f64) - (ys[2] as f64);
        let d3 = (xs[3] as f64) - (ys[3] as f64);
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    for (k, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        let d = (*x as f64) - (*y as f64);
        acc[k] += d * d;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// Squared L2 distance ||a - b||² (f64 accumulation, 4-wide unrolled
/// tiles). Direct subtract-and-square — immune to the cancellation the
/// Gram identity suffers for near-identical rows, which is why the
/// single-pair API keeps this form while `aggregation::pairwise_sqdist`
/// uses norms + dot.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i < a.len() {
        let end = (i + GRAM_TILE).min(a.len());
        acc += dist_sq_tile(&a[i..end], &b[i..end]);
        i = end;
    }
    acc
}

/// L2 distance.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f64 {
    dist_sq(a, b).sqrt()
}

/// out = mean of rows (each row of equal length), accumulated in f64.
///
/// One generic helper serves both `&[Vec<f32>]` and `&[&[f32]]` callers
/// (the coordinator's column means and the aggregation rules), so the
/// accumulation policy lives in exactly one place. f32 accumulation loses
/// low-order digits once the running sum dwarfs a single row's magnitude
/// (h ≳ 10³ rows of large values shift the mean by orders of magnitude
/// more than one f32 ulp — see `mean_of_f64_accumulation_fixes_drift`).
pub fn mean_of<R: AsRef<[f32]>>(rows: &[R], out: &mut [f32]) {
    assert!(!rows.is_empty());
    // per-thread f64 staging: this runs once per aggregation call on the
    // round hot path, where a fresh d-length allocation per call is pure
    // overhead. Moved out of the cell for the call (the repo-wide
    // take/replace pattern), so re-entrancy degrades to an allocation.
    thread_local! {
        static MEAN_ACC: std::cell::RefCell<Vec<f64>> =
            std::cell::RefCell::new(Vec::new());
    }
    let mut acc = MEAN_ACC.with(|cell| cell.take());
    acc.clear();
    acc.resize(out.len(), 0.0);
    for r in rows {
        let r = r.as_ref();
        debug_assert_eq!(r.len(), out.len());
        for (a, &x) in acc.iter_mut().zip(r) {
            *a += x as f64;
        }
    }
    let inv = 1.0 / rows.len() as f64;
    for (o, a) in out.iter_mut().zip(acc.iter()) {
        *o = (a * inv) as f32;
    }
    MEAN_ACC.with(|cell| cell.replace(acc));
}

/// out = a - b
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// out = a + b
#[inline]
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Clip `x` to L2 ball of radius `tau` around `center`:
/// x <- center + min(1, tau/||x-center||) * (x - center).
/// This is the clipping primitive of ClippedGossip / CS+ / RTC.
pub fn clip_to_ball(x: &mut [f32], center: &[f32], tau: f64) {
    let d = dist(x, center);
    if d > tau && d > 0.0 {
        let f = (tau / d) as f32;
        for (xi, ci) in x.iter_mut().zip(center) {
            *xi = ci + f * (*xi - ci);
        }
    }
}

/// True iff every element is finite.
#[inline]
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn dot_and_norms() {
        let a = [3.0f32, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm_sq(&a), 25.0);
        assert_eq!(norm(&a), 5.0);
    }

    #[test]
    fn dist_symmetry_and_zero() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 6.0, 3.0];
        assert_eq!(dist_sq(&a, &b), dist_sq(&b, &a));
        assert_eq!(dist_sq(&a, &a), 0.0);
        assert_eq!(dist(&a, &b), 5.0);
    }

    #[test]
    fn mean_of_rows() {
        let r1 = [0.0f32, 2.0];
        let r2 = [2.0f32, 4.0];
        let mut out = [0.0f32; 2];
        mean_of(&[&r1, &r2], &mut out);
        assert_eq!(out, [1.0, 3.0]);
    }

    #[test]
    fn clip_inside_ball_is_noop() {
        let mut x = vec![1.0f32, 1.0];
        let c = [0.0f32, 0.0];
        clip_to_ball(&mut x, &c, 10.0);
        assert_eq!(x, vec![1.0, 1.0]);
    }

    #[test]
    fn clip_outside_ball_projects() {
        let mut x = vec![3.0f32, 4.0];
        let c = [0.0f32, 0.0];
        clip_to_ball(&mut x, &c, 2.5);
        assert!((norm(&x) - 2.5).abs() < 1e-6);
        // direction preserved
        assert!((x[0] / x[1] - 3.0 / 4.0).abs() < 1e-6);
    }

    #[test]
    fn clip_respects_center() {
        let mut x = vec![10.0f32, 0.0];
        let c = [8.0f32, 0.0];
        clip_to_ball(&mut x, &c, 1.0);
        assert!((x[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn mean_of_f64_accumulation_fixes_drift() {
        // property: for h ≥ 10³ rows mixing large and small magnitudes,
        // the old f32-accumulation path (reproduced inline) drifts from
        // the exact mean by ≫ one f32 ulp, while the f64 path lands
        // within one ulp of the f32-rounded exact value. Constants chosen
        // so the drift is deterministic and large: alternating 3e8 / 1.0
        // rows make the f32 running sum (~2.25e11, ulp ≈ 16384) eat the
        // small addends and round every large one.
        let h = 1500usize;
        let d = 4usize;
        let rows: Vec<Vec<f32>> = (0..h)
            .map(|i| vec![if i % 2 == 0 { 3.0e8f32 } else { 1.0f32 }; d])
            .collect();
        let exact = (750.0f64 * 3.0e8 + 750.0) / h as f64; // 150 000 000.5
        // old path: f32 accumulate (axpy) then f32 scale
        let mut old = vec![0.0f32; d];
        for r in &rows {
            axpy(&mut old, 1.0, r);
        }
        scale(&mut old, 1.0 / h as f32);
        // new path
        let mut new = vec![0.0f32; d];
        mean_of(&rows, &mut new);
        let ulp = 16.0f64; // f32 spacing at 1.5e8
        for j in 0..d {
            let old_err = (old[j] as f64 - exact).abs();
            let new_err = (new[j] as f64 - exact).abs();
            assert!(old_err > 10.0 * ulp, "j={j}: old path only off by {old_err}");
            assert!(new_err <= ulp, "j={j}: f64 path off by {new_err}");
        }
    }

    #[test]
    fn f64_accumulation_beats_f32() {
        // large-magnitude cancellation case that f32 accumulation fails
        let n = 1_000_000;
        let x = vec![1e4f32; n];
        let ns = norm_sq(&x);
        assert!((ns - 1e8 * n as f64).abs() / (1e8 * n as f64) < 1e-12);
    }

    #[test]
    fn tiled_kernels_handle_remainders_and_tile_edges() {
        // lengths straddling the chunk (4) and tile (GRAM_TILE) edges all
        // agree with the naive serial loops to reordering precision
        for len in [
            0usize,
            1,
            3,
            4,
            5,
            7,
            GRAM_TILE - 1,
            GRAM_TILE,
            GRAM_TILE + 1,
            2 * GRAM_TILE + 3,
        ] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32 * 0.11).cos()).collect();
            let naive_dot: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (*x as f64) * (*y as f64))
                .sum();
            let naive_dist: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| {
                    let d = (*x as f64) - (*y as f64);
                    d * d
                })
                .sum();
            let scale = naive_dist.abs().max(naive_dot.abs()).max(1.0);
            assert!(
                (dot(&a, &b) - naive_dot).abs() / scale < 1e-10,
                "dot len={len}"
            );
            assert!(
                (dist_sq(&a, &b) - naive_dist).abs() / scale < 1e-10,
                "dist_sq len={len}"
            );
        }
    }

    #[test]
    fn norm_sq_is_exactly_dot_with_self() {
        // the cache contract: a norm computed anywhere equals dot(x, x)
        // bit-for-bit, so cached and fresh norms are interchangeable
        let x: Vec<f32> = (0..5000).map(|i| (i as f32 * 0.013).tan()).collect();
        assert_eq!(norm_sq(&x).to_bits(), dot(&x, &x).to_bits());
    }

    #[test]
    fn finite_detection() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }
}
