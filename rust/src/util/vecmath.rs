//! Flat-vector math over `&[f32]` — the model-parameter workhorse.
//!
//! Every honest node's model is a flat `Vec<f32>` of length `d` (the same
//! layout the AOT artifacts use), so the coordinator's hot loop is built
//! from these primitives. Reductions accumulate in f64: with d up to ~10⁶
//! and adversarial magnitudes in play, f32 accumulation loses digits that
//! the robustness logic (distance rankings!) actually needs.

/// y += a * x
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x (copy)
#[inline]
pub fn assign(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

/// Element-wise in-place scale: x *= a
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for xi in x {
        *xi *= a;
    }
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as f64) * (*y as f64);
    }
    acc
}

/// Squared L2 norm (f64 accumulation).
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for v in x {
        acc += (*v as f64) * (*v as f64);
    }
    acc
}

/// L2 norm.
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Squared L2 distance ||a - b||² (f64 accumulation).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x as f64) - (*y as f64);
        acc += d * d;
    }
    acc
}

/// L2 distance.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f64 {
    dist_sq(a, b).sqrt()
}

/// out = mean of rows (each row of equal length), accumulated in f64.
///
/// One generic helper serves both `&[Vec<f32>]` and `&[&[f32]]` callers
/// (the coordinator's column means and the aggregation rules), so the
/// accumulation policy lives in exactly one place. f32 accumulation loses
/// low-order digits once the running sum dwarfs a single row's magnitude
/// (h ≳ 10³ rows of large values shift the mean by orders of magnitude
/// more than one f32 ulp — see `mean_of_f64_accumulation_fixes_drift`).
pub fn mean_of<R: AsRef<[f32]>>(rows: &[R], out: &mut [f32]) {
    assert!(!rows.is_empty());
    let mut acc = vec![0.0f64; out.len()];
    for r in rows {
        let r = r.as_ref();
        debug_assert_eq!(r.len(), out.len());
        for (a, &x) in acc.iter_mut().zip(r) {
            *a += x as f64;
        }
    }
    let inv = 1.0 / rows.len() as f64;
    for (o, a) in out.iter_mut().zip(acc) {
        *o = (a * inv) as f32;
    }
}

/// out = a - b
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// out = a + b
#[inline]
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Clip `x` to L2 ball of radius `tau` around `center`:
/// x <- center + min(1, tau/||x-center||) * (x - center).
/// This is the clipping primitive of ClippedGossip / CS+ / RTC.
pub fn clip_to_ball(x: &mut [f32], center: &[f32], tau: f64) {
    let d = dist(x, center);
    if d > tau && d > 0.0 {
        let f = (tau / d) as f32;
        for (xi, ci) in x.iter_mut().zip(center) {
            *xi = ci + f * (*xi - ci);
        }
    }
}

/// True iff every element is finite.
#[inline]
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn dot_and_norms() {
        let a = [3.0f32, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm_sq(&a), 25.0);
        assert_eq!(norm(&a), 5.0);
    }

    #[test]
    fn dist_symmetry_and_zero() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 6.0, 3.0];
        assert_eq!(dist_sq(&a, &b), dist_sq(&b, &a));
        assert_eq!(dist_sq(&a, &a), 0.0);
        assert_eq!(dist(&a, &b), 5.0);
    }

    #[test]
    fn mean_of_rows() {
        let r1 = [0.0f32, 2.0];
        let r2 = [2.0f32, 4.0];
        let mut out = [0.0f32; 2];
        mean_of(&[&r1, &r2], &mut out);
        assert_eq!(out, [1.0, 3.0]);
    }

    #[test]
    fn clip_inside_ball_is_noop() {
        let mut x = vec![1.0f32, 1.0];
        let c = [0.0f32, 0.0];
        clip_to_ball(&mut x, &c, 10.0);
        assert_eq!(x, vec![1.0, 1.0]);
    }

    #[test]
    fn clip_outside_ball_projects() {
        let mut x = vec![3.0f32, 4.0];
        let c = [0.0f32, 0.0];
        clip_to_ball(&mut x, &c, 2.5);
        assert!((norm(&x) - 2.5).abs() < 1e-6);
        // direction preserved
        assert!((x[0] / x[1] - 3.0 / 4.0).abs() < 1e-6);
    }

    #[test]
    fn clip_respects_center() {
        let mut x = vec![10.0f32, 0.0];
        let c = [8.0f32, 0.0];
        clip_to_ball(&mut x, &c, 1.0);
        assert!((x[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn mean_of_f64_accumulation_fixes_drift() {
        // property: for h ≥ 10³ rows mixing large and small magnitudes,
        // the old f32-accumulation path (reproduced inline) drifts from
        // the exact mean by ≫ one f32 ulp, while the f64 path lands
        // within one ulp of the f32-rounded exact value. Constants chosen
        // so the drift is deterministic and large: alternating 3e8 / 1.0
        // rows make the f32 running sum (~2.25e11, ulp ≈ 16384) eat the
        // small addends and round every large one.
        let h = 1500usize;
        let d = 4usize;
        let rows: Vec<Vec<f32>> = (0..h)
            .map(|i| vec![if i % 2 == 0 { 3.0e8f32 } else { 1.0f32 }; d])
            .collect();
        let exact = (750.0f64 * 3.0e8 + 750.0) / h as f64; // 150 000 000.5
        // old path: f32 accumulate (axpy) then f32 scale
        let mut old = vec![0.0f32; d];
        for r in &rows {
            axpy(&mut old, 1.0, r);
        }
        scale(&mut old, 1.0 / h as f32);
        // new path
        let mut new = vec![0.0f32; d];
        mean_of(&rows, &mut new);
        let ulp = 16.0f64; // f32 spacing at 1.5e8
        for j in 0..d {
            let old_err = (old[j] as f64 - exact).abs();
            let new_err = (new[j] as f64 - exact).abs();
            assert!(old_err > 10.0 * ulp, "j={j}: old path only off by {old_err}");
            assert!(new_err <= ulp, "j={j}: f64 path off by {new_err}");
        }
    }

    #[test]
    fn f64_accumulation_beats_f32() {
        // large-magnitude cancellation case that f32 accumulation fails
        let n = 1_000_000;
        let x = vec![1e4f32; n];
        let ns = norm_sq(&x);
        assert!((ns - 1e8 * n as f64).abs() / (1e8 * n as f64) < 1e-12);
    }

    #[test]
    fn finite_detection() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }
}
