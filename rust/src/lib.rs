//! # RPEL — Robust Pull-based Epidemic Learning
//!
//! A production-grade reproduction of *"Robust and Efficient Collaborative
//! Learning"* (El Mrini, Farhadkhani, Guerraoui — EPFL, 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) is the decentralized-learning coordinator: the
//! synchronous round scheduler, the pull-based epidemic sampler, the
//! omniscient Byzantine adversary engine, robust aggregation (native and
//! AOT/Pallas-backed), the fixed-graph baseline runtimes, and the
//! hypergeometric "effective adversarial fraction" machinery that drives
//! hyper-parameter selection (paper §4.2, Lemma 4.1, Algorithm 2).
//!
//! Layers 2/1 (JAX model graphs and Pallas aggregation kernels) are
//! compiled **once** at build time (`make artifacts`) to HLO text; the
//! [`runtime`] module loads and executes them through the PJRT CPU client
//! (`xla` crate). Python never runs on the training path.
//!
//! ## Quick start
//!
//! ```no_run
//! use rpel::config::presets;
//! use rpel::coordinator::Trainer;
//!
//! let cfg = presets::quickstart_config();
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let history = trainer.run().unwrap();
//! println!("final avg accuracy: {:.3}", history.final_avg_accuracy());
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and DESIGN.md for the
//! full system inventory and per-figure experiment index.

// In-crate #[cfg(test)] modules may freely time things and build scratch
// hash tables; the rpel-lint pass skips test regions for the same reason
// clippy's disallowed lists (clippy.toml) are relaxed for them here.
#![cfg_attr(test, allow(clippy::disallowed_methods, clippy::disallowed_types))]

pub mod aggregation;
pub mod analysis;
pub mod attacks;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sampling;
pub mod testkit;
pub mod util;
pub mod wire;

/// Crate-wide result alias (all fallible public APIs use `anyhow`).
pub type Result<T> = anyhow::Result<T>;
